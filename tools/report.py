"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
JSONL results.

    PYTHONPATH=src python tools/report.py results/dryrun_*.jsonl
"""

from __future__ import annotations

import json
import sys


def load(paths):
    recs = {}
    for p in paths:
        with open(p) as f:
            for line in f:
                r = json.loads(line)
                recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def true_peak(rec) -> int:
    """Live-bytes peak (see launch.dryrun._memory_record): without
    donation args+temp+outputs coexist; with donation the outputs alias
    donated args and XLA books them under temp."""
    m = rec["memory"]
    a, o, t = m["argument_bytes"], m["output_bytes"], m["temp_bytes"]
    if m.get("donated"):
        return t + max(a - o, 0)
    return a + t + o


def main(paths):
    recs = load(paths)
    meshes = sorted({k[2] for k in recs})
    print("## Dry-run matrix (status / peak GiB per chip)\n")
    archs = sorted({k[0] for k in recs})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for mesh in meshes:
        print(f"### mesh {mesh}\n")
        print("| arch | " + " | ".join(shapes) + " |")
        print("|---|" + "---|" * len(shapes))
        for a in archs:
            cells = []
            for s in shapes:
                r = recs.get((a, s, mesh))
                if r is None:
                    cells.append("—")
                elif r["status"] == "skip":
                    cells.append("skip")
                elif r["status"] != "ok":
                    cells.append("**FAIL**")
                else:
                    cells.append("ok " + fmt_bytes(true_peak(r)))
            print(f"| {a} | " + " | ".join(cells) + " |")
        print()

    print("## Roofline (single pod, 256 chips; seconds per step)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant "
          "| useful | peak GiB |")
    print("|---|---|---|---|---|---|---|---|")
    single = [m for m in meshes if m.count("x") == 1]
    for a in archs:
        for s in shapes:
            r = recs.get((a, s, single[0] if single else meshes[0]))
            if not r or r["status"] != "ok":
                continue
            rf = r["roofline"]
            print(f"| {a} | {s} | {rf['compute_s']:.3f} | "
                  f"{rf['memory_s']:.3f} | {rf['collective_s']:.3f} | "
                  f"{rf['dominant']} | {rf['useful_ratio']:.2f} | "
                  f"{fmt_bytes(true_peak(r))} |")


if __name__ == "__main__":
    main(sys.argv[1:])

"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
JSONL results, and summarize serving event logs (repro.obs.EventLog
JSONL — records carrying a "kind" key) when given one:

    PYTHONPATH=src python tools/report.py results/dryrun_*.jsonl
    PYTHONPATH=src python tools/report.py results/serve_events.jsonl
"""

from __future__ import annotations

import json
import sys


def load(paths):
    """Split mixed JSONL inputs: dry-run records keyed by
    (arch, shape, mesh), and obs event-log records (any line with a
    "kind" key, see repro.obs.EventLog)."""
    recs, events = {}, []
    for p in paths:
        with open(p) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                r = json.loads(line)
                if "kind" in r:
                    events.append(r)
                else:
                    recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs, events


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def true_peak(rec) -> int:
    """Live-bytes peak (see launch.dryrun._memory_record): without
    donation args+temp+outputs coexist; with donation the outputs alias
    donated args and XLA books them under temp."""
    m = rec["memory"]
    a, o, t = m["argument_bytes"], m["output_bytes"], m["temp_bytes"]
    if m.get("donated"):
        return t + max(a - o, 0)
    return a + t + o


def render_events(events) -> None:
    """Per-kind summary of a serving event log: counts, the window the
    events span, and the newest few records of each kind (model swaps,
    shard joins, error bursts — the operational story, not metrics)."""
    by_kind: dict[str, list] = {}
    for e in events:
        by_kind.setdefault(e["kind"], []).append(e)
    t0 = min(e.get("ts", 0.0) for e in events)
    t1 = max(e.get("ts", 0.0) for e in events)
    print(f"## Events ({len(events)} over {t1 - t0:.1f}s)\n")
    print("| kind | count | last payload |")
    print("|---|---|---|")
    for kind in sorted(by_kind):
        es = by_kind[kind]
        last = {k: v for k, v in es[-1].items()
                if k not in ("ts", "kind")}
        payload = json.dumps(last) if last else "—"
        print(f"| {kind} | {len(es)} | `{payload}` |")
    print()


def main(paths):
    recs, events = load(paths)
    if events:
        render_events(events)
    if not recs:
        return
    meshes = sorted({k[2] for k in recs})
    print("## Dry-run matrix (status / peak GiB per chip)\n")
    archs = sorted({k[0] for k in recs})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for mesh in meshes:
        print(f"### mesh {mesh}\n")
        print("| arch | " + " | ".join(shapes) + " |")
        print("|---|" + "---|" * len(shapes))
        for a in archs:
            cells = []
            for s in shapes:
                r = recs.get((a, s, mesh))
                if r is None:
                    cells.append("—")
                elif r["status"] == "skip":
                    cells.append("skip")
                elif r["status"] != "ok":
                    cells.append("**FAIL**")
                else:
                    cells.append("ok " + fmt_bytes(true_peak(r)))
            print(f"| {a} | " + " | ".join(cells) + " |")
        print()

    print("## Roofline (single pod, 256 chips; seconds per step)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant "
          "| useful | peak GiB |")
    print("|---|---|---|---|---|---|---|---|")
    single = [m for m in meshes if m.count("x") == 1]
    for a in archs:
        for s in shapes:
            r = recs.get((a, s, single[0] if single else meshes[0]))
            if not r or r["status"] != "ok":
                continue
            rf = r["roofline"]
            print(f"| {a} | {s} | {rf['compute_s']:.3f} | "
                  f"{rf['memory_s']:.3f} | {rf['collective_s']:.3f} | "
                  f"{rf['dominant']} | {rf['useful_ratio']:.2f} | "
                  f"{fmt_bytes(true_peak(r))} |")


if __name__ == "__main__":
    main(sys.argv[1:])

"""Paper Table II: speedup ratio vs number of compute nodes, from the
event-driven async simulator (virtual wall clock with heterogeneous
client speeds and server aggregation cost — reproducing the saturation
the paper observes: ~1.5/~4.2/~8.3 at n=2/5/10)."""

from __future__ import annotations

import jax

from benchmarks.common import row, stock_datasets, timed
from repro.core.simulator import AsyncSimulator, SimConfig
from repro.data.sharding import client_splits
from repro.models.rnn import RNNConfig, init_rnn
from repro.optim.optimizers import sgd
from repro.training.loop import evaluate, make_loss_fn

K = 2000


def make_sim(n, train_ds, test_ds, cfg, loss_fn, params,
             heterogeneous=False):
    import numpy as np
    splits = client_splits(len(train_ds), n, "iid")

    def mk(idx):
        def gen(rng, h, batch):
            out = []
            for _ in range(h):
                b = rng.choice(idx, size=batch)
                out.append((train_ds.x[b], train_ds.y[b],
                            train_ds.v.astype(np.float32)[b],
                            np.ones(batch, np.float32)))
            return tuple(np.stack([o[i] for o in out]) for i in range(4))
        return gen

    return AsyncSimulator(
        loss_fn, sgd(), params, [mk(s) for s in splits],
        SimConfig(n_clients=n, total_iterations=K, batch_size=32,
                  heterogeneous_speeds=heterogeneous,
                  server_cost=0.02, net_delay=(0.005, 0.02)),
        eval_fn=lambda p: evaluate(p, cfg, test_ds)[0])


def main() -> None:
    train_ds, test_ds = stock_datasets("AAPL")
    cfg = RNNConfig()
    loss_fn = make_loss_fn(cfg)
    params = init_rnn(jax.random.PRNGKey(0), cfg)
    for hetero in (False, True):
        tag = "hetero" if hetero else "homog"
        for n in (1, 2, 5, 10):
            sim = make_sim(n, train_ds, test_ds, cfg, loss_fn, params,
                           heterogeneous=hetero)
            s, us = timed(sim.run, repeat=1)
            row(f"speedup/{tag}/n{n}", us,
                f"speedup={s['speedup']:.2f};comms={s['communications']};"
                f"stale_max={s['max_staleness']};"
                f"mse={s['eval_log'][-1][1]:.5f}")


if __name__ == "__main__":
    main()

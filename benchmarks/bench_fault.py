"""Fault tolerance of the crash-supervised process mesh (ISSUE 7
acceptance): measure what a SIGKILLed shard worker costs — detection
latency, fail-fast time for the victim's in-flight requests, supervised
respawn time, re-homed session count — and hard-assert the recovery
guarantees the tests promise, at bench scale.

Three phases over the same (reduced) paper-LSTM model on a 2-process
mesh with a fast heartbeat:

  steady  — mixed submit/step traffic against the healthy fleet; the
            baseline rps the crash phase is compared against;
  crash   — the same traffic, then ONE worker is SIGKILLed mid-flight:
            the victim's requests must fail within the heartbeat budget
            (hard assert: max failure latency far below the 60 s RPC
            timeout), the surviving shard drops ZERO requests (hard
            assert), the supervisor respawns the shard (recovery time
            reported) and post-recovery traffic reaches the replacement
            (hard assert via respawn counter + serving pids);
  restart — durable-state whole-fleet restart (ISSUE 10): traffic
            with a running ``CheckpointDaemon`` must cost <= 5% rps
            against the same mesh without one (hard assert), then the
            WHOLE fleet is SIGKILLed and a fresh mesh boots from the
            ``DurableStore`` — restore time, resumed session count and
            stale re-primes reported; the restored weight version and
            session counts are hard-asserted.

Rows: ``fault/steady,us_per_request,rps=..``,
``fault/crash,0,detect_ms=..;recover_s=..;failed_fast=..;max_fail_ms=..;
survivor_drops=0;rehomed=..;crashes=1;respawns=1``,
``fault/restart,0,baseline_rps=..;ckpt_rps=..;ckpt_cost_pct=..;
restore_s=..;resumed_sessions=..;reprimed_sessions=..``.
"""

from __future__ import annotations

import os
import signal
import threading
import time

import numpy as np

from benchmarks.common import row

HEARTBEAT_S = 0.1
MISS_BUDGET = 4


def _model(smoke: bool):
    import jax

    from repro.models.rnn import RNNConfig, init_rnn
    from repro.serving import LSTMForecaster

    cfg = RNNConfig(input_dim=5, hidden=16 if smoke else 64, num_layers=1,
                    fc_dims=(8,), window=12, evl_head=True)
    fc = LSTMForecaster(cfg=cfg, params=init_rnn(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    fc.calibrate(rng.standard_normal((64, cfg.window, 5)).astype(np.float32)
                 * 0.02)
    return cfg, fc, rng


def main(smoke: bool = False) -> None:
    from repro.obs import EventLog
    from repro.serving import (BatcherConfig, ModelRegistry,
                               MultiProcessServingEngine)

    cfg, fc, rng = _model(smoke)
    n_requests = 200 if smoke else 1000
    wins = rng.standard_normal(
        (64, cfg.window, cfg.input_dim)).astype(np.float32) * 0.02
    clients = [f"c{i}" for i in range(16)]

    reg = ModelRegistry()
    reg.register("m", fc)
    bcfg = BatcherConfig(max_batch=8, max_wait_ms=2.0,
                         length_buckets=(cfg.window,))
    events = EventLog()
    mesh = MultiProcessServingEngine(reg, bcfg, n_shards=2,
                                     heartbeat_s=HEARTBEAT_S,
                                     miss_budget=MISS_BUDGET,
                                     events=events)
    with mesh:
        mesh.warmup("m", lengths=(cfg.window,))
        mesh.reset_clock()

        # -- steady phase: healthy-fleet baseline -------------------------
        t0 = time.perf_counter()
        futs = [mesh.submit("m", wins[i % len(wins)],
                            client_id=clients[i % len(clients)])
                for i in range(n_requests)]
        for f in futs:
            f.result(timeout=60.0)
        steady_wall = time.perf_counter() - t0
        steady_rps = n_requests / steady_wall
        row("fault/steady", steady_wall / n_requests * 1e6,
            f"rps={steady_rps:.0f}")

        # -- crash phase: SIGKILL one worker under mixed traffic ----------
        victim_sid = 0
        victim_pid = mesh.workers[victim_sid].process.pid
        survivor_clients = [c for c in clients
                            if mesh.shard_for(c) != victim_sid]
        victim_clients = [c for c in clients
                          if mesh.shard_for(c) == victim_sid]

        stop = threading.Event()
        survivor_futs: list = []
        survivor_errors: list = []
        fail_lat_ms: list = []
        retried_ok = [0]
        flock = threading.Lock()

        def survivor_traffic():
            i = 0
            while not stop.is_set():
                try:
                    f = mesh.submit("m", wins[i % len(wins)],
                                    client_id=survivor_clients[
                                        i % len(survivor_clients)])
                    with flock:
                        survivor_futs.append(f)
                except Exception as e:  # noqa: BLE001 — a drop IS a failure
                    survivor_errors.append(e)
                i += 1
                time.sleep(0.001)

        def victim_traffic():
            # the victim's requests may fail during the outage — but
            # only FAST, and a retry must succeed once repaired (that
            # retry is what re-homes the client onto the respawn)
            i = 0
            while not stop.is_set():
                c = victim_clients[i % len(victim_clients)]
                t_req = time.monotonic()
                try:
                    mesh.submit("m", wins[i % len(wins)],
                                client_id=c).result(timeout=60.0)
                    if fail_lat_ms:            # first success after fails
                        retried_ok[0] += 1
                except Exception:  # noqa: BLE001
                    fail_lat_ms.append((time.monotonic() - t_req) * 1e3)
                i += 1
                time.sleep(0.001)

        # streaming sessions pinned to the victim shard: their carries
        # die with it. The stepper below keeps stepping them through
        # the outage (with retry) — once the router shrinks, the steps
        # land on the SURVIVOR, which builds fresh carries there; the
        # respawn then wins those clients back and migrates the carries
        # home, so the bench's rehomed count exercises the real path
        sess_clients = victim_clients[:4]
        sess_w = {c: wins[j] for j, c in enumerate(sess_clients)}
        for c, w in sess_w.items():
            for t in range(cfg.window // 2):
                mesh.step("m", c, w[t])
        stepped_elsewhere = [0]

        def victim_stepper():
            i = 0
            while not stop.is_set():
                c = sess_clients[i % len(sess_clients)]
                w = sess_w[c]
                t = cfg.window // 2 + (i % (cfg.window // 2))
                try:
                    mesh.step("m", c, w[t], history=w[:t])
                    if mesh.shard_for(c) != victim_sid:
                        stepped_elsewhere[0] += 1
                except Exception:  # noqa: BLE001 — outage window, retried
                    pass
                i += 1
                time.sleep(0.005)

        threads = [threading.Thread(target=fn)
                   for fn in (survivor_traffic, victim_traffic,
                              victim_stepper)]
        for t in threads:
            t.start()
        try:
            time.sleep(0.5)
            t_kill = time.monotonic()
            t_kill_wall = time.time()          # EventLog stamps wall time
            os.kill(victim_pid, signal.SIGKILL)
            # detection: first shard_crash event
            detect_ms = None
            while time.monotonic() - t_kill < 30.0:
                crash_evs = [e for e in events.events()
                             if e["kind"] == "shard_crash"]
                if crash_evs:
                    detect_ms = (crash_evs[0]["ts"] - t_kill_wall) * 1e3
                    break
                time.sleep(0.01)
            assert detect_ms is not None, "crash never detected"
            # recovery: respawned worker serving again
            recover_s = None
            while time.monotonic() - t_kill < 120.0:
                w = mesh.workers.get(victim_sid)
                if mesh.respawns >= 1 and w is not None \
                        and w.pid != victim_pid:
                    recover_s = time.monotonic() - t_kill
                    break
                time.sleep(0.01)
            assert recover_s is not None, "shard never respawned"
            time.sleep(0.5)                    # post-recovery traffic
        finally:
            stop.set()
            for t in threads:
                t.join()

        # hard guarantees, bench-scale
        assert not survivor_errors, survivor_errors[:3]
        with flock:
            pending = list(survivor_futs)
        for f in pending:                      # zero survivor drops
            f.result(timeout=60.0)
        budget_ms = (HEARTBEAT_S * MISS_BUDGET + 5.0) * 1e3
        max_fail_ms = max(fail_lat_ms) if fail_lat_ms else 0.0
        assert max_fail_ms < budget_ms, \
            f"victim failures too slow: {max_fail_ms:.0f}ms"
        assert retried_ok[0] > 0 or not fail_lat_ms, \
            "victim traffic never resumed after repair"
        snap = mesh.snapshot()
        assert snap["crashes"] == 1 and snap["respawns"] == 1

        # finish the victim-pinned streams through the re-prime path
        for c, w in sess_w.items():
            for t in range(cfg.window // 2, cfg.window):
                mesh.step("m", c, w[t], history=w[:t])

        respawn_ev = next(e for e in events.events()
                          if e["kind"] == "shard_respawn")
        if stepped_elsewhere[0]:
            # steps landed on the survivor during the outage, so the
            # respawn had carries to win back — the re-home path ran
            assert respawn_ev.get("rehomed", 0) >= 1, respawn_ev
        row("fault/crash", 0.0,
            f"detect_ms={detect_ms:.0f};recover_s={recover_s:.2f};"
            f"failed_fast={len(fail_lat_ms)};"
            f"max_fail_ms={max_fail_ms:.0f};"
            f"survivor_drops=0;rehomed={respawn_ev.get('rehomed', 0)};"
            f"crashes={snap['crashes']};respawns={snap['respawns']}")

    _restart_phase(smoke)


def _restart_phase(smoke: bool) -> None:
    """Durable-state restart: checkpointing overhead vs an identical
    uncheckpointed mesh (hard assert <= 5% rps cost), then a whole-fleet
    SIGKILL and a timed cold boot from the store."""
    import shutil
    import tempfile

    import jax

    from repro.models.rnn import init_rnn
    from repro.serving import (BatcherConfig, LSTMForecaster, ModelRegistry,
                               MultiProcessServingEngine)
    from repro.serving.durable import CheckpointDaemon, DurableStore

    cfg, fc, rng = _model(smoke)
    n_requests = 150 if smoke else 600
    wins = rng.standard_normal(
        (64, cfg.window, cfg.input_dim)).astype(np.float32) * 0.02
    clients = [f"c{i}" for i in range(16)]
    bcfg = BatcherConfig(max_batch=8, max_wait_ms=2.0,
                         length_buckets=(cfg.window,))
    tmp = tempfile.mkdtemp(prefix="bench-durable-")
    try:
        store = DurableStore(tmp, keep_last=3)
        reg = ModelRegistry()
        reg.register("m", fc)
        mesh = MultiProcessServingEngine(reg, bcfg, n_shards=2,
                                         supervise=False, durable=store)
        mesh.start()
        try:
            mesh.warmup("m", lengths=(cfg.window,))

            def burst() -> float:
                t0 = time.perf_counter()
                futs = [mesh.submit("m", wins[i % len(wins)],
                                    client_id=clients[i % len(clients)])
                        for i in range(n_requests)]
                for f in futs:
                    f.result(timeout=60.0)
                return n_requests / (time.perf_counter() - t0)

            burst()                                 # warm both shards
            baseline_rps = max(burst() for _ in range(2))
            daemon = CheckpointDaemon(store, mesh, interval_s=0.25)
            daemon.start()
            ckpt_rps = max(burst() for _ in range(2))
            cost_pct = (1.0 - ckpt_rps / baseline_rps) * 100.0
            assert ckpt_rps >= 0.95 * baseline_rps, \
                (f"checkpointing cost too high: {baseline_rps:.0f} -> "
                 f"{ckpt_rps:.0f} rps ({cost_pct:.1f}%)")

            # streaming sessions: half created BEFORE a weight swap
            # (their checkpointed carries go stale), half after
            stale_c, fresh_c = clients[:4], clients[4:8]
            half = cfg.window // 2
            for c in stale_c:
                for t in range(half):
                    mesh.step("m", c, wins[0][t])
            daemon.checkpoint_now()
            fc2 = LSTMForecaster(
                cfg=cfg, params=init_rnn(jax.random.PRNGKey(1), cfg))
            fc2.calibrate(rng.standard_normal(
                (64, cfg.window, cfg.input_dim)).astype(np.float32) * 0.02)
            mesh.swap("m", fc2)
            mesh.propagate("m")
            for c in fresh_c:
                for t in range(half):
                    mesh.step("m", c, wins[1][t])
            daemon.checkpoint_now()
            daemon.stop()

            # whole-fleet loss: SIGKILL every worker (supervision is
            # off, so nothing comes back on its own)
            for w in mesh.workers.values():
                os.kill(w.process.pid, signal.SIGKILL)
        finally:
            try:
                mesh.stop()
            except Exception:  # noqa: BLE001 — the fleet is dead
                pass

        # cold boot: fresh registry + mesh, restore from the store
        reg2 = ModelRegistry()
        mesh2 = MultiProcessServingEngine(reg2, bcfg, n_shards=2,
                                          supervise=False)
        with mesh2:
            t0 = time.perf_counter()
            out = mesh2.restore_from(DurableStore(tmp, keep_last=3))
            restore_s = time.perf_counter() - t0
            assert reg2.version("m") == 2, reg2.version("m")
            assert out["restored_sessions"] == 8, out
            assert out["restored_stale"] == 4, out
            # restored streams serve: fresh resume in place, stale
            # re-prime from history on their next step
            for c in fresh_c:
                mesh2.step("m", c, wins[1][half])
            for c in stale_c:
                mesh2.step("m", c, wins[0][half], history=wins[0][:half])
            reprimed = mesh2.snapshot()["reprimes"]
            assert reprimed >= len(stale_c), reprimed
        row("fault/restart", 0.0,
            f"baseline_rps={baseline_rps:.0f};ckpt_rps={ckpt_rps:.0f};"
            f"ckpt_cost_pct={cost_pct:.1f};restore_s={restore_s:.3f};"
            f"resumed_sessions={out['restored_sessions']};"
            f"reprimed_sessions={out['restored_stale']}")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()

"""Fault tolerance of the crash-supervised process mesh (ISSUE 7
acceptance): measure what a SIGKILLed shard worker costs — detection
latency, fail-fast time for the victim's in-flight requests, supervised
respawn time, re-homed session count — and hard-assert the recovery
guarantees the tests promise, at bench scale.

Two phases over the same (reduced) paper-LSTM model on a 2-process
mesh with a fast heartbeat:

  steady  — mixed submit/step traffic against the healthy fleet; the
            baseline rps the crash phase is compared against;
  crash   — the same traffic, then ONE worker is SIGKILLed mid-flight:
            the victim's requests must fail within the heartbeat budget
            (hard assert: max failure latency far below the 60 s RPC
            timeout), the surviving shard drops ZERO requests (hard
            assert), the supervisor respawns the shard (recovery time
            reported) and post-recovery traffic reaches the replacement
            (hard assert via respawn counter + serving pids).

Rows: ``fault/steady,us_per_request,rps=..``,
``fault/crash,0,detect_ms=..;recover_s=..;failed_fast=..;max_fail_ms=..;
survivor_drops=0;rehomed=..;crashes=1;respawns=1``.
"""

from __future__ import annotations

import os
import signal
import threading
import time

import numpy as np

from benchmarks.common import row

HEARTBEAT_S = 0.1
MISS_BUDGET = 4


def _model(smoke: bool):
    import jax

    from repro.models.rnn import RNNConfig, init_rnn
    from repro.serving import LSTMForecaster

    cfg = RNNConfig(input_dim=5, hidden=16 if smoke else 64, num_layers=1,
                    fc_dims=(8,), window=12, evl_head=True)
    fc = LSTMForecaster(cfg=cfg, params=init_rnn(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    fc.calibrate(rng.standard_normal((64, cfg.window, 5)).astype(np.float32)
                 * 0.02)
    return cfg, fc, rng


def main(smoke: bool = False) -> None:
    from repro.obs import EventLog
    from repro.serving import (BatcherConfig, ModelRegistry,
                               MultiProcessServingEngine)

    cfg, fc, rng = _model(smoke)
    n_requests = 200 if smoke else 1000
    wins = rng.standard_normal(
        (64, cfg.window, cfg.input_dim)).astype(np.float32) * 0.02
    clients = [f"c{i}" for i in range(16)]

    reg = ModelRegistry()
    reg.register("m", fc)
    bcfg = BatcherConfig(max_batch=8, max_wait_ms=2.0,
                         length_buckets=(cfg.window,))
    events = EventLog()
    mesh = MultiProcessServingEngine(reg, bcfg, n_shards=2,
                                     heartbeat_s=HEARTBEAT_S,
                                     miss_budget=MISS_BUDGET,
                                     events=events)
    with mesh:
        mesh.warmup("m", lengths=(cfg.window,))
        mesh.reset_clock()

        # -- steady phase: healthy-fleet baseline -------------------------
        t0 = time.perf_counter()
        futs = [mesh.submit("m", wins[i % len(wins)],
                            client_id=clients[i % len(clients)])
                for i in range(n_requests)]
        for f in futs:
            f.result(timeout=60.0)
        steady_wall = time.perf_counter() - t0
        steady_rps = n_requests / steady_wall
        row("fault/steady", steady_wall / n_requests * 1e6,
            f"rps={steady_rps:.0f}")

        # -- crash phase: SIGKILL one worker under mixed traffic ----------
        victim_sid = 0
        victim_pid = mesh.workers[victim_sid].process.pid
        survivor_clients = [c for c in clients
                            if mesh.shard_for(c) != victim_sid]
        victim_clients = [c for c in clients
                          if mesh.shard_for(c) == victim_sid]

        stop = threading.Event()
        survivor_futs: list = []
        survivor_errors: list = []
        fail_lat_ms: list = []
        retried_ok = [0]
        flock = threading.Lock()

        def survivor_traffic():
            i = 0
            while not stop.is_set():
                try:
                    f = mesh.submit("m", wins[i % len(wins)],
                                    client_id=survivor_clients[
                                        i % len(survivor_clients)])
                    with flock:
                        survivor_futs.append(f)
                except Exception as e:  # noqa: BLE001 — a drop IS a failure
                    survivor_errors.append(e)
                i += 1
                time.sleep(0.001)

        def victim_traffic():
            # the victim's requests may fail during the outage — but
            # only FAST, and a retry must succeed once repaired (that
            # retry is what re-homes the client onto the respawn)
            i = 0
            while not stop.is_set():
                c = victim_clients[i % len(victim_clients)]
                t_req = time.monotonic()
                try:
                    mesh.submit("m", wins[i % len(wins)],
                                client_id=c).result(timeout=60.0)
                    if fail_lat_ms:            # first success after fails
                        retried_ok[0] += 1
                except Exception:  # noqa: BLE001
                    fail_lat_ms.append((time.monotonic() - t_req) * 1e3)
                i += 1
                time.sleep(0.001)

        # streaming sessions pinned to the victim shard: their carries
        # die with it. The stepper below keeps stepping them through
        # the outage (with retry) — once the router shrinks, the steps
        # land on the SURVIVOR, which builds fresh carries there; the
        # respawn then wins those clients back and migrates the carries
        # home, so the bench's rehomed count exercises the real path
        sess_clients = victim_clients[:4]
        sess_w = {c: wins[j] for j, c in enumerate(sess_clients)}
        for c, w in sess_w.items():
            for t in range(cfg.window // 2):
                mesh.step("m", c, w[t])
        stepped_elsewhere = [0]

        def victim_stepper():
            i = 0
            while not stop.is_set():
                c = sess_clients[i % len(sess_clients)]
                w = sess_w[c]
                t = cfg.window // 2 + (i % (cfg.window // 2))
                try:
                    mesh.step("m", c, w[t], history=w[:t])
                    if mesh.shard_for(c) != victim_sid:
                        stepped_elsewhere[0] += 1
                except Exception:  # noqa: BLE001 — outage window, retried
                    pass
                i += 1
                time.sleep(0.005)

        threads = [threading.Thread(target=fn)
                   for fn in (survivor_traffic, victim_traffic,
                              victim_stepper)]
        for t in threads:
            t.start()
        try:
            time.sleep(0.5)
            t_kill = time.monotonic()
            t_kill_wall = time.time()          # EventLog stamps wall time
            os.kill(victim_pid, signal.SIGKILL)
            # detection: first shard_crash event
            detect_ms = None
            while time.monotonic() - t_kill < 30.0:
                crash_evs = [e for e in events.events()
                             if e["kind"] == "shard_crash"]
                if crash_evs:
                    detect_ms = (crash_evs[0]["ts"] - t_kill_wall) * 1e3
                    break
                time.sleep(0.01)
            assert detect_ms is not None, "crash never detected"
            # recovery: respawned worker serving again
            recover_s = None
            while time.monotonic() - t_kill < 120.0:
                w = mesh.workers.get(victim_sid)
                if mesh.respawns >= 1 and w is not None \
                        and w.pid != victim_pid:
                    recover_s = time.monotonic() - t_kill
                    break
                time.sleep(0.01)
            assert recover_s is not None, "shard never respawned"
            time.sleep(0.5)                    # post-recovery traffic
        finally:
            stop.set()
            for t in threads:
                t.join()

        # hard guarantees, bench-scale
        assert not survivor_errors, survivor_errors[:3]
        with flock:
            pending = list(survivor_futs)
        for f in pending:                      # zero survivor drops
            f.result(timeout=60.0)
        budget_ms = (HEARTBEAT_S * MISS_BUDGET + 5.0) * 1e3
        max_fail_ms = max(fail_lat_ms) if fail_lat_ms else 0.0
        assert max_fail_ms < budget_ms, \
            f"victim failures too slow: {max_fail_ms:.0f}ms"
        assert retried_ok[0] > 0 or not fail_lat_ms, \
            "victim traffic never resumed after repair"
        snap = mesh.snapshot()
        assert snap["crashes"] == 1 and snap["respawns"] == 1

        # finish the victim-pinned streams through the re-prime path
        for c, w in sess_w.items():
            for t in range(cfg.window // 2, cfg.window):
                mesh.step("m", c, w[t], history=w[:t])

        respawn_ev = next(e for e in events.events()
                          if e["kind"] == "shard_respawn")
        if stepped_elsewhere[0]:
            # steps landed on the survivor during the outage, so the
            # respawn had carries to win back — the re-home path ran
            assert respawn_ev.get("rehomed", 0) >= 1, respawn_ev
        row("fault/crash", 0.0,
            f"detect_ms={detect_ms:.0f};recover_s={recover_s:.2f};"
            f"failed_fast={len(fail_lat_ms)};"
            f"max_fail_ms={max_fail_ms:.0f};"
            f"survivor_drops=0;rehomed={respawn_ev.get('rehomed', 0)};"
            f"crashes={snap['crashes']};respawns={snap['respawns']}")


if __name__ == "__main__":
    main()

"""Paper Figs. 5-10: prediction accuracy of the distributed framework vs
the single-node baseline, for n in {1, 2, 5, 10} compute nodes, on two
tickers (AAPL, AMZN) — test MSE as the accuracy metric (the paper reports
prediction curves; same level of accuracy is the claim)."""

from __future__ import annotations

from benchmarks.common import row, stock_datasets, timed
from repro.training.loop import train_rnn_local_sgd, train_rnn_serial

ITERS = 1500
BATCH = 32


def main() -> None:
    for ticker in ("AAPL", "AMZN"):
        train_ds, test_ds = stock_datasets(ticker)
        res, us = timed(train_rnn_serial, train_ds, test_ds,
                        iterations=ITERS, batch=BATCH, repeat=1)
        base = res.test_mse
        row(f"prediction/{ticker}/serial_n1", us, f"mse={base:.5f}")
        for n in (2, 5, 10):
            res, us = timed(train_rnn_local_sgd, train_ds, test_ds,
                            n_workers=n, iterations=ITERS, batch=BATCH,
                            repeat=1)
            row(f"prediction/{ticker}/async_n{n}", us,
                f"mse={res.test_mse:.5f};rel={res.test_mse/base:.2f};"
                f"comms={res.communications}")


if __name__ == "__main__":
    main()

"""Sharded serving mesh scaling curve + swap-storm behavior (ISSUE 3
acceptance) + multi-process transport (ISSUE 4 acceptance): aggregate
throughput at 1/2/4 shards, p99 / dropped requests / version skew while
a publisher storms weight swaps across the fleet, and the same mesh over
OS processes with a shard joining and leaving mid-traffic.

Three phases over the same (reduced) paper-LSTM model:

  scaling    — submit-all traffic against 1, 2 and 4 shards; the
               4-shard mesh must beat the single engine (>= 1.5x on a
               multi-core CPU — reported, since the achievable ratio is
               machine-dependent);
  swapstorm  — a ``WeightPublisher`` publishes into the swarm every few
               ms while traffic flows over the max-shard mesh: zero
               dropped requests (hard assert), every sampled version
               vector within the configured staleness skew bound (hard
               assert), p99 and pull/transfer volume reported;
  transport  — the mesh over the SOCKET transport, one EngineShard per
               OS process: traffic flows while a shard joins and a
               shard leaves the live fleet, with zero dropped requests
               and the skew bound held throughout (hard asserts), and
               rps/latency vs the in-process thread mesh reported.

Rows: ``mesh/shards<n>,us_per_request,rps=..;p99_ms=..;occ=..``,
``mesh/scaling,0,speedup4v1=..``,
``mesh/swapstorm,us_per_request,p99_ms=..;dropped=..;skew_max=..;...``
and ``mesh/transport,us_per_request,rps=..;procs=..;dropped=..;...``.

Standalone runs force 4 host devices (one per shard, before jax
initializes) so shard flushes can execute concurrently; under
``benchmarks.run`` whatever devices exist are used.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import row


def _model(smoke: bool):
    import jax

    from repro.models.rnn import RNNConfig, init_rnn
    from repro.serving import LSTMForecaster

    # reduced paper topology; sized so the jitted flush dominates the
    # GIL-held batching overhead (that compute is what shards overlap)
    cfg = RNNConfig(input_dim=5, hidden=32 if smoke else 256, num_layers=2,
                    fc_dims=(16, 8) if smoke else (64, 32), window=20,
                    evl_head=True)
    fc = LSTMForecaster(cfg=cfg, params=init_rnn(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    fc.calibrate(rng.standard_normal((64, cfg.window, 5)).astype(np.float32)
                 * 0.02)
    return cfg, fc, rng


def _serve_all(engine, key, windows, n_requests: int):
    """Submit everything upfront, wait for all results; returns
    (rps, dropped)."""
    dropped = 0
    futures = []
    t0 = time.perf_counter()
    for i in range(n_requests):
        try:
            futures.append(engine.submit(key, windows[i % len(windows)]))
        except RuntimeError:
            dropped += 1
    for f in futures:
        f.result(timeout=120.0)
    return len(futures) / (time.perf_counter() - t0), dropped


def main(n_requests: int = 384, smoke: bool = False) -> None:
    from repro.serving import (BatcherConfig, ModelRegistry,
                               ServingEngine, ShardedServingEngine,
                               WeightPublisher)

    if smoke:
        n_requests = min(n_requests, 96)
    cfg, fc0, rng = _model(smoke)
    windows = rng.standard_normal(
        (128, cfg.window, 5)).astype(np.float32) * 0.02
    bcfg = BatcherConfig(max_batch=16, max_wait_ms=2.0,
                         length_buckets=(cfg.window,))
    shard_counts = (1, 2) if smoke else (1, 2, 4)
    max_shards = shard_counts[-1]
    max_skew = 1

    # -- phase 1: scaling curve -------------------------------------------
    rps = {}
    for n_shards in shard_counts:
        reg = ModelRegistry()
        reg.register("m", fc0)
        engine = (ServingEngine(reg, bcfg) if n_shards == 1 else
                  ShardedServingEngine(reg, bcfg, n_shards=n_shards,
                                       max_skew=max_skew))
        with engine:
            engine.warmup("m", lengths=(cfg.window,))
            _serve_all(engine, "m", windows, n_requests)   # warm pass
            if n_shards == 1:
                engine.telemetry.reset_clock()
            else:
                engine.reset_clock()
            # best of 3 measured passes: a co-tenant stealing the box
            # mid-pass should not decide the scaling curve
            rps[n_shards] = max(
                _serve_all(engine, "m", windows, n_requests)[0]
                for _ in range(1 if smoke else 3))
            snap = (engine.telemetry.snapshot() if n_shards == 1
                    else engine.snapshot())
        row(f"mesh/shards{n_shards}", 1e6 / max(rps[n_shards], 1e-9),
            f"rps={rps[n_shards]:.0f};p99_ms={snap['p99_ms']:.2f};"
            f"occ={snap['batch_occupancy']:.2f}")
    speedup = rps[max_shards] / max(rps[1], 1e-9)
    per_count = ";".join(f"speedup{n}v1={rps[n]/max(rps[1], 1e-9):.2f}x"
                         for n in shard_counts[1:])
    row("mesh/scaling", 0.0, per_count
        + (";smoke=driver-check-only (tiny model, single pass: not a "
           "scaling measurement)" if smoke else
           f";accept={'PASS' if speedup >= 1.5 else 'FAIL'} (>=1.5x)"))

    # -- phase 2: swap storm over the mesh --------------------------------
    reg = ModelRegistry()
    reg.register("m", fc0)
    mesh = ShardedServingEngine(reg, bcfg, n_shards=max_shards,
                                max_skew=max_skew)
    publisher = WeightPublisher(mesh.swarm, "m", template=fc0)
    import jax
    variants = [jax.tree.map(lambda a, s=s: a * s, fc0.params)
                for s in (1.0, 1.05, 0.95)]
    stop = threading.Event()
    swaps = [0]
    skew_samples: list[tuple[int, int]] = []

    def swapper() -> None:
        while not stop.is_set():
            publisher.publish(variants[swaps[0] % len(variants)])
            swaps[0] += 1
            time.sleep(0.003)

    def sampler() -> None:
        # every sampled vector must respect the skew bound (the vector
        # is taken atomically under the swarm's publish lock)
        while not stop.is_set():
            skew_samples.append((mesh.swarm.skew("m"),
                                 mesh.swarm.staleness("m")))
            time.sleep(0.001)

    with mesh:
        mesh.warmup("m", lengths=(cfg.window,))
        mesh.reset_clock()
        threads = [threading.Thread(target=swapper, name="mesh-swapper"),
                   threading.Thread(target=sampler, name="mesh-sampler")]
        for t in threads:
            t.start()
        try:
            storm_rps, dropped = _serve_all(mesh, "m", windows, n_requests)
        finally:
            stop.set()
            for t in threads:
                t.join()
        snap = mesh.snapshot()
    skew_max = max((s for s, _ in skew_samples), default=0)
    stale_max = max((s for _, s in skew_samples), default=0)
    row("mesh/swapstorm", 1e6 / max(storm_rps, 1e-9),
        f"p99_ms={snap['p99_ms']:.2f};dropped={dropped};swaps={swaps[0]};"
        f"pulls={snap['pulls']};mb_pulled={snap['bytes_pulled']/1e6:.1f};"
        f"skew_max={skew_max};staleness_max={stale_max};"
        f"versions_served={len(snap['requests_by_version'])}")
    assert dropped == 0, \
        f"swap storm dropped {dropped} requests on the mesh"
    assert stale_max <= max_skew, \
        f"staleness skew {stale_max} exceeded the bound {max_skew}"
    print(f"# mesh: {speedup:.2f}x at {max_shards} shards | storm: "
          f"{swaps[0]} publishes, 0 dropped, skew bound {max_skew} held "
          f"({len(skew_samples)} samples, max staleness {stale_max})")

    # -- phase 3: multi-process transport with live membership ------------
    _transport_phase(cfg, fc0, windows, n_requests, max_skew,
                     thread_rps=rps[2])     # vs the 2-shard thread mesh


def _transport_phase(cfg, fc0, windows, n_requests, max_skew,
                     thread_rps) -> None:
    """The mesh over OS processes (2 workers), a shard joining and a
    shard leaving while traffic flows: zero drops + skew bound asserted,
    throughput vs the thread mesh reported."""
    from repro.serving import (BatcherConfig, ModelRegistry,
                               MultiProcessServingEngine)

    bcfg = BatcherConfig(max_batch=16, max_wait_ms=2.0,
                         length_buckets=(cfg.window,))
    reg = ModelRegistry()
    reg.register("m", fc0)
    mesh = MultiProcessServingEngine(reg, bcfg, n_shards=2,
                                     max_skew=max_skew)
    dropped = 0
    skew_samples = []
    with mesh:
        mesh.warmup("m", lengths=(cfg.window,))
        mesh.reset_clock()
        # steady state, timed: the cross-process rps the row reports
        t0 = time.perf_counter()
        steady = [mesh.submit("m", windows[i % len(windows)],
                              client_id=f"client-{i % 32}")
                  for i in range(n_requests)]
        for f in steady:
            f.result(timeout=120.0)
        rps = n_requests / (time.perf_counter() - t0)
        # membership churn, untimed (a join spawns a whole process):
        # submits stay in flight across the join and the leave — the
        # acceptance asserts are zero drops + the skew bound
        futures = []
        third = max(1, n_requests // 3)
        for phase, membership in ((0, None), (1, "join"), (2, "leave")):
            if membership == "join":
                mesh.add_shard()            # mid-traffic: futures from
            elif membership == "leave":     # phase 0/1 are still pending
                mesh.remove_shard(0)
            skew_samples.append(mesh.staleness("m"))
            for i in range(third):
                try:
                    futures.append(mesh.submit(
                        "m", windows[(phase * third + i) % len(windows)],
                        client_id=f"client-{i % 32}"))
                except (RuntimeError, ConnectionError, KeyError):
                    dropped += 1
        for f in futures:
            try:
                f.result(timeout=120.0)
            except Exception:  # noqa: BLE001 — a failed future IS a drop
                dropped += 1
        snap = mesh.snapshot()
    row("mesh/transport", 1e6 / max(rps, 1e-9),
        f"rps={rps:.0f};vs_thread_mesh={rps/max(thread_rps, 1e-9):.2f}x;"
        f"procs=2->3->2;p99_ms={snap['p99_ms']:.2f};dropped={dropped};"
        f"pulls={snap['pulls']};mb_pushed={snap['bytes_pulled']/1e6:.1f};"
        f"staleness_max={max(skew_samples)}")
    assert dropped == 0, \
        f"membership change dropped {dropped} requests on the transport"
    assert max(skew_samples) <= max_skew, \
        f"staleness {max(skew_samples)} exceeded the bound {max_skew}"
    print(f"# transport: {n_requests} steady + {len(futures)} churn "
          f"requests over 2->3->2 worker processes, 0 dropped, skew "
          f"bound {max_skew} held")


if __name__ == "__main__":
    import argparse
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small model + few requests (CI smoke)")
    ap.add_argument("--requests", type=int, default=512)
    args = ap.parse_args()
    # one host device per shard, set before jax initializes — shard
    # flushes then execute concurrently (see conftest note: this forcing
    # stays inside this process, never in the shared test env)
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4")
    main(n_requests=args.requests, smoke=args.smoke)

"""Ensemble serving plane (ISSUE 9 acceptance, both hard-asserted):

- the fused ensemble path (one engine micro-batch -> N per-model fused
  dispatches) must sustain >= 1.5x the throughput of serving the same
  requests as N sequential batch-1 member rounds with host-side fusion;
- on a labeled synthetic extreme-event stream, the EVT-weighted fused
  alert must match or beat the BEST single member on precision AND
  recall (error-steered weights crush the uninformative member, and
  averaging the independent members cancels noise).

Rows: ``ens/fused_engine`` / ``ens/sequential_members`` with the
``ens/speedup_vs_sequential`` headline, then ``ens/alert_member_*`` /
``ens/alert_fused`` precision-recall rows and ``ens/alert_gain``.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row
from repro.models.rnn import RNNConfig


def _precision_recall(p, labels, threshold=0.5):
    fired = p >= threshold
    tp = int(np.sum(fired & (labels == 1)))
    precision = tp / max(int(fired.sum()), 1)
    recall = tp / max(int((labels == 1).sum()), 1)
    return precision, recall


def main(n_requests: int = 256, smoke: bool = False) -> None:
    import jax

    from repro.serving import (BatcherConfig, EnsembleFuser, EnsembleSpec,
                               LSTMForecaster, ModelRegistry, ServingEngine,
                               Telemetry, fusion_weights)
    from repro.models.rnn import init_rnn

    if smoke:
        n_requests = min(n_requests, 128)

    # reduced paper config (2 LSTM + 3 FC, window 20) so the bench
    # isolates serving overhead, same as bench_serving
    cfg = RNNConfig(input_dim=5, hidden=32, num_layers=2, fc_dims=(16, 8),
                    window=20, evl_head=True)
    rng = np.random.default_rng(0)
    calib = rng.standard_normal((64, cfg.window, 5)).astype(np.float32) * 0.02
    members = {}
    for i, name in enumerate(("m1", "m2", "m3", "m4")):
        fc = LSTMForecaster(cfg=cfg,
                            params=init_rnn(jax.random.PRNGKey(i), cfg))
        fc.calibrate(calib)
        members[name] = fc
    reg = ModelRegistry()
    for name, fc in members.items():
        reg.register(name, fc)
    reg.register_ensemble("ens", list(members))
    n_members = len(members)

    windows = rng.standard_normal(
        (n_requests, cfg.window, 5)).astype(np.float32) * 0.02

    # -- fused path: the engine micro-batches ensemble requests, each
    # flush fanning out as exactly N per-model fused dispatches
    bcfg = BatcherConfig(max_batch=64, max_wait_ms=5.0,
                         length_buckets=(cfg.window,))
    with ServingEngine(reg, bcfg, telemetry=Telemetry()) as eng:
        eng.warmup("ens", lengths=(cfg.window,))
        # untimed priming wave: partial flushes during ramp-up hit batch
        # shapes warmup never saw, and one jit compile would dominate a
        # smoke-sized timed window
        for f in [eng.submit("ens", w) for w in windows[:64]]:
            f.result(timeout=120.0)
        t0 = time.perf_counter()
        futures = [eng.submit("ens", w) for w in windows]
        for f in futures:
            f.result(timeout=120.0)
        fused_rps = n_requests / (time.perf_counter() - t0)
    row("ens/fused_engine", 1e6 / max(fused_rps, 1e-9),
        f"rps={fused_rps:.0f};members={n_members}")

    # -- baseline: N sequential batch-1 member rounds per request, fused
    # on the host (the pre-ensemble serve loop a caller would write)
    errs = np.zeros((n_members,))
    for fc in members.values():            # steady state before timing
        fc.predict(windows[:1])
    t0 = time.perf_counter()
    for w in windows:
        ys, ps = [], []
        for fc in members.values():
            y, p = fc.predict(w[None])
            ys.append(float(np.asarray(y)[0]))
            ps.append(float(np.asarray(p)[0]))
        w_fuse = fusion_weights(np.ones((n_members,)), errs)
        _ = w_fuse @ np.asarray(ys), w_fuse @ np.asarray(ps)
    seq_rps = n_requests / (time.perf_counter() - t0)
    row("ens/sequential_members", 1e6 / max(seq_rps, 1e-9),
        f"rps={seq_rps:.0f};members={n_members}")

    speedup = fused_rps / max(seq_rps, 1e-9)
    ok = speedup >= 1.5
    row("ens/speedup_vs_sequential", 0.0,
        f"{speedup:.1f}x at {n_members} members"
        f"{' (>=1.5x OK)' if ok else ' (BELOW 1.5x)'}")
    assert ok, (
        f"fused ensemble {speedup:.2f}x vs {n_members}-sequential — "
        "the >=1.5x acceptance bar failed")

    # -- alert quality: labeled synthetic extreme stream ------------------
    # Two informative members with INDEPENDENT noise plus one
    # uninformative member. Online ground-truth errors steer the EVT
    # weights: the noise member is crushed, and averaging the two
    # informative members cancels noise neither can cancel alone — so
    # the fused alert beats the best single member on both axes.
    n_stream = 1500 if smoke else 4000
    srng = np.random.default_rng(7)
    labels = (srng.random(n_stream) < 0.08).astype(np.int64)
    signal = 0.15 + 0.55 * labels
    ps = np.stack([
        np.clip(signal + 0.30 * srng.standard_normal(n_stream), 0.0, 1.0),
        np.clip(signal + 0.30 * srng.standard_normal(n_stream), 0.0, 1.0),
        srng.random(n_stream),                   # uninformative member
    ])
    spec = EnsembleSpec(members=("a", "b", "noise"), temperature=0.05,
                        error_half_life=16)
    fuser = EnsembleFuser(ps.shape[0], spec)
    for t in range(n_stream):                    # online error tracking
        fuser.record_errors(np.abs(ps[:, t] - labels[t]))
    weights = fuser.weights()
    p_fused = weights @ ps

    best_precision = best_recall = 0.0
    for i, name in enumerate(spec.members):
        precision, recall = _precision_recall(ps[i], labels)
        best_precision = max(best_precision, precision)
        best_recall = max(best_recall, recall)
        row(f"ens/alert_member_{name}", 0.0,
            f"precision={precision:.3f};recall={recall:.3f};"
            f"weight={weights[i]:.3f}")
    precision, recall = _precision_recall(p_fused, labels)
    row("ens/alert_fused", 0.0,
        f"precision={precision:.3f};recall={recall:.3f}")
    ok = precision >= best_precision and recall >= best_recall
    row("ens/alert_gain", 0.0,
        f"precision {precision:.3f} vs best {best_precision:.3f}, "
        f"recall {recall:.3f} vs best {best_recall:.3f}"
        f"{' (fused >= best OK)' if ok else ' (FUSED BELOW BEST)'}")
    assert ok, (
        f"fused alert precision={precision:.3f}/recall={recall:.3f} did "
        f"not match the best member ({best_precision:.3f}/"
        f"{best_recall:.3f})")


if __name__ == "__main__":
    main()

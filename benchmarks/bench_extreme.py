"""Paper §IV.C task 1+3 — sensitivity study on imbalanced-data handling:
plain sliding windows vs extreme-oversampling vs EVL loss weighting, on
the stock task. Figures of merit: test MSE and extreme-event detection
(recall / F1 from the indicator head)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import row, stock_datasets, timed
from repro.extreme.resampling import (evl_sample_weights,
                                      oversample_extreme_windows)
from repro.training.loop import train_rnn_serial

ITERS = 1500


def main() -> None:
    train_ds, test_ds = stock_datasets("AAPL")
    rng = np.random.default_rng(0)

    # 1) plain sliding windows (risk: underfit on extremes)
    res, us = timed(train_rnn_serial, train_ds, test_ds, iterations=ITERS,
                    batch=32, evl_weight=0.5, repeat=1)
    row("extreme/plain", us,
        f"mse={res.test_mse:.5f};recall={res.test_extreme['recall']:.2f};"
        f"f1={res.test_extreme['f1']:.2f}")

    # 2) oversampled extremes (the paper's "duplicate" trick; risk: overfit)
    # implemented as per-sample weights proportional to duplication
    idx = oversample_extreme_windows(train_ds.returns, train_ds.eps1,
                                     train_ds.eps2, target_fraction=0.3,
                                     rng=rng)
    counts = np.bincount(idx, minlength=len(train_ds)).astype(np.float32)
    w_over = counts / max(counts.mean(), 1e-9)
    res, us = timed(train_rnn_serial, train_ds, test_ds, iterations=ITERS,
                    batch=32, evl_weight=0.5, weights=w_over, repeat=1)
    row("extreme/oversample", us,
        f"mse={res.test_mse:.5f};recall={res.test_extreme['recall']:.2f};"
        f"f1={res.test_extreme['f1']:.2f}")

    # 3) EVL-style per-sample loss weights (no resampling)
    w_evl = evl_sample_weights(train_ds.returns, train_ds.eps1,
                               train_ds.eps2)
    res, us = timed(train_rnn_serial, train_ds, test_ds, iterations=ITERS,
                    batch=32, evl_weight=0.5, weights=w_evl, repeat=1)
    row("extreme/evl_weighted", us,
        f"mse={res.test_mse:.5f};recall={res.test_extreme['recall']:.2f};"
        f"f1={res.test_extreme['f1']:.2f}")


if __name__ == "__main__":
    main()

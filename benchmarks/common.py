"""Shared benchmark utilities. Output format: ``name,us_per_call,derived``
CSV rows (one per measurement), where ``derived`` carries the
benchmark-specific figure of merit (MSE, speedup, rounds, ...).

Every ``row`` is also collected in memory so the harness
(``benchmarks/run.py --json``) can persist each suite's phases to
``BENCH_<suite>.json`` — the machine-readable perf trajectory carried
across PRs as a CI artifact."""

from __future__ import annotations

import time

_ROWS: list[dict] = []


def row(name: str, us_per_call: float, derived) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append({"name": name, "us": round(float(us_per_call), 1),
                  "metric": str(derived)})
    print(line, flush=True)
    return line


def drain_rows() -> list[dict]:
    """Return and clear the rows collected since the last drain (the
    harness calls this at suite boundaries)."""
    global _ROWS
    rows, _ROWS = _ROWS, []
    return rows


def timed(fn, *args, repeat: int = 3, **kw):
    """Return (result, us_per_call) — best of ``repeat``."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def stock_datasets(ticker: str = "AAPL", n_days: int = 1430):
    from repro.data import load_stock, make_windows, train_test_split
    ohlcv = load_stock(ticker, n_days=n_days)
    tr, te = train_test_split(ohlcv)
    return make_windows(tr), make_windows(te)

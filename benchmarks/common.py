"""Shared benchmark utilities. Output format: ``name,us_per_call,derived``
CSV rows (one per measurement), where ``derived`` carries the
benchmark-specific figure of merit (MSE, speedup, rounds, ...)."""

from __future__ import annotations

import time


def row(name: str, us_per_call: float, derived) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line


def timed(fn, *args, repeat: int = 3, **kw):
    """Return (result, us_per_call) — best of ``repeat``."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def stock_datasets(ticker: str = "AAPL", n_days: int = 1430):
    from repro.data import load_stock, make_windows, train_test_split
    ohlcv = load_stock(ticker, n_days=n_days)
    tr, te = train_test_split(ohlcv)
    return make_windows(tr), make_windows(te)

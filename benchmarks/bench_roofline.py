"""Roofline report: reads the dry-run JSONL (results/dryrun_*.jsonl,
produced by ``python -m repro.launch.dryrun --out ...``) and prints the
per-(arch x shape x mesh) roofline terms. The dry-run itself is too heavy
to run inside the benchmark harness (80 x multi-minute XLA compiles); run
it via the module CLI and this bench formats/validates the results."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

RESULTS_GLOB = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun*.jsonl")


def load_records() -> list[dict]:
    recs: dict[tuple, dict] = {}
    for path in sorted(glob.glob(RESULTS_GLOB)):
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                recs[(r["arch"], r["shape"], r["mesh"])] = r  # last wins
    return list(recs.values())


def main() -> None:
    recs = load_records()
    if not recs:
        row("roofline/missing", 0.0,
            "run: PYTHONPATH=src python -m repro.launch.dryrun --out "
            "results/dryrun.jsonl")
        return
    ok = skip = fail = 0
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] == "skip":
            skip += 1
            row(name, 0.0, "skip=" + r.get("skip_reason", "?")[:60])
            continue
        if r["status"] != "ok":
            fail += 1
            row(name, 0.0, "FAIL=" + r.get("error", "?")[:80])
            continue
        ok += 1
        rf = r["roofline"]
        m = r["memory"]
        row(name, float(r.get("compile_s", 0)) * 1e6,
            f"dominant={rf['dominant']};compute_ms={rf['compute_s']*1e3:.2f};"
            f"memory_ms={rf['memory_s']*1e3:.2f};"
            f"collective_ms={rf['collective_s']*1e3:.2f};"
            f"useful={rf['useful_ratio']:.2f};"
            f"peak_GiB={m['peak_bytes']/2**30:.2f}")
    row("roofline/summary", 0.0, f"ok={ok};skip={skip};fail={fail}")


if __name__ == "__main__":
    main()

"""Serving throughput/latency across micro-batcher settings (ISSUE 1
acceptance: the dynamic batcher must sustain >= 5x the throughput of
batch-size-1 serving on the reduced paper LSTM config), plus the
multi-session DECODE phase (ISSUE 5 acceptance: the batched decode path
must sustain >= 2x the streaming-step throughput of the per-session
dispatch loop at >= 8 concurrent sessions — hard-asserted under
``--smoke``), plus the SLOTS phase (ISSUE 8 acceptance: device-resident
decode slots must sustain >= 1.5x the gather/scatter steady-state step
throughput at >= 32 resident sessions, with dispatch counting proving
zero host gather/scatter — both hard-asserted under ``--smoke``).

Rows: ``serve/<config>,us_per_request,rps=..;p95_ms=..;occ=..``, a
``serve/speedup_vs_batch1`` row with the headline multiple,
``serve/decode_*`` rows for the streaming phase with
``serve/decode_speedup_vs_loop``, and ``serve/slots_*`` rows with
``serve/slots_speedup_vs_gather`` for the slot-resident phase.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row
from repro.models.rnn import RNNConfig


def _configs(window: int):
    from repro.serving import BatcherConfig
    buckets = (window,)          # exact-length bucket: no padding waste
    return [
        ("batch1", BatcherConfig(max_batch=1, max_wait_ms=0.0,
                                 length_buckets=buckets)),
        ("micro8_w2ms", BatcherConfig(max_batch=8, max_wait_ms=2.0,
                                      length_buckets=buckets)),
        ("micro32_w2ms", BatcherConfig(max_batch=32, max_wait_ms=2.0,
                                       length_buckets=buckets)),
        ("micro64_w5ms", BatcherConfig(max_batch=64, max_wait_ms=5.0,
                                       length_buckets=buckets)),
    ]


def main(n_requests: int = 512, smoke: bool = False) -> None:
    import jax

    from repro.models.rnn import init_rnn
    from repro.serving import (LSTMForecaster, ModelRegistry,
                               RecurrentSessionRunner, ServingEngine,
                               SessionCache, Telemetry)

    if smoke:
        n_requests = min(n_requests, 128)

    # reduced paper config: same topology (2 LSTM + 3 FC, window 20),
    # smaller widths so the bench isolates serving overhead
    cfg = RNNConfig(input_dim=5, hidden=32, num_layers=2, fc_dims=(16, 8),
                    window=20, evl_head=True)
    fc = LSTMForecaster(cfg=cfg, params=init_rnn(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    fc.calibrate(rng.standard_normal((64, cfg.window, 5)).astype(np.float32)
                 * 0.02)
    reg = ModelRegistry()
    reg.register("m", fc)

    windows = rng.standard_normal(
        (n_requests, cfg.window, 5)).astype(np.float32) * 0.02
    rps = {}
    configs = _configs(cfg.window)
    if smoke:
        configs = [c for c in configs if c[0] in ("batch1", "micro32_w2ms")]
    for name, bcfg in configs:
        with ServingEngine(reg, bcfg, telemetry=Telemetry()) as eng:
            eng.warmup("m", lengths=(cfg.window,))
            eng.telemetry.reset_clock()
            futures = [eng.submit("m", w) for w in windows]
            for f in futures:
                f.result(timeout=120.0)
            snap = eng.telemetry.snapshot()
        rps[name] = snap["throughput_rps"]
        row(f"serve/{name}", 1e6 / max(snap["throughput_rps"], 1e-9),
            f"rps={snap['throughput_rps']:.0f};p95_ms={snap['p95_ms']:.2f};"
            f"occ={snap['batch_occupancy']:.2f}")

    best = max(v for k, v in rps.items() if k != "batch1")
    speedup = best / max(rps["batch1"], 1e-9)
    row("serve/speedup_vs_batch1", 0.0,
        f"{speedup:.1f}x{' (>=5x OK)' if speedup >= 5.0 else ' (BELOW 5x)'}")

    # -- decode phase: multi-session streaming steps -----------------------
    # N resident sessions each advance one observation per tick. The
    # baseline dispatches one fused step per session per tick (the
    # pre-batched hot path); the batched decode path gathers all N
    # carries and flushes each tick as ceil(N/decode_width) fused
    # dispatches. Identical math, bitwise-equal outputs (tested in
    # tests/) — this phase measures only the dispatch amortization.
    n_sessions = 8 if smoke else 64
    n_ticks = 25 if smoke else 100
    xs = rng.standard_normal(
        (n_ticks, n_sessions, 5)).astype(np.float32) * 0.02
    fc.warm_decode()

    # num_slots=0 pins both baselines to the pre-slots paths (per-session
    # dispatch loop, then cache gather -> fused step -> scatter) so the
    # decode rows stay comparable across the bench trajectory
    def _loop_phase():
        runner = RecurrentSessionRunner(
            fc, SessionCache(max_sessions=n_sessions), num_slots=0)
        t0 = time.perf_counter()
        for t in range(n_ticks):
            for s in range(n_sessions):
                runner.step(f"s{s}", xs[t, s])
        return n_ticks * n_sessions / (time.perf_counter() - t0)

    def _batched_phase():
        runner = RecurrentSessionRunner(
            fc, SessionCache(max_sessions=n_sessions), num_slots=0)
        t0 = time.perf_counter()
        for t in range(n_ticks):
            runner.step_many([(f"s{s}", xs[t, s], None)
                              for s in range(n_sessions)])
        return n_ticks * n_sessions / (time.perf_counter() - t0)

    loop_sps = _loop_phase()
    batched_sps = _batched_phase()
    row("serve/decode_per_session_loop", 1e6 / max(loop_sps, 1e-9),
        f"steps_per_s={loop_sps:.0f};sessions={n_sessions}")
    row("serve/decode_batched", 1e6 / max(batched_sps, 1e-9),
        f"steps_per_s={batched_sps:.0f};sessions={n_sessions};"
        f"width={fc.decode_width}")

    # and end-to-end through the engine's step flush grouping: every
    # tick is submitted without waiting (the flush waves keep one
    # client's steps ordered even when several ticks share a flush), so
    # this measures pipelined streaming throughput, not max_wait
    bcfg = next(c for n, c in _configs(cfg.window) if n == "micro64_w5ms")
    with ServingEngine(reg, bcfg, telemetry=Telemetry()) as eng:
        eng.warmup("m", lengths=(cfg.window,))
        eng.telemetry.reset_clock()
        t0 = time.perf_counter()
        futs = [eng.submit_step("m", f"s{s}", xs[t, s])
                for t in range(n_ticks) for s in range(n_sessions)]
        for f in futs:
            f.result(timeout=60.0)
        engine_sps = n_ticks * n_sessions / (time.perf_counter() - t0)
        snap = eng.telemetry.snapshot()
    row("serve/decode_engine", 1e6 / max(engine_sps, 1e-9),
        f"steps_per_s={engine_sps:.0f};flushes={snap['step_batches']};"
        f"mean_step_batch={snap['mean_step_batch']:.1f};"
        f"step_p95_ms={snap['step_p95_ms']:.2f}")

    decode_speedup = batched_sps / max(loop_sps, 1e-9)
    ok = decode_speedup >= 2.0
    row("serve/decode_speedup_vs_loop", 0.0,
        f"{decode_speedup:.1f}x at {n_sessions} sessions"
        f"{' (>=2x OK)' if ok else ' (BELOW 2x)'}")
    if smoke:
        assert ok, (
            f"batched decode {decode_speedup:.2f}x at {n_sessions} "
            f"sessions — the >=2x acceptance bar failed")

    # -- slots phase: device-resident lanes vs gather/scatter --------------
    # Steady state: every session already occupies a device lane, so a
    # flush is ONE fused slots_generate dispatch — no per-tick carry
    # gather from the cache, no scatter back. The gather/scatter runner
    # (num_slots=0) pays the host round-trip every tick. Same math,
    # bitwise-equal outputs (tested in tests/); dispatch counting proves
    # the zero-gather/scatter claim rather than asserting it by eye.
    from repro.kernels import dispatch

    n_slot_sessions = 32 if smoke else 64     # acceptance floor is 32
    n_slot_ticks = 25 if smoke else 100
    sxs = rng.standard_normal(
        (n_slot_ticks + 1, n_slot_sessions, 5)).astype(np.float32) * 0.02

    def _gather_phase():
        runner = RecurrentSessionRunner(
            fc, SessionCache(max_sessions=n_slot_sessions), num_slots=0)
        runner.step_many([(f"s{s}", sxs[0, s], None)
                          for s in range(n_slot_sessions)])   # warm
        t0 = time.perf_counter()
        for t in range(1, n_slot_ticks + 1):
            runner.step_many([(f"s{s}", sxs[t, s], None)
                              for s in range(n_slot_sessions)])
        return n_slot_ticks * n_slot_sessions / (time.perf_counter() - t0)

    def _slots_phase():
        runner = RecurrentSessionRunner(
            fc, SessionCache(max_sessions=n_slot_sessions),
            num_slots=n_slot_sessions)
        # first tick makes every session lane-resident (prefill+insert)
        runner.step_many([(f"s{s}", sxs[0, s], None)
                          for s in range(n_slot_sessions)])
        with dispatch.counting() as counts:
            t0 = time.perf_counter()
            for t in range(1, n_slot_ticks + 1):
                runner.step_many([(f"s{s}", sxs[t, s], None)
                                  for s in range(n_slot_sessions)])
            sps = n_slot_ticks * n_slot_sessions / (time.perf_counter() - t0)
        return sps, counts

    gather_sps = _gather_phase()
    slots_sps, counts = _slots_phase()
    clean = (counts["slots_generate"] == n_slot_ticks
             and counts["decode_many"] == 0 and counts["decode_step"] == 0
             and counts["slots_insert"] == 0
             and counts["decode_replay"] == 0)
    row("serve/slots_gather_scatter", 1e6 / max(gather_sps, 1e-9),
        f"steps_per_s={gather_sps:.0f};sessions={n_slot_sessions}")
    row("serve/slots_resident", 1e6 / max(slots_sps, 1e-9),
        f"steps_per_s={slots_sps:.0f};sessions={n_slot_sessions};"
        f"generate_dispatches={counts['slots_generate']};"
        f"gather_scatter_dispatches="
        f"{counts['decode_many'] + counts['decode_step']}")
    slots_speedup = slots_sps / max(gather_sps, 1e-9)
    sok = slots_speedup >= 1.5
    row("serve/slots_speedup_vs_gather", 0.0,
        f"{slots_speedup:.1f}x at {n_slot_sessions} resident sessions"
        f"{' (>=1.5x OK)' if sok else ' (BELOW 1.5x)'}"
        f"{';steady_state_clean' if clean else ';DISPATCH LEAK'}")
    if smoke:
        assert clean, (
            f"slots steady state leaked host gather/scatter dispatches: "
            f"{dict(counts)} over {n_slot_ticks} flushes")
        assert sok, (
            f"slot-resident decode {slots_speedup:.2f}x at "
            f"{n_slot_sessions} sessions — the >=1.5x acceptance bar "
            f"failed")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced workload + hard decode/slots asserts")
    ap.add_argument("--requests", type=int, default=512)
    args = ap.parse_args()
    main(n_requests=args.requests, smoke=args.smoke)

"""Serving throughput/latency across micro-batcher settings (ISSUE 1
acceptance: the dynamic batcher must sustain >= 5x the throughput of
batch-size-1 serving on the reduced paper LSTM config).

Rows: ``serve/<config>,us_per_request,rps=..;p95_ms=..;occ=..`` plus a
final ``serve/speedup_vs_batch1`` row with the headline multiple.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.models.rnn import RNNConfig


def _configs(window: int):
    from repro.serving import BatcherConfig
    buckets = (window,)          # exact-length bucket: no padding waste
    return [
        ("batch1", BatcherConfig(max_batch=1, max_wait_ms=0.0,
                                 length_buckets=buckets)),
        ("micro8_w2ms", BatcherConfig(max_batch=8, max_wait_ms=2.0,
                                      length_buckets=buckets)),
        ("micro32_w2ms", BatcherConfig(max_batch=32, max_wait_ms=2.0,
                                       length_buckets=buckets)),
        ("micro64_w5ms", BatcherConfig(max_batch=64, max_wait_ms=5.0,
                                       length_buckets=buckets)),
    ]


def main(n_requests: int = 512) -> None:
    import jax

    from repro.models.rnn import init_rnn
    from repro.serving import (LSTMForecaster, ModelRegistry, ServingEngine,
                               Telemetry)

    # reduced paper config: same topology (2 LSTM + 3 FC, window 20),
    # smaller widths so the bench isolates serving overhead
    cfg = RNNConfig(input_dim=5, hidden=32, num_layers=2, fc_dims=(16, 8),
                    window=20, evl_head=True)
    fc = LSTMForecaster(cfg=cfg, params=init_rnn(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    fc.calibrate(rng.standard_normal((64, cfg.window, 5)).astype(np.float32)
                 * 0.02)
    reg = ModelRegistry()
    reg.register("m", fc)

    windows = rng.standard_normal(
        (n_requests, cfg.window, 5)).astype(np.float32) * 0.02
    rps = {}
    for name, bcfg in _configs(cfg.window):
        with ServingEngine(reg, bcfg, telemetry=Telemetry()) as eng:
            eng.warmup("m", lengths=(cfg.window,))
            eng.telemetry.reset_clock()
            futures = [eng.submit("m", w) for w in windows]
            for f in futures:
                f.result(timeout=120.0)
            snap = eng.telemetry.snapshot()
        rps[name] = snap["throughput_rps"]
        row(f"serve/{name}", 1e6 / max(snap["throughput_rps"], 1e-9),
            f"rps={snap['throughput_rps']:.0f};p95_ms={snap['p95_ms']:.2f};"
            f"occ={snap['batch_occupancy']:.2f}")

    best = max(v for k, v in rps.items() if k != "batch1")
    speedup = best / max(rps["batch1"], 1e-9)
    row("serve/speedup_vs_batch1", 0.0,
        f"{speedup:.1f}x{' (>=5x OK)' if speedup >= 5.0 else ' (BELOW 5x)'}")


if __name__ == "__main__":
    main()

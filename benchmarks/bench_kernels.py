"""Kernel micro-benchmarks: Pallas (interpret mode on CPU — correctness
path) vs the pure-jnp reference, at paper-relevant shapes. On-CPU wall
time is NOT a TPU performance claim; the derived column carries the
allclose max-error vs the oracle, which is the meaningful number here.

The ``dispatch`` phase re-measures the two LSTM-cell implementations
across a (batch, hidden) grid on the CURRENT backend and emits the
winner per shape — the measurements behind the default table in
``repro.kernels.dispatch``. ``--tune-out PATH`` persists the measured
rules as a table JSON (point ``REPRO_DISPATCH_TABLE`` at it, or
``dispatch.load_table`` it, to serve from the re-tuned table)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed

RNG = np.random.default_rng(0)


def _tune_dispatch(smoke: bool, tune_out: str | None) -> None:
    from repro.kernels import dispatch
    from repro.kernels.lstm.ops import lstm_cell_fused as pallas_cell
    from repro.kernels.lstm.ref import lstm_cell_ref

    backend = jax.default_backend()
    grid = [(8, 64)] if smoke else [(1, 64), (8, 64), (32, 64), (8, 128)]
    xla_cell = jax.jit(lstm_cell_ref)
    rules = []
    for B, H in grid:
        I = 5
        x = jnp.asarray(RNG.standard_normal((B, I)).astype(np.float32))
        h = jnp.asarray(RNG.standard_normal((B, H)).astype(np.float32))
        c = jnp.asarray(RNG.standard_normal((B, H)).astype(np.float32))
        wx = jnp.asarray(0.1 * RNG.standard_normal((I, 4 * H)), jnp.float32)
        wh = jnp.asarray(0.1 * RNG.standard_normal((H, 4 * H)), jnp.float32)
        b = jnp.asarray(0.1 * RNG.standard_normal(4 * H), jnp.float32)
        _, us_xla = timed(lambda: jax.block_until_ready(
            xla_cell(x, h, c, wx, wh, b)))
        _, us_pal = timed(lambda: jax.block_until_ready(
            pallas_cell(x, h, c, wx, wh, b)))
        compiled = backend == "tpu"     # elsewhere the kernel interprets
        winner = "pallas" if us_pal < us_xla and compiled else "xla"
        row(f"kernels/dispatch_b{B}_h{H}", min(us_xla, us_pal),
            f"xla_us={us_xla:.1f};"
            f"pallas_us={us_pal:.1f}{'' if compiled else '(interpret)'};"
            f"winner={winner};backend={backend}")
        if winner == "pallas":
            rules.append({"min_batch": B, "min_hidden": H,
                          "impl": "pallas"})
    if tune_out:
        # keep only the weakest floor per impl: rules are monotone
        if rules:
            rules = [min(rules, key=lambda r: (r["min_batch"],
                                               r["min_hidden"]))]
        dispatch.set_rules("lstm_cell", backend, rules)
        dispatch.save_table(tune_out)
        print(f"# wrote dispatch table for backend={backend} "
              f"-> {tune_out}", flush=True)


def main(smoke: bool = False, tune_out: str | None = None) -> None:
    # LSTM cell at the paper's model size
    B, I, H = 32, 5, 64
    x = jnp.asarray(RNG.standard_normal((B, I)).astype(np.float32))
    h = jnp.asarray(RNG.standard_normal((B, H)).astype(np.float32))
    c = jnp.asarray(RNG.standard_normal((B, H)).astype(np.float32))
    wx = jnp.asarray(0.1 * RNG.standard_normal((I, 4 * H)), jnp.float32)
    wh = jnp.asarray(0.1 * RNG.standard_normal((H, 4 * H)), jnp.float32)
    b = jnp.asarray(0.1 * RNG.standard_normal(4 * H), jnp.float32)
    from repro.kernels.lstm.ops import lstm_cell_fused
    from repro.kernels.lstm.ref import lstm_cell_ref
    (hn, _), us = timed(lambda: jax.block_until_ready(
        lstm_cell_fused(x, h, c, wx, wh, b)))
    hr, _ = lstm_cell_ref(x, h, c, wx, wh, b)
    err = float(jnp.max(jnp.abs(hn - hr)))
    row("kernels/lstm_cell_32x64", us, f"max_err={err:.2e}")

    # EVL at epoch size
    n = 16384
    u = jnp.asarray(RNG.uniform(0.01, 0.99, n).astype(np.float32))
    v = jnp.asarray((RNG.uniform(size=n) < 0.05).astype(np.float32))
    from repro.kernels.evl.ops import evl_loss_fused
    from repro.kernels.evl.ref import evl_loss_ref
    got, us = timed(lambda: jax.block_until_ready(
        evl_loss_fused(u, v, 0.95, 0.05, 2.0, reduce="none")))
    err = float(jnp.max(jnp.abs(got - evl_loss_ref(u, v, 0.95, 0.05, 2.0))))
    row("kernels/evl_16k", us, f"max_err={err:.2e}")

    # flash attention, prefill-like tile
    Bq, S, Hq, Hkv, D = 1, 512, 8, 2, 64
    q = jnp.asarray(RNG.standard_normal((Bq, S, Hq, D)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((Bq, S, Hkv, D)).astype(np.float32))
    vv = jnp.asarray(RNG.standard_normal((Bq, S, Hkv, D)).astype(np.float32))
    from repro.kernels.attention.ops import flash_attention
    from repro.kernels.attention.ref import attention_ref
    got, us = timed(lambda: jax.block_until_ready(
        flash_attention(q, k, vv, causal=True)))
    err = float(jnp.max(jnp.abs(got - attention_ref(q, k, vv, causal=True))))
    row("kernels/flash_attn_512", us, f"max_err={err:.2e}")

    # SSD chunk scan, mamba2-370m-like head
    B2, L, H2, P, N = 2, 256, 4, 64, 32
    xd = jnp.asarray(0.1 * RNG.standard_normal((B2, L, H2, P)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.01, 0.5, (B2, L, H2)), jnp.float32)
    B_ = jnp.asarray(0.3 * RNG.standard_normal((B2, L, N)), jnp.float32)
    C_ = jnp.asarray(0.3 * RNG.standard_normal((B2, L, N)), jnp.float32)
    from repro.kernels.ssd.ops import ssd_scan_fused
    from repro.models.ssm import ssd_chunked
    (y1, _), us = timed(lambda: jax.block_until_ready(
        ssd_scan_fused(xd, a, B_, C_, chunk=64)))
    y2, _ = ssd_chunked(xd, a, B_, C_, chunk=64)
    err = float(jnp.max(jnp.abs(y1 - y2)))
    row("kernels/ssd_256", us, f"max_err={err:.2e}")

    # Pallas-vs-XLA dispatch measurements (backend-local)
    _tune_dispatch(smoke, tune_out)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced dispatch-tune grid (CI smoke)")
    ap.add_argument("--tune-out", default=None, metavar="PATH",
                    help="write the measured dispatch table JSON here")
    args = ap.parse_args()
    main(smoke=args.smoke, tune_out=args.tune_out)

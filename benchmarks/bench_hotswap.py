"""Serving behavior during weight updates (ISSUE 2 acceptance): p99
latency and dropped-request count under a swap storm, hot-swap vs a
stop-the-world reload baseline.

Three phases over the same traffic generator:
  steady     — no weight updates (the latency floor);
  hotswap    — a publisher thread swaps weights every few ms while
               traffic flows: zero drops required, p99 within 2x steady;
  stopworld  — the engine is halted around each weight update: submits
               in the stopped window are dropped, and latency spikes are
               unbounded by design.

Rows: ``hotswap/<phase>,us_per_request,p99_ms=..;dropped=..;swaps=..``
plus ``hotswap/p99_ratio_vs_steady`` with the acceptance figure.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import row
from repro.models.rnn import RNNConfig


def _percentile(xs: list[float], p: float) -> float:
    if not xs:
        return 0.0
    data = sorted(xs)
    k = min(len(data) - 1, max(0, int(round(p / 100.0 * (len(data) - 1)))))
    return data[k]


def _run_phase(engine, key, windows, n_requests: int, swap_fn=None,
               swap_interval_s: float = 0.003):
    """Serve ``n_requests``; optionally run ``swap_fn`` on a side thread
    every ``swap_interval_s``. Returns (latencies_s, dropped, swaps)."""
    stop = threading.Event()
    swaps = [0]

    def swapper() -> None:
        while not stop.is_set():
            swap_fn()
            swaps[0] += 1
            time.sleep(swap_interval_s)

    thread = None
    if swap_fn is not None:
        thread = threading.Thread(target=swapper, name="bench-swapper")
        thread.start()
    latencies: list[float] = []
    dropped = 0
    try:
        for i in range(n_requests):
            t0 = time.perf_counter()
            try:
                engine.predict(key, windows[i % len(windows)], timeout=30.0)
            except RuntimeError:
                dropped += 1       # submit refused: engine stopped
                # mid-reload — precisely what hot swap eliminates
                time.sleep(5e-4)   # client pause before the next attempt
                continue
            latencies.append(time.perf_counter() - t0)
    finally:
        stop.set()
        if thread is not None:
            thread.join()
    return latencies, dropped, swaps[0]


def main(n_requests: int = 400, smoke: bool = False) -> None:
    import jax

    from repro.models.rnn import init_rnn
    from repro.serving import (BatcherConfig, LSTMForecaster, ModelRegistry,
                               ServingEngine, WeightPublisher,
                               stop_the_world_swap)

    if smoke:
        n_requests = min(n_requests, 80)
    cfg = RNNConfig(input_dim=5, hidden=32, num_layers=2, fc_dims=(16, 8),
                    window=20, evl_head=True)
    fc0 = LSTMForecaster(cfg=cfg, params=init_rnn(jax.random.PRNGKey(0),
                                                  cfg))
    rng = np.random.default_rng(0)
    fc0.calibrate(rng.standard_normal((64, cfg.window, 5)).astype(np.float32)
                  * 0.02)
    reg = ModelRegistry()
    reg.register("m", fc0)
    variants = [jax.tree.map(lambda a, s=s: a * s, fc0.params)
                for s in (1.0, 1.05, 0.95)]
    windows = rng.standard_normal((64, cfg.window, 5)).astype(np.float32) \
        * 0.02

    engine = ServingEngine(reg, BatcherConfig(
        max_batch=8, max_wait_ms=1.0, length_buckets=(cfg.window,)))
    publisher = WeightPublisher(reg, "m", template=fc0,
                                telemetry=engine.telemetry)
    counter = [0]

    def hot_swap() -> None:
        counter[0] += 1
        publisher.publish(variants[counter[0] % len(variants)])

    def stop_world() -> None:
        counter[0] += 1
        stop_the_world_swap(
            engine, reg, "m",
            fc0.with_params(variants[counter[0] % len(variants)]),
            reload_s=0.005)        # modest simulated checkpoint reload

    results = {}
    with engine:
        engine.warmup("m", lengths=(cfg.window,))
        for phase, swap_fn, interval in (
                ("steady", None, 0.0),
                ("hotswap", hot_swap, 0.003),
                ("stopworld", stop_world, 0.02)):
            engine.telemetry.reset_clock()
            lat, dropped, swaps = _run_phase(engine, "m", windows,
                                             n_requests, swap_fn,
                                             swap_interval_s=interval)
            results[phase] = (lat, dropped, swaps)
            us = (np.mean(lat) * 1e6) if lat else float("inf")
            row(f"hotswap/{phase}", us,
                f"p99_ms={_percentile(lat, 99) * 1e3:.2f};"
                f"dropped={dropped};swaps={swaps}")

    steady_p99 = _percentile(results["steady"][0], 99)
    hot_p99 = _percentile(results["hotswap"][0], 99)
    ratio = hot_p99 / max(steady_p99, 1e-9)
    # smoke runs report the ratio without the accept gate: percentiles
    # over ~80 requests on a loaded CI box are too noisy to gate on
    row("hotswap/p99_ratio_vs_steady", hot_p99 * 1e6,
        f"ratio={ratio:.2f}"
        + ("" if smoke else
           f";accept={'PASS' if ratio <= 2.0 else 'FAIL'}"))
    assert results["hotswap"][1] == 0, \
        f"hot swap dropped {results['hotswap'][1]} requests"
    print(f"# hot swap: {results['hotswap'][2]} swaps, 0 dropped, p99 "
          f"{ratio:.2f}x steady | stop-the-world: "
          f"{results['stopworld'][2]} reloads dropped "
          f"{results['stopworld'][1]} requests")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced request count (CI smoke)")
    ap.add_argument("--requests", type=int, default=400)
    args = ap.parse_args()
    main(n_requests=args.requests, smoke=args.smoke)

"""Observability-plane cost (ISSUE 6 acceptance: serving with request
tracing ON must sustain >= 95% of the tracing-OFF throughput — the
tracer is designed for always-on production use, so its overhead is
measured, hard-asserted under ``--smoke``, and carried in the perf
trajectory).

Rows: ``obs/serve_traced`` vs ``obs/serve_untraced`` with the headline
``obs/tracing_overhead`` percentage (interleaved rounds, best-of each,
so machine noise hits both modes alike), ``obs/span_mark`` (raw cost of
one span record), ``obs/dispatch_counting`` (the accounting context
around a decode workload, with the fused-dispatch invariant checked),
and ``obs/render_prometheus`` / ``obs/event_log`` (export-path costs —
per scrape and per event, both off the serving hot path).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row, timed
from repro.models.rnn import RNNConfig


def main(n_requests: int = 512, smoke: bool = False) -> None:
    import jax

    from repro.kernels import dispatch
    from repro.models.rnn import init_rnn
    from repro.obs import EventLog, Tracer, render_prometheus
    from repro.serving import (BatcherConfig, LSTMForecaster, ModelRegistry,
                               ServingEngine, Telemetry)

    if smoke:
        # still long enough per round (~20ms) that multi-ms interference
        # bursts average out instead of deciding a whole round
        n_requests = min(n_requests, 256)

    # reduced paper config, same as bench_serving: the overhead figure
    # must be relative to the throughput the serving bench reports
    cfg = RNNConfig(input_dim=5, hidden=32, num_layers=2, fc_dims=(16, 8),
                    window=20, evl_head=True)
    fc = LSTMForecaster(cfg=cfg, params=init_rnn(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    fc.calibrate(rng.standard_normal((64, cfg.window, 5)).astype(np.float32)
                 * 0.02)
    reg = ModelRegistry()
    reg.register("m", fc)
    bcfg = BatcherConfig(max_batch=32, max_wait_ms=2.0,
                         length_buckets=(cfg.window,))
    windows = rng.standard_normal(
        (n_requests, cfg.window, 5)).astype(np.float32) * 0.02

    # -- tracing overhead: paired traced/untraced rounds -------------------
    # ONE engine, warmed once, with the tracer toggled between rounds:
    # both modes run the identical compiled programs on the identical
    # queue/flush machinery, so the delta isolates the tracer. The
    # tracer's per-request cost (~2-3us) is an order of magnitude below
    # the round-to-round machine noise on a shared box, so the headline
    # is the MEDIAN of per-pair ratios: each off/on pair runs
    # back-to-back (shared conditions; drift cancels within a pair) and
    # the median discards the pairs a noise burst landed inside. GC is
    # held during the timed region so collections triggered by one
    # mode's allocations cannot bill the other mode's round.
    import gc

    def _round(eng, tracer) -> float:
        eng.tracer = tracer
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter()
            futures = [eng.submit("m", w) for w in windows]
            for f in futures:
                f.result(timeout=120.0)
            return len(futures) / (time.perf_counter() - t0)
        finally:
            gc.enable()

    rps_off = rps_on = 0.0
    with ServingEngine(reg, bcfg, telemetry=Telemetry()) as eng:
        eng.warmup("m", lengths=(cfg.window,))
        _round(eng, None)                  # one shakeout round, discarded
        # up to two measurement sets: a background burst spanning most
        # of a set can push its median over the bound, so a failing
        # first set gets ONE clean re-measure before the verdict
        for _attempt in range(2):
            ratios = []
            for _ in range(7 if smoke else 9):
                off = _round(eng, None)
                on = _round(eng, Tracer(capacity=256))
                rps_off, rps_on = max(rps_off, off), max(rps_on, on)
                ratios.append(on / off)
            ratios.sort()
            overhead_pct = (1.0 - ratios[len(ratios) // 2]) * 100.0
            if overhead_pct <= 5.0:
                break
    row("obs/serve_untraced", 1e6 / max(rps_off, 1e-9),
        f"rps={rps_off:.0f}")
    row("obs/serve_traced", 1e6 / max(rps_on, 1e-9),
        f"rps={rps_on:.0f}")
    ok = overhead_pct <= 5.0
    row("obs/tracing_overhead", 0.0,
        f"{overhead_pct:+.1f}%{' (<=5% OK)' if ok else ' (ABOVE 5%)'}")
    if smoke:
        assert ok, (f"tracing overhead {overhead_pct:.1f}% exceeds the 5% "
                    f"bound ({rps_off:.0f} rps off vs {rps_on:.0f} rps on)")

    # -- raw span cost: one start + 7 marks + finish, like one request -----
    tracer = Tracer(capacity=256)
    names = ("submit", "queue", "gather", "flush", "dispatch", "scatter",
             "reply")

    def _trace_once(n: int = 1000):
        for _ in range(n):
            ctx = tracer.start("predict")
            for name in names:
                ctx.mark(name)
            ctx.finish()

    _, us = timed(_trace_once)
    row("obs/span_mark", us / 1000 / (len(names) + 2),
        f"spans_per_request={len(names)}")

    # -- dispatch accounting around a decode workload ----------------------
    n_sessions, n_ticks = (8, 10) if smoke else (32, 25)
    xs = rng.standard_normal(
        (n_ticks, n_sessions, 5)).astype(np.float32) * 0.02
    fc.warm_decode()
    with ServingEngine(reg, bcfg, telemetry=Telemetry()) as eng:
        eng.warmup("m", lengths=(cfg.window,))
        with dispatch.counting() as counts:
            t0 = time.perf_counter()
            futs = [eng.submit_step("m", f"s{s}", xs[t, s])
                    for t in range(n_ticks) for s in range(n_sessions)]
            for f in futs:
                f.result(timeout=60.0)
            wall = time.perf_counter() - t0
        flushes = eng.telemetry.step_batches
    # the PR-8 contract, *counted* rather than inferred from timing: the
    # engine's runner keeps sessions in device-resident slots, so each
    # flush wave is ONE fused slots_generate dispatch (duplicate clients
    # in a piled-up flush split into extra waves), inserts happen only
    # while sessions first become resident, and the host gather/scatter
    # ops (decode_many / decode_step) never fire
    assert counts["slots_generate"] >= flushes, \
        (counts.by_op(), flushes)
    assert counts["decode_many"] == 0 and counts["decode_step"] == 0, \
        (counts.by_op(), flushes)
    assert counts["slots_insert"] <= n_sessions, \
        (counts.by_op(), n_sessions)
    row("obs/dispatch_counting", 1e6 * wall / (n_ticks * n_sessions),
        f"slots_generate={counts['slots_generate']};flushes={flushes};"
        f"inserts={counts['slots_insert']};"
        f"steps_per_s={n_ticks * n_sessions / wall:.0f}")

    # -- export path: render + event append, per call ----------------------
    snap = Telemetry.merge([Telemetry(), Telemetry()])
    _, us = timed(lambda: [render_prometheus(snap) for _ in range(100)])
    row("obs/render_prometheus", us / 100, f"keys={len(snap)}")
    log = EventLog(capacity=4096)
    _, us = timed(lambda: [log.log("tick", i=i) for i in range(1000)])
    row("obs/event_log", us / 1000, "ring=4096;no_file")


if __name__ == "__main__":
    main()

"""Benchmark harness — one module per paper table/figure (DESIGN.md §8).
Prints ``name,us_per_call,derived`` CSV. Select with ``--only <substr>``.
``--smoke`` runs benchmarks that support it with reduced workloads (the
CI guard against benchmark drivers silently rotting). ``--json`` also
writes each suite's rows to ``BENCH_<suite>.json`` (per-phase
name/us/metric) so the perf trajectory persists across PRs — CI uploads
them as artifacts.
"""

from __future__ import annotations

import argparse
import inspect
import json
import sys
import traceback

from benchmarks import (bench_communication, bench_ensemble, bench_extreme,
                        bench_fault, bench_hotswap, bench_kernels, bench_obs,
                        bench_prediction, bench_roofline, bench_serving,
                        bench_serving_mesh, bench_speedup, common)

ALL = [
    ("prediction", bench_prediction),    # paper Figs. 5-10
    ("speedup", bench_speedup),          # paper Table II
    ("communication", bench_communication),  # paper Remark 1
    ("extreme", bench_extreme),          # paper §IV.C sensitivity study
    ("kernels", bench_kernels),          # Pallas kernels vs oracles
    ("roofline", bench_roofline),        # dry-run roofline table
    ("serving", bench_serving),          # ISSUE 1 micro-batcher throughput
    ("hotswap", bench_hotswap),          # ISSUE 2 swap-storm latency/drops
    # "mesh", not "serving_mesh": --only matches substrings, and
    # `--only serving` must keep selecting just bench_serving
    ("mesh", bench_serving_mesh),        # ISSUE 3 shard scaling + storm;
    # ISSUE 4 multi-process transport phase (join/leave over OS
    # processes) runs as its third phase, --smoke included
    ("obs", bench_obs),                  # ISSUE 6 tracing-overhead bound
    ("fault", bench_fault),              # ISSUE 7 crash supervision:
    # SIGKILL mid-traffic -> detection/fail-fast/respawn budgets
    ("ensemble", bench_ensemble),        # ISSUE 9 fused ensemble serving
    # vs N-sequential members + fused-alert precision/recall gain
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced workloads where the benchmark supports "
                    "a `smoke` parameter")
    ap.add_argument("--json", action="store_true",
                    help="write each suite's rows to BENCH_<suite>.json "
                    "(per-phase name/us/metric)")
    args = ap.parse_args()
    failures = 0
    for name, mod in ALL:
        if args.only and args.only not in name:
            continue
        print(f"# --- {name} ---", flush=True)
        common.drain_rows()               # suite boundary: fresh collector
        ok = True
        try:
            if args.smoke and \
                    "smoke" in inspect.signature(mod.main).parameters:
                mod.main(smoke=True)
            else:
                mod.main()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures += 1
            ok = False
        if args.json:
            path = f"BENCH_{name}.json"
            with open(path, "w") as f:
                json.dump({"suite": name, "ok": ok, "smoke": args.smoke,
                           "rows": common.drain_rows()}, f, indent=2)
            print(f"# wrote {path}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

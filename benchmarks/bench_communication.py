"""Paper Remark 1: communication-cost reduction of linearly increasing
sample sequences. Rounds (= model exchanges) needed for K gradient
computations: linear s_i = 10*i vs constant s = 10 — T ~ sqrt(2K/a) vs
T ~ K/10 — plus measured bytes on the real LSTM training path."""

from __future__ import annotations

from benchmarks.common import row, stock_datasets, timed
from repro.core.schedules import (ConstantSchedule, SampleSchedule,
                                  communication_rounds_constant)
from repro.training.loop import train_rnn_local_sgd


def main() -> None:
    lin = SampleSchedule(a=10)
    for k in (10_000, 100_000, 288_375):   # paper K = 288375
        t_lin = lin.rounds_for_budget(k)
        t_const = communication_rounds_constant(k, 10)
        row(f"communication/rounds/K{k}", 0.0,
            f"linear={t_lin};constant={t_const};"
            f"reduction={t_const/t_lin:.1f}x")

    train_ds, test_ds = stock_datasets("AAPL")
    for name, sched in (("linear", SampleSchedule(a=10)),
                        ("constant", ConstantSchedule(size=10))):
        res, us = timed(train_rnn_local_sgd, train_ds, test_ds,
                        n_workers=2, iterations=1000, batch=32,
                        schedule=sched, repeat=1)
        row(f"communication/train2w/{name}", us,
            f"comms={res.communications};bytes={res.comm_bytes};"
            f"mse={res.test_mse:.5f}")


if __name__ == "__main__":
    main()

"""The paper's experiment, end to end: n in {1, 2, 5, 10} asynchronous
compute nodes with heterogeneous speeds training the LSTM stock predictor
through the central server (event-driven simulator), reproducing the
speedup table (Table II) and the same-accuracy claim (Figs. 5-10).

    PYTHONPATH=src python examples/async_stock.py [--iterations 2000]
"""

import argparse

import jax
import numpy as np

from repro.core.simulator import AsyncSimulator, SimConfig
from repro.data import load_stock, make_windows, train_test_split
from repro.data.sharding import client_splits
from repro.models.rnn import RNNConfig, init_rnn
from repro.optim.optimizers import sgd
from repro.training.loop import evaluate, make_loss_fn

ap = argparse.ArgumentParser()
ap.add_argument("--iterations", type=int, default=2000)
ap.add_argument("--ticker", default="AAPL")
args = ap.parse_args()

ohlcv = load_stock(args.ticker)
tr, te = train_test_split(ohlcv)
train_ds, test_ds = make_windows(tr), make_windows(te)
cfg = RNNConfig()
loss_fn = make_loss_fn(cfg)
params = init_rnn(jax.random.PRNGKey(0), cfg)

print(f"{args.ticker}: K={args.iterations} gradient computations, "
      f"linear schedule s_i=10i, eta_i = 0.01/(1+0.01*sqrt(t))")
print(f"{'n':>3} {'speedup':>8} {'comms':>6} {'max_stale':>9} "
      f"{'test MSE':>9}")

base_mse = None
for n in (1, 2, 5, 10):
    splits = client_splits(len(train_ds), n, "iid")

    def mk(idx):
        def gen(rng, h, batch):
            out = []
            for _ in range(h):
                b = rng.choice(idx, size=batch)
                out.append((train_ds.x[b], train_ds.y[b],
                            train_ds.v.astype(np.float32)[b],
                            np.ones(batch, np.float32)))
            return tuple(np.stack([o[i] for o in out]) for i in range(4))
        return gen

    sim = AsyncSimulator(
        loss_fn, sgd(), params, [mk(s) for s in splits],
        SimConfig(n_clients=n, total_iterations=args.iterations,
                  batch_size=32, server_cost=0.02,
                  net_delay=(0.005, 0.02)),
        eval_fn=lambda p: evaluate(p, cfg, test_ds)[0])
    s = sim.run()
    mse = s["eval_log"][-1][1]
    base_mse = base_mse or mse
    print(f"{n:>3} {s['speedup']:>8.2f} {s['communications']:>6} "
          f"{s['max_staleness']:>9} {mse:>9.5f}")

print("\npaper Table II reference: n=2 ~1.5x, n=5 ~4.2x, n=10 ~8.3x "
      "(saturation from server aggregation)")

"""Quickstart: train the paper's 2-layer-LSTM stock predictor on one
compute node, then with the async local-SGD framework on 2 workers, and
compare — ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.data import load_stock, make_windows, train_test_split
from repro.training.loop import train_rnn_local_sgd, train_rnn_serial

ohlcv = load_stock("AAPL", n_days=1000)   # synthetic fallback when offline
train_raw, test_raw = train_test_split(ohlcv)
train_ds, test_ds = make_windows(train_raw), make_windows(test_raw)
print(f"AAPL: {len(train_ds)} train / {len(test_ds)} test windows "
      f"(window=20, OHLCV), extreme fraction "
      f"{float(np.mean(train_ds.v != 0)):.3f}")

print("\n-- single compute node (paper baseline) --")
serial = train_rnn_serial(train_ds, test_ds, iterations=800, batch=32)
print(f"test MSE {serial.test_mse:.5f} after {serial.iterations} iters")

print("\n-- async local SGD, 2 workers, linear schedule s_i = 10*i --")
dist = train_rnn_local_sgd(train_ds, test_ds, n_workers=2,
                           iterations=800, batch=32)
print(f"test MSE {dist.test_mse:.5f} after {dist.iterations} iters "
      f"with only {dist.communications} model exchanges "
      f"({dist.comm_bytes / 1e6:.1f} MB total)")

ratio = dist.test_mse / serial.test_mse
print(f"\naccuracy ratio dist/serial = {ratio:.2f} "
      f"(paper claim: same level of accuracy)")

"""End-to-end driver: train a ~100M-parameter dense transformer for a few
hundred steps on CPU using the full framework path — the zoo model
definition, Adam, gradient clipping, the paper's local-SGD rounds with
the linear schedule, and checkpointing.

    PYTHONPATH=src python examples/e2e_train.py --steps 200
(defaults are sized to finish in a few minutes on CPU; pass --steps 300
--batch 8 --seq 256 for the full run)
"""

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import ARCHS
from repro.core.async_local_sgd import AsyncLocalSGD, LocalSGDConfig
from repro.core.schedules import SampleSchedule, StepSizeSchedule
from repro.data.tokens import synthetic_token_batch
from repro.models import transformer as tfm
from repro.optim.optimizers import adam

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--workers", type=int, default=2)
ap.add_argument("--ckpt", default="/tmp/repro_e2e.npz")
args = ap.parse_args()

# ~100M params: a scaled-down qwen1.5 family member built through the
# same config system as the full zoo entries.
cfg = dataclasses.replace(
    ARCHS["qwen1.5-4b"], name="qwen1.5-100m", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, head_dim=64, d_ff=2048, vocab=8192,
    dtype="float32", remat=False)
params = tfm.init_lm(cfg, jax.random.PRNGKey(0))
n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
print(f"{cfg.name}: {n_params / 1e6:.1f}M params, "
      f"{args.workers} local-SGD workers, target {args.steps} steps")


def loss_fn(p, batch):
    return tfm.lm_loss(cfg, p, batch)


trainer = AsyncLocalSGD(
    loss_fn, adam(clip_norm=1.0),
    LocalSGDConfig(n_workers=args.workers,
                   schedule=SampleSchedule(a=4.0),
                   stepsize=StepSizeSchedule(eta0=3e-4, beta=0.01)))
stacked, opt_state = trainer.init(params)

t0 = time.time()
round_i = 0
while trainer.iterations_done < args.steps:
    round_i += 1
    h = trainer.local_steps_for_round(round_i)
    toks = np.stack([
        np.stack([synthetic_token_batch(args.batch, args.seq, cfg.vocab,
                                        seed=round_i * 1000 + w * 100 + i)
                  for i in range(h)])
        for w in range(args.workers)])
    stacked, opt_state, loss = trainer.run_round(stacked, opt_state,
                                                 jnp.asarray(toks))
    print(f"round {round_i:3d} (H={h:2d}, iters {trainer.iterations_done:4d},"
          f" lr {trainer.lr_for_round():.2e}): loss {loss:.4f}", flush=True)

dt = time.time() - t0
final = jax.tree.map(lambda a: a[0], stacked)
save_checkpoint(args.ckpt, final,
                metadata={"rounds": trainer.rounds_done,
                          "iterations": trainer.iterations_done})
loaded, meta = load_checkpoint(args.ckpt, like=final)
assert meta["rounds"] == trainer.rounds_done
print(f"\n{trainer.iterations_done} iterations in {dt:.0f}s with "
      f"{trainer.communications} model exchanges "
      f"(vs {trainer.iterations_done} for per-step sync); "
      f"loss {trainer.loss_history[0]:.3f} -> {trainer.loss_history[-1]:.3f}")
print(f"checkpoint round-trip OK: {args.ckpt}")
assert trainer.loss_history[-1] < trainer.loss_history[0]

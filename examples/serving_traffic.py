"""Simulated many-client serving traffic against the streaming engine.

Each client streams windows from its own synthetic heavy-tailed ticker
(``repro.data.synthetic``); requests are dynamically micro-batched, and
each client also keeps a recurrent session resident in the cache so
per-step updates are O(1). Extreme-event alerts (EVL head + EVT tail)
are printed as they fire.

    PYTHONPATH=src python examples/serving_traffic.py
"""

import time

import numpy as np

from repro.data import load_stock, make_windows
from repro.serving import (BatcherConfig, ModelRegistry,
                           RecurrentSessionRunner, ServingEngine,
                           SessionCache, build_lstm_forecaster)

N_CLIENTS = 24
REQUESTS_PER_CLIENT = 8
ALERT_P = 0.9


def main() -> None:
    fc = build_lstm_forecaster(seed=0)
    registry = ModelRegistry()
    registry.register("paper-lstm", fc)

    # one synthetic ticker per client: distinct but reproducible series
    streams = []
    for c in range(N_CLIENTS):
        ohlcv = load_stock(f"CLIENT{c}", n_days=fc.window + 96)
        streams.append(make_windows(ohlcv, window=fc.window).x)

    engine = ServingEngine(
        registry, BatcherConfig(max_batch=16, max_wait_ms=2.0,
                                length_buckets=(fc.window,)))
    with engine:
        engine.warmup("paper-lstm")
        engine.telemetry.reset_clock()

        # phase 1: bursty batched traffic — every client fires windows at
        # the engine; the micro-batcher packs them into shared applies
        t0 = time.time()
        futures = {}
        for step in range(REQUESTS_PER_CLIENT):
            for c, stream in enumerate(streams):
                futures[(c, step)] = engine.submit(
                    "paper-lstm", stream[step % len(stream)])
        alerts = 0
        for (c, step), fut in futures.items():
            forecast, p = fut.result(timeout=30.0)
            if p >= ALERT_P:
                alerts += 1
                if alerts <= 5:
                    print(f"  ALERT client {c:2d} step {step}: forecast "
                          f"{forecast:+.4f}  p_extreme {p:.3f}")
        wall = time.time() - t0
        snap = engine.telemetry.snapshot()
        print(f"batched: {len(futures)} requests from {N_CLIENTS} clients "
              f"in {wall*1e3:.0f} ms, {alerts} extreme alerts")
        print("  " + engine.telemetry.format(snap))

        # phase 2: streaming sessions — per-client carry state stays
        # resident, so each new tick is one O(1) step, not a re-run of
        # the whole window
        runner = RecurrentSessionRunner(
            fc, SessionCache(max_sessions=N_CLIENTS,
                             telemetry=engine.telemetry))
        t0 = time.time()
        n = 0
        for step in range(fc.window):
            for c, stream in enumerate(streams):
                y, p = runner.step(f"client-{c}", stream[0][step])
                n += 1
        wall = time.time() - t0
        print(f"sessions: {n} O(1) steps in {wall*1e3:.0f} ms "
              f"({n/max(wall, 1e-9):.0f} steps/s)")
        print(f"  cache: {runner.cache.stats()}")


if __name__ == "__main__":
    main()

"""Extreme-event sensitivity study (paper §II.A + §IV.C): compare
imbalanced-data handling strategies on a heavy-tailed synthetic stock —
plain windows, extreme oversampling, EVL-weighted loss — and fit the EVT
tail model to the return distribution.

    PYTHONPATH=src python examples/extreme_events.py
"""

import numpy as np

from repro.data import load_stock, make_windows, train_test_split
from repro.data.synthetic import log_returns
from repro.extreme.evt import fit_tail, tail_probability
from repro.extreme.resampling import (evl_sample_weights,
                                      oversample_extreme_windows)
from repro.training.loop import train_rnn_serial

ohlcv = load_stock("AAPL")
returns = log_returns(ohlcv[:, 3])

# --- EVT tail fit (eqs. 3-4) ---------------------------------------------
p = fit_tail(returns, q=0.95)
print(f"EVT tail fit: xi={p['xi']:.4f} scale={p['scale']:.4f} "
      f"P(Y>xi)={p['tail_at_xi']:.3f}")
for mult in (1, 2, 4):
    y = p["xi"] + mult * p["scale"]
    t = float(tail_probability(y, p["xi"], p["scale"], p["tail_at_xi"],
                               gamma=0.0))  # Gumbel: unbounded support
    emp = float(np.mean(returns > y))
    print(f"  P(Y > xi+{mult}*scale): model {t:.4f} vs empirical {emp:.4f}")

# --- training with the three strategies ----------------------------------
tr, te = train_test_split(ohlcv)
train_ds, test_ds = make_windows(tr), make_windows(te)
v = np.asarray(train_ds.v)
print(f"\n{len(train_ds)} windows, {np.sum(v != 0)} extreme "
      f"({100 * np.mean(v != 0):.1f}% — the imbalance barrier)")

rng = np.random.default_rng(0)
strategies = {"plain": None}
idx = oversample_extreme_windows(train_ds.returns, train_ds.eps1,
                                 train_ds.eps2, 0.3, rng)
counts = np.bincount(idx, minlength=len(train_ds)).astype(np.float32)
strategies["oversample"] = counts / counts.mean()
strategies["evl_weights"] = evl_sample_weights(
    train_ds.returns, train_ds.eps1, train_ds.eps2)

print(f"\n{'strategy':>12} {'test MSE':>9} {'recall':>7} {'f1':>6}")
for name, w in strategies.items():
    res = train_rnn_serial(train_ds, test_ds, iterations=1200, batch=32,
                           evl_weight=0.5, weights=w)
    e = res.test_extreme
    print(f"{name:>12} {res.test_mse:>9.5f} {e['recall']:>7.2f} "
          f"{e['f1']:>6.2f}")

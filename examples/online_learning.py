"""Online learning in one process, deterministically: the local-SGD
round loop hot-swaps every round's worker-averaged weights into a live
serving engine *from inside the round callback*, and a batch of client
traffic is served between rounds — so you can watch the served forecasts
move (and the model version climb) as training converges, without any
thread nondeterminism.

    PYTHONPATH=src python examples/online_learning.py
"""

import jax
import numpy as np

from repro.configs.paper_lstm import CONFIG
from repro.data import load_stock, make_windows, train_test_split
from repro.models.rnn import init_rnn
from repro.serving import (BatcherConfig, LSTMForecaster, ModelRegistry,
                           ServingEngine, WeightPublisher)
from repro.training.loop import train_rnn_local_sgd
from repro.training.metrics import mse


def main() -> None:
    ohlcv = load_stock("AAPL", n_days=500)
    tr, te = train_test_split(ohlcv)
    train_ds, test_ds = make_windows(tr), make_windows(te)
    probe = test_ds.x[:16]                     # fixed probe traffic

    key = "paper-lstm"
    registry = ModelRegistry()
    fc0 = LSTMForecaster(cfg=CONFIG,
                         params=init_rnn(jax.random.PRNGKey(0), CONFIG))
    fc0.calibrate(train_ds.x[:64])
    registry.register(key, fc0)

    engine = ServingEngine(registry, BatcherConfig(
        max_batch=16, max_wait_ms=2.0, length_buckets=(CONFIG.window,)))
    publisher = WeightPublisher(registry, key,
                                calib_windows=train_ds.x[:64],
                                telemetry=engine.telemetry)

    with engine:
        engine.warmup(key, lengths=(CONFIG.window,))

        def on_round(round_idx, avg_params):
            version = publisher.publish(avg_params, round_idx)
            futs = [engine.submit(key, w) for w in probe]
            got = np.array([f.result(timeout=30.0)[0] for f in futs])
            served_mse = mse(got, test_ds.y[:16])
            versions = {f.model_version for f in futs}
            print(f"round {round_idx:2d} -> published v{version}; probe "
                  f"MSE {served_mse:.5f} served by "
                  f"{sorted(versions)}")

        res = train_rnn_local_sgd(train_ds, test_ds, n_workers=3,
                                  iterations=300, batch=32, seed=0,
                                  round_callback=on_round)
        snap = engine.telemetry.snapshot()

    print(f"\ntraining done: test MSE {res.test_mse:.5f} after "
          f"{res.communications} exchanges")
    print(f"served {snap['requests']} probe requests across "
          f"{len(snap['requests_by_version'])} model versions; "
          f"{snap['swaps']} hot swaps, zero dropped")


if __name__ == "__main__":
    main()

"""Streaming forecast serving: micro-batcher flush policies, padding
correctness, session-cache semantics, and registry round-trips."""

import time

import jax
import numpy as np
import pytest

from repro.models.rnn import RNNConfig, init_rnn, rnn_apply
from repro.serving import (BatcherConfig, LSTMForecaster, ModelRegistry,
                           RecurrentSessionRunner, ServingEngine,
                           SessionCache, Telemetry, build_lstm_forecaster)

CFG = RNNConfig(input_dim=5, hidden=16, num_layers=2, fc_dims=(8, 4),
                window=20, evl_head=True)


@pytest.fixture(scope="module")
def forecaster():
    params = init_rnn(jax.random.PRNGKey(0), CFG)
    fc = LSTMForecaster(cfg=CFG, params=params)
    rng = np.random.default_rng(0)
    fc.calibrate(rng.standard_normal((64, CFG.window, 5)).astype(np.float32)
                 * 0.02)
    return fc


@pytest.fixture()
def registry(forecaster):
    reg = ModelRegistry()
    reg.register("m", forecaster)
    return reg


def _windows(n, t=20, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, t, 5)).astype(np.float32) * 0.02


# -- micro-batcher ---------------------------------------------------------

def test_flush_on_max_batch(registry):
    """With an effectively infinite wait, a full group must still flush
    the moment it reaches max_batch."""
    cfg = BatcherConfig(max_batch=4, max_wait_ms=60_000.0)
    with ServingEngine(registry, cfg) as eng:
        eng.warmup("m", lengths=(20,))
        futs = [eng.submit("m", w) for w in _windows(4)]
        res = [f.result(timeout=10.0) for f in futs]
    assert len(res) == 4
    assert eng.telemetry.batches >= 1
    snap = eng.telemetry.snapshot()
    assert snap["mean_batch"] == 4.0


def test_flush_on_timeout(registry):
    """A partial group must flush once its oldest request has waited
    max_wait_ms, without needing more arrivals."""
    cfg = BatcherConfig(max_batch=64, max_wait_ms=10.0)
    with ServingEngine(registry, cfg) as eng:
        eng.warmup("m", lengths=(20,))
        t0 = time.perf_counter()
        futs = [eng.submit("m", w) for w in _windows(3)]
        res = [f.result(timeout=10.0) for f in futs]
        elapsed = time.perf_counter() - t0
    assert len(res) == 3
    assert elapsed < 5.0                       # did not wait for a full batch
    assert eng.telemetry.snapshot()["mean_batch"] == 3.0


def test_bucket_padding_matches_unbatched(registry, forecaster):
    """Mixed-length windows batched into one padded bucket must produce
    exactly the same predictions as unbatched exact-shape applies."""
    lengths = (12, 20, 17, 9, 20)
    wins = [_windows(1, t, seed=t)[0] for t in lengths]
    cfg = BatcherConfig(max_batch=8, max_wait_ms=5.0)
    with ServingEngine(registry, cfg) as eng:
        futs = [eng.submit("m", w) for w in wins]
        got = [f.result(timeout=10.0) for f in futs]
    # exact-shape reference; batching at a different [B, T] makes XLA pick
    # a different tiling, so agreement is to float32 ulp, not bitwise
    for (y_got, p_got), w in zip(got, wins):
        y_ref, p_ref = forecaster.predict(w[None])
        np.testing.assert_allclose(y_got, y_ref[0], atol=1e-7, rtol=1e-6)
        np.testing.assert_allclose(p_got, p_ref[0], atol=1e-7, rtol=1e-6)
    # within one padded batch the gather must be exact: re-submitting the
    # same mixed-length batch reproduces itself bitwise
    with ServingEngine(registry, cfg) as eng:
        futs = [eng.submit("m", w) for w in wins]
        again = [f.result(timeout=10.0) for f in futs]
    assert got == again


def test_cancelled_request_does_not_kill_engine(registry):
    """A client cancelling its future must not crash the worker thread
    (futures transition to RUNNING at flush, so late cancels fail)."""
    cfg = BatcherConfig(max_batch=64, max_wait_ms=20.0)
    with ServingEngine(registry, cfg) as eng:
        fut = eng.submit("m", _windows(1)[0])
        fut.cancel()                           # may or may not win the race
        res = eng.predict("m", _windows(1)[0], timeout=10.0)
    assert isinstance(res, tuple) and len(res) == 2


def test_engine_submit_requires_running(registry):
    eng = ServingEngine(registry)
    with pytest.raises(RuntimeError):
        eng.submit("m", _windows(1)[0])


def test_engine_rejects_bad_submissions(registry):
    with ServingEngine(registry) as eng:
        with pytest.raises(KeyError):
            eng.submit("nope", _windows(1)[0])         # unknown model
        with pytest.raises(ValueError):
            eng.submit("m", np.zeros((0, 5), np.float32))   # empty window
        with pytest.raises(ValueError):
            eng.submit("m", np.zeros((20,), np.float32))    # wrong rank
        assert eng.predict("m", _windows(1)[0], timeout=10.0)


def test_batch_bucketing_quantizes_shapes():
    cfg = BatcherConfig(max_batch=32, length_buckets=(16, 32))
    assert cfg.bucket_len(9) == 16
    assert cfg.bucket_len(16) == 16
    assert cfg.bucket_len(20) == 32
    assert cfg.bucket_len(40) == 32            # beyond buckets: clamped
    assert cfg.bucket_batch(3) == 4
    assert cfg.bucket_batch(32) == 32


def test_overlong_window_clamps_to_largest_bucket(registry, forecaster):
    """Regression: a request longer than every configured length bucket
    used to keep its raw length — a shape outside the fixed compile set
    (never warmed), recompiling on the serving hot path. It is now
    clamped to the largest bucket, serving the newest rows (the LSTM is
    causal, so those rows ARE the clamped window's forecast)."""
    cfg = BatcherConfig(max_batch=4, max_wait_ms=5.0,
                        length_buckets=(12, 20))
    # every length the hot path can see maps into the configured buckets
    assert {cfg.bucket_len(t) for t in (1, 12, 19, 20, 21, 64)} <= {12, 20}
    long_window = _windows(1, t=33, seed=9)[0]
    with ServingEngine(registry, cfg) as eng:
        eng.warmup("m", lengths=(12, 20))
        y_got, p_got = eng.predict("m", long_window, timeout=10.0)
    # the served result is exactly the truncated-window prediction
    y_ref, p_ref = forecaster.predict(long_window[None, -20:])
    np.testing.assert_array_equal(y_got, y_ref[0])
    np.testing.assert_array_equal(p_got, p_ref[0])


def test_client_id_threads_through_to_telemetry(registry):
    """Regression: per-client attribution must survive into the flush
    telemetry and the resolved future."""
    cfg = BatcherConfig(max_batch=4, max_wait_ms=2.0, length_buckets=(20,))
    with ServingEngine(registry, cfg) as eng:
        futs = [eng.submit("m", w, client_id=f"c{i % 2}")
                for i, w in enumerate(_windows(4))]
        futs.append(eng.submit("m", _windows(1)[0]))      # anonymous
        for f in futs:
            f.result(timeout=10.0)
    assert futs[0].client_id == "c0" and futs[1].client_id == "c1"
    assert futs[-1].client_id is None
    snap = eng.telemetry.snapshot()
    assert snap["requests_by_client"] == {"c0": 2, "c1": 2}
    assert snap["unique_clients"] == 2


def test_non_pow2_max_batch_rounds_down(registry):
    """Regression: max_batch=12 used to clamp bucket_batch to 12 — a
    shape outside the '{pow2 batches} x {length buckets}' compile set.
    The config now rounds down at construction, so every emitted batch
    shape is one the warmup compiled."""
    cfg = BatcherConfig(max_batch=12)
    assert cfg.max_batch == 8
    assert {cfg.bucket_batch(n) for n in range(1, cfg.max_batch + 1)} \
        == {1, 2, 4, 8}
    with pytest.raises(ValueError):
        BatcherConfig(max_batch=0)
    # un-padded batching is untouched by the rounding
    assert BatcherConfig(max_batch=12, pad_batch=False).max_batch == 12
    # end to end: a full group at the rounded max_batch flushes as one
    # pow2 batch the warmup covered (no mid-traffic compile, exact batch)
    eng_cfg = BatcherConfig(max_batch=6, max_wait_ms=60_000.0,
                            length_buckets=(20,))
    assert eng_cfg.max_batch == 4
    with ServingEngine(registry, eng_cfg) as eng:
        eng.warmup("m", lengths=(20,))
        futs = [eng.submit("m", w) for w in _windows(4)]
        assert len([f.result(timeout=10.0) for f in futs]) == 4
    snap = eng.telemetry.snapshot()
    assert snap["mean_batch"] == 4.0 and snap["batch_occupancy"] == 1.0


def test_replay_is_one_dispatch_not_a_step_loop(forecaster):
    """Regression: ``replay`` used to loop Python-side over ``step``,
    syncing the device O(window) times per cache miss / swap re-prime.
    It is now a single jitted lax.scan dispatch — and still bitwise
    equal to the step loop (the session cache's contract)."""
    w = _windows(1, seed=13)[0]
    calls = {"n": 0}
    real_step = forecaster.step

    def counting_step(x_t, carry):
        calls["n"] += 1
        return real_step(x_t, carry)

    forecaster.step = counting_step
    try:
        y_scan, p_scan, carry_scan = forecaster.replay(w[None])
    finally:
        forecaster.step = real_step
    assert calls["n"] == 0                     # no per-step host loop
    # bitwise equivalence against the explicit step loop
    carry = forecaster.init_carry(1)
    for t in range(CFG.window):
        y_loop, p_loop, carry = forecaster.step(w[None, t], carry)
    np.testing.assert_array_equal(y_loop, y_scan)
    np.testing.assert_array_equal(p_loop, p_scan)
    for (h1, c1), (h2, c2) in zip(carry, carry_scan):
        np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_compiled_rnn_builds_once_under_threads():
    """Regression: ``_compiled_rnn`` used to tolerate a 'benign' race —
    two threads could each build a full jit wrapper set for the same
    config during shard-join warmup. Now double-checked-locked: exactly
    one build, every thread gets the same object."""
    import threading

    from repro.serving import forecaster as fmod

    cfg = RNNConfig(input_dim=5, hidden=12, num_layers=1, fc_dims=(6,),
                    window=10, evl_head=True)   # fresh: not yet cached
    fmod._RNN_COMPILED.pop(cfg, None)
    builds = {"n": 0}
    real_build = fmod._build_rnn_fns

    def counting_build(c):
        builds["n"] += 1
        time.sleep(0.05)          # widen the race window
        return real_build(c)

    fmod._build_rnn_fns = counting_build
    results = []
    try:
        barrier = threading.Barrier(8)

        def hit():
            barrier.wait()
            results.append(fmod._compiled_rnn(cfg))

        threads = [threading.Thread(target=hit) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        fmod._build_rnn_fns = real_build
        fmod._RNN_COMPILED.pop(cfg, None)
    assert builds["n"] == 1
    assert all(r is results[0] for r in results)


# -- batched decode path ---------------------------------------------------

def test_batched_step_matches_sequential_bitwise(forecaster):
    """The decode-lane contract: stepping N sessions as one batched
    flush is BITWISE identical to stepping them one by one (both run
    the same fixed-width compiled step)."""
    n, T = 8, CFG.window
    rng = np.random.default_rng(21)
    xs = rng.standard_normal((T, n, 5)).astype(np.float32) * 0.02

    seq = [forecaster.init_carry(1) for _ in range(n)]
    seq_out = [None] * n
    for t in range(T):
        for i in range(n):
            y, p, seq[i] = forecaster.step(xs[t, i:i + 1], seq[i])
            seq_out[i] = (float(y[0]), float(p[0]))
    bat = [forecaster.init_carry(1) for _ in range(n)]
    for t in range(T):
        ys, ps, bat = forecaster.step_many(xs[t], bat)
    for i in range(n):
        assert (float(ys[i]), float(ps[i])) == seq_out[i]
        for (h1, c1), (h2, c2) in zip(seq[i], bat[i]):
            np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
            np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_donate_default_platform_gate(monkeypatch):
    """Carry donation defaults ON off-CPU and OFF on CPU, where XLA
    donation is a warn + copy no-op."""
    from repro.serving import forecaster as fc_mod
    assert fc_mod._donate_default() == (jax.default_backend() != "cpu")
    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
    assert fc_mod._donate_default() is True
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert fc_mod._donate_default() is False


def test_donated_step_many_matches_non_donated_bitwise(forecaster,
                                                       monkeypatch):
    """The donating compiled programs (gather/scatter and slots paths)
    must be bit-for-bit the non-donating ones.  On CPU an explicit
    ``donate=True`` is gated off, so force the donating variants by
    patching the platform query — XLA then warns and copies, which is
    exactly the behavior the gate exists to avoid, but the numerics
    contract still has to hold."""
    import warnings

    def donated(fn, *args, **kw):
        monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
        try:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                return fn(*args, donate=True, **kw)
        finally:
            monkeypatch.undo()

    rng = np.random.default_rng(3)
    xs = rng.standard_normal((5, 5)).astype(np.float32) * 0.02
    mk = lambda: [forecaster.init_carry(1) for _ in range(5)]  # noqa: E731
    y0, p0, cs0 = forecaster.step_many(xs, mk(), donate=False)
    y1, p1, cs1 = donated(forecaster.step_many, xs, mk())
    assert np.array_equal(y0, y1) and np.array_equal(p0, p1)
    for a, b in zip(jax.tree_util.tree_leaves(cs0),
                    jax.tree_util.tree_leaves(cs1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    carry = forecaster.init_carry(1)
    s0 = forecaster.insert(forecaster.init_slots(4), 1, carry,
                           donate=False)
    s1 = donated(forecaster.insert, forecaster.init_slots(4), 1, carry)
    x = np.zeros((s0.num_slots, 5), np.float32)
    x[1] = xs[0]
    ya, pa, s0 = forecaster.generate(s0, x, donate=False)
    yb, pb, s1 = donated(forecaster.generate, s1, x)
    assert np.array_equal(ya, yb) and np.array_equal(pa, pb)
    for a, b in zip(jax.tree_util.tree_leaves(s0.carry),
                    jax.tree_util.tree_leaves(s1.carry)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_transport_worker_shard_forces_donation_off():
    """Regression: the transport worker's recv loop migrates session
    carries concurrently with the flush thread, so its EngineShard must
    pin donate_carries=False regardless of platform default."""
    from repro.serving.transport import _ShardState
    state = _ShardState()
    state.configure(0, BatcherConfig(max_batch=4, max_wait_ms=1.0), 16)
    assert state.shard.donate_carries is False


def test_step_many_partial_and_chunked_flushes(forecaster):
    """Batches that underfill (n < width) or overflow (n > width) the
    decode lane still match per-session steps bitwise."""
    rng = np.random.default_rng(5)
    for n in (1, 3, 8, 13):
        xs = rng.standard_normal((n, 5)).astype(np.float32) * 0.02
        ys, ps, _ = forecaster.step_many(
            xs, [forecaster.init_carry(1) for _ in range(n)])
        assert ys.shape == (n,)
        for i in range(n):
            y1, p1, _ = forecaster.step(xs[i:i + 1],
                                        forecaster.init_carry(1))
            assert float(ys[i]) == float(y1[0])
            assert float(ps[i]) == float(p1[0])


def test_runner_step_many_matches_step(forecaster):
    """Gather/scatter through the session cache: batched runner steps
    equal sequential runner steps, carries land back per client."""
    n, T = 6, 10
    rng = np.random.default_rng(9)
    xs = rng.standard_normal((T, n, 5)).astype(np.float32) * 0.02
    r_seq = RecurrentSessionRunner(forecaster,
                                   SessionCache(max_sessions=n))
    r_bat = RecurrentSessionRunner(forecaster,
                                   SessionCache(max_sessions=n))
    for t in range(T):
        seq = [r_seq.step(f"c{i}", xs[t, i]) for i in range(n)]
        bat = r_bat.step_many([(f"c{i}", xs[t, i], None)
                               for i in range(n)])
        assert bat == seq
    # slot runner: sessions live in device lanes, not the cache (the
    # cache is the spill tier and stays empty while lanes suffice)
    assert sorted(r_bat.resident_clients()) == [f"c{i}" for i in range(n)]
    assert len(r_bat.cache) == 0
    # spilling hands every lane's carry to the cache, bitwise intact
    assert r_bat.spill_all() == n
    assert len(r_bat.cache) == n


def test_runner_step_many_duplicate_clients_keep_stream_order(forecaster):
    """Two steps for one client inside a single batched call must see
    each other's carries (waves), exactly like two sequential steps."""
    rng = np.random.default_rng(11)
    x0, x1 = (rng.standard_normal((2, 5)).astype(np.float32) * 0.02)
    r_seq = RecurrentSessionRunner(forecaster,
                                   SessionCache(max_sessions=2))
    a = r_seq.step("dup", x0)
    b = r_seq.step("dup", x1)
    r_bat = RecurrentSessionRunner(forecaster,
                                   SessionCache(max_sessions=2))
    got = r_bat.step_many([("dup", x0, None), ("dup", x1, None)])
    assert got == [a, b]


def test_engine_step_flush_groups_and_matches_runner(registry, forecaster):
    """Engine-level batched decode: a burst of submit_step calls flushes
    as fused batches (telemetry shows >1 sessions per flush) and the
    results equal the plain per-session runner bitwise."""
    n, T = 8, 6
    rng = np.random.default_rng(33)
    xs = rng.standard_normal((T, n, 5)).astype(np.float32) * 0.02
    runner = RecurrentSessionRunner(forecaster,
                                    SessionCache(max_sessions=n))
    ref = {}
    for t in range(T):
        for i in range(n):
            ref[(t, i)] = runner.step(f"c{i}", xs[t, i])
    cfg = BatcherConfig(max_batch=16, max_wait_ms=5.0, length_buckets=(20,))
    with ServingEngine(registry, cfg) as eng:
        eng.warmup("m", lengths=(20,))
        eng.telemetry.reset_clock()
        futs = {}
        for t in range(T):
            for i in range(n):
                futs[(t, i)] = eng.submit_step("m", f"c{i}", xs[t, i])
        got = {k: f.result(timeout=30.0) for k, f in futs.items()}
    assert got == ref
    snap = eng.telemetry.snapshot()
    assert snap["step_requests"] == n * T
    assert snap["step_batches"] < n * T           # actually batched
    assert snap["mean_step_batch"] > 1.0
    assert 0.0 < snap["step_occupancy"] <= 1.0
    # version attribution rides on step futures like predict futures
    assert all(f.model_version == forecaster.version
               for f in futs.values())


def test_engine_step_rejects_bad_submissions(registry):
    with ServingEngine(registry) as eng:
        with pytest.raises(ValueError):
            eng.submit_step("m", None, np.zeros(5, np.float32))
        with pytest.raises(ValueError):
            eng.submit_step("m", "c", np.zeros((3,), np.float32))
        with pytest.raises(KeyError):
            eng.submit_step("nope", "c", np.zeros(5, np.float32))
        # malformed history fails THIS submit, not the whole flush it
        # would later share with other clients' steps
        with pytest.raises(ValueError):
            eng.submit_step("m", "c", np.zeros(5, np.float32),
                            history=np.zeros((4, 6), np.float32))
        with pytest.raises(ValueError):
            eng.submit_step("m", "c", np.zeros(5, np.float32),
                            history=np.zeros((0, 5), np.float32))
        assert eng.step("m", "c", np.zeros(5, np.float32), timeout=10.0)


def test_engine_step_occupancy_counts_waves(registry, forecaster):
    """Regression: padded-slot accounting must reflect the follow-up
    waves duplicate client ids dispatch — 2 clients x 8 steps in one
    flush is 8 padded lane dispatches, not 2."""
    cfg = BatcherConfig(max_batch=16, max_wait_ms=40.0,
                        length_buckets=(20,))
    rng = np.random.default_rng(3)
    xs = rng.standard_normal((8, 2, 5)).astype(np.float32) * 0.02
    with ServingEngine(registry, cfg) as eng:
        eng.warmup("m", lengths=(20,))
        eng.telemetry.reset_clock()
        futs = [eng.submit_step("m", f"c{i}", xs[t, i])
                for t in range(8) for i in range(2)]
        for f in futs:
            f.result(timeout=30.0)
    snap = eng.telemetry.snapshot()
    assert snap["step_requests"] == 16
    W = forecaster.decode_width
    # every wave holds at most 2 real sessions in a W-wide lane
    # dispatch (the pre-fix accounting ignored waves and claimed 1.0)
    assert 0.0 < snap["step_occupancy"] <= 2 / W


def test_engine_step_recovers_evicted_session_via_history(registry,
                                                          forecaster):
    """A step arriving with history after its session was evicted from
    the engine cache replays the prefix — same numbers as an
    uninterrupted stream."""
    w = _windows(1, seed=17)[0]
    runner = RecurrentSessionRunner(forecaster,
                                    SessionCache(max_sessions=4))
    for t in range(CFG.window):
        want = runner.step("c", w[t])
    with ServingEngine(registry) as eng:
        half = CFG.window // 2
        for t in range(half):
            eng.step("m", "c", w[t], timeout=10.0)
        # simulate eviction: the session lives in a decode lane, so
        # spill it to the cache (the spill tier) before dropping it
        assert eng.spill_sessions() == 1
        assert eng.sessions.drop("c")
        for t in range(half, CFG.window):
            got = eng.step("m", "c", w[t], history=w[:t], timeout=10.0)
    assert got == want


# -- session cache ---------------------------------------------------------

def test_session_cache_lru_eviction():
    cache = SessionCache(max_sessions=2)
    cache.put("a", "carry-a", 8)
    cache.put("b", "carry-b", 8)
    assert cache.get("a") == "carry-a"         # refresh a; b is now LRU
    cache.put("c", "carry-c", 8)
    assert cache.get("b") is None              # evicted
    assert cache.get("a") == "carry-a"
    assert cache.get("c") == "carry-c"
    assert cache.evictions == 1
    assert cache.nbytes_in_use == 16


def test_session_cache_ttl_and_bytes():
    now = [0.0]
    cache = SessionCache(max_sessions=8, ttl_s=10.0, max_bytes=20,
                         clock=lambda: now[0])
    cache.put("a", "A", 8)
    now[0] = 5.0
    cache.put("b", "B", 8)
    now[0] = 12.0                              # a expired (idle 12s), b not
    assert cache.get("a") is None
    assert cache.get("b") == "B"
    cache.put("c", "C", 16)                    # 8 + 16 > 20 -> evict LRU (b)
    assert cache.get("b") is None
    assert cache.nbytes_in_use == 16


def test_oversize_carry_warns_and_surfaces_over_budget():
    """Regression: a single carry larger than max_bytes used to evict
    every other session and then sit over budget forever, silently. It
    still gets admitted (rejecting it would silently restart the
    client's stream), but now warns and surfaces the state in stats()."""
    cache = SessionCache(max_sessions=8, max_bytes=20)
    cache.put("a", "A", 8)
    assert cache.stats()["over_budget"] is False
    with pytest.warns(RuntimeWarning, match="over budget"):
        cache.put("big", "B", 64)
    st = cache.stats()
    assert st["over_budget"] is True
    assert st["oversize_admissions"] == 1
    assert cache.nbytes_in_use == 64 and len(cache) == 1
    # a later normal put reclaims the oversize entry via plain LRU: the
    # cache returns under budget (nothing "forever" about it any more)
    cache.put("c", "C", 8)
    assert cache.get("big") is None
    assert cache.stats()["over_budget"] is False
    assert cache.nbytes_in_use == 8


def test_session_carry_matches_full_window_recompute(forecaster):
    """Acceptance: serving a session incrementally through the cache is
    numerically identical to recomputing from the full window."""
    w = _windows(1)[0]                          # [20, 5]
    runner = RecurrentSessionRunner(forecaster, SessionCache(max_sessions=4))
    for t in range(CFG.window):
        y_inc, p_inc = runner.step("client", w[t])
    # full-window recompute through the same compiled step path (what a
    # cache miss executes): bitwise identical
    y_ref, p_ref, _ = forecaster.replay(w[None])
    assert y_inc == float(y_ref[0]) and p_inc == float(p_ref[0])
    # and equal to the batched scan apply to float32 resolution (XLA
    # fuses the full-sequence scan differently, so not bitwise)
    y_scan, _ = rnn_apply(forecaster.params, w[None], CFG)
    np.testing.assert_allclose(y_inc, float(y_scan[0]), atol=1e-6, rtol=0)
    # the session entered its device lane on the first step (one cache
    # miss) and stayed resident for the rest — the spill tier is never
    # touched again
    assert runner.resident_clients() == ["client"]
    assert runner.slot_inserts == 1 and runner.slot_spills == 0
    st = runner.cache.stats()
    assert st["misses"] == 1 and st["hits"] == 0


def test_session_eviction_recovers_via_history_replay(forecaster):
    """Evicting a session mid-stream must not change its predictions when
    the client supplies its window history on the miss."""
    w = _windows(1, seed=3)[0]
    runner = RecurrentSessionRunner(forecaster, SessionCache(max_sessions=4))
    for t in range(CFG.window):
        y_uninterrupted, _ = runner.step("c1", w[t])

    runner2 = RecurrentSessionRunner(forecaster, SessionCache(max_sessions=4))
    half = CFG.window // 2
    for t in range(half):
        runner2.step("c2", w[t])
    # simulate eviction of LIVE state: spill the lane to the cache,
    # then drop the cache entry
    assert runner2.spill(["c2"]) == 1
    assert runner2.cache.drop("c2")
    for t in range(half, CFG.window):
        y_resumed, _ = runner2.step("c2", w[t], history=w[:t])
    assert y_uninterrupted == y_resumed


def test_session_runner_on_miss_error(forecaster):
    runner = RecurrentSessionRunner(forecaster, SessionCache(max_sessions=2),
                                    on_miss="error")
    w = _windows(1)[0]
    with pytest.raises(KeyError):
        runner.step("evicted-client", w[0])            # miss, no history
    y, p = runner.step("evicted-client", w[5], history=w[:5])
    assert np.isfinite(y) and 0.0 <= p <= 1.0


def test_session_cache_telemetry_hit_rate(forecaster):
    tel = Telemetry()
    runner = RecurrentSessionRunner(
        forecaster, SessionCache(max_sessions=4, telemetry=tel))
    w = _windows(1)[0]
    for t in range(10):
        runner.step("c", w[t])
    assert tel.snapshot()["cache_hit_rate"] == pytest.approx(0.9)


# -- registry --------------------------------------------------------------

def test_registry_checkpoint_roundtrip(tmp_path, forecaster):
    reg = ModelRegistry()
    reg.register("paper", forecaster)
    path = str(tmp_path / "paper.npz")
    reg.save("paper", path)
    loaded = reg.load(path, key="paper-v2")
    assert "paper-v2" in reg
    assert loaded.cfg == forecaster.cfg
    assert loaded.tail == pytest.approx(forecaster.tail)
    assert loaded.eps == pytest.approx(forecaster.eps)
    w = _windows(3, seed=7)
    y0, p0 = forecaster.predict(w)
    y1, p1 = loaded.predict(w)
    np.testing.assert_array_equal(y0, y1)
    np.testing.assert_array_equal(p0, p1)


def test_registry_unknown_key():
    reg = ModelRegistry()
    with pytest.raises(KeyError):
        reg.get("missing")


def test_build_lstm_forecaster_is_calibrated():
    fc = build_lstm_forecaster(seed=0, n_days=120)
    assert fc.tail is not None and fc.tail["scale"] > 0
    y, p = fc.predict(_windows(2))
    assert y.shape == (2,) and p.shape == (2,)
    assert np.all((p >= 0) & (p <= 1))

"""Observability plane (ISSUE 6): per-request trace spans through the
serving stack (in-process AND stitched across the transport's process
boundary), dispatch accounting ("one fused dispatch per flush" asserted,
not trusted), telemetry time-series/history, and the metrics export
surface (Prometheus text, JSONL events, the stdlib HTTP endpoint)."""

import dataclasses
import json
import time
import urllib.request

import jax
import numpy as np
import pytest

from repro.kernels import dispatch
from repro.models.rnn import RNNConfig, init_rnn
from repro.obs import EventLog, MetricsServer, Tracer, now, render_prometheus
from repro.serving import (BatcherConfig, LSTMForecaster, ModelRegistry,
                           MultiProcessServingEngine, ServingEngine,
                           ShardedServingEngine, Telemetry)

CFG = RNNConfig(input_dim=3, hidden=8, num_layers=1, fc_dims=(4,),
                window=8, evl_head=True)
BCFG = BatcherConfig(max_batch=4, max_wait_ms=2.0, length_buckets=(8,))

# residual clock skew allowed between the two processes of a stitched
# trace (same machine, epoch-anchored perf_counter on both sides) plus
# the worker's result-serialization time
EPS_CROSS_PROCESS_S = 0.05


@pytest.fixture(scope="module")
def forecaster():
    fc = LSTMForecaster(cfg=CFG, params=init_rnn(jax.random.PRNGKey(0),
                                                 CFG))
    rng = np.random.default_rng(0)
    fc.calibrate(rng.standard_normal((64, CFG.window, 3)).astype(np.float32)
                 * 0.02)
    return fc


@pytest.fixture()
def registry(forecaster):
    reg = ModelRegistry()
    reg.register("m", forecaster)
    return reg


def _windows(n, t=CFG.window, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, t, 3)).astype(np.float32) * 0.02


def _wait(pred, timeout=5.0):
    deadline = time.perf_counter() + timeout
    while not pred():
        if time.perf_counter() > deadline:
            return False
        time.sleep(0.005)
    return True


# -- tracer unit behavior ---------------------------------------------------

def test_tracer_marks_chain_gapless():
    """Each mark records [t_last, now] and advances t_last, so chained
    spans cover the trace exactly (zero gaps, no epsilon needed)."""
    tracer = Tracer()
    ctx = tracer.start("op")
    for name in ("a", "b", "c"):
        ctx.mark(name)
    trace = ctx.finish()
    assert trace.status == "ok"
    assert trace.names() == ["a", "b", "c"]
    assert trace.gaps(0.0) == []
    spans = sorted(trace.spans, key=lambda s: s.t0)
    for prev, cur in zip(spans, spans[1:]):
        assert cur.t0 == prev.t1


def test_tracer_gaps_detects_uncovered_interval():
    tracer = Tracer()
    ctx = tracer.start("op")
    t = now()
    ctx.span("a", t, t + 1.0)
    ctx.span("b", t + 2.0, t + 3.0)        # hole in (t+1, t+2)
    trace = ctx.finish()
    gaps = trace.gaps(0.0)
    assert len(gaps) == 1
    assert gaps[0] == pytest.approx((t + 1.0, t + 2.0))


def test_tracer_disabled_returns_none_contexts():
    tracer = Tracer(enabled=False)
    assert tracer.start("op") is None
    assert tracer.adopt("some-id") is None
    assert tracer.stats()["started"] == 0


def test_tracer_completed_ring_is_bounded():
    tracer = Tracer(capacity=4)
    for i in range(10):
        tracer.start("op", meta={"i": i}).finish()
    done = tracer.traces()
    assert len(done) == 4
    assert [t.meta["i"] for t in done] == [6, 7, 8, 9]
    assert tracer.stats()["finished"] == 10


def test_tracer_abandoned_traces_do_not_leak():
    """The tracer keeps no registry of live traces — a trace lives only
    on its context, so an abandoned request's trace is simply garbage
    collected (nothing to evict, nothing to leak)."""
    import gc
    import weakref

    tracer = Tracer()
    ctxs = [tracer.start("op") for _ in range(12)]   # never finished
    refs = [weakref.ref(ctx.trace) for ctx in ctxs]
    del ctxs
    gc.collect()
    assert all(r() is None for r in refs)
    assert tracer.traces() == []                     # nothing reached the ring
    assert tracer.stats()["started"] == 12
    assert tracer.stats()["finished"] == 0


def test_tracer_export_makes_later_spans_noops():
    """The transport worker exports mid-flush (inside the set_result
    done-callback); the engine's post-set_result reply/finish must then
    be silently ignored."""
    tracer = Tracer()
    ctx = tracer.start("op")
    ctx.mark("work")
    spans = tracer.export(ctx)
    assert [s["name"] for s in spans] == ["work"]
    assert ctx.mark("reply") is None
    assert ctx.finish() is None
    assert tracer.traces() == []


def test_tracer_adopt_stitches_with_offset_sids():
    """adopt + add_spans reassemble one trace from two processes' spans
    with non-colliding span ids."""
    router, worker = Tracer(), Tracer()
    ctx = router.start("predict")
    ctx.mark("route")
    ctx.mark("submit")
    wctx = worker.adopt(ctx.trace_id, op="predict", t0=ctx.t_last,
                        parent=ctx.last_sid)
    wctx.mark("transport")
    wctx.mark("dispatch")
    shipped = worker.export(wctx)
    router.add_spans(ctx, shipped)
    ctx.t_last = shipped[-1]["t1"]
    ctx.mark("reply")
    trace = ctx.finish()
    assert trace.names() == ["route", "submit", "transport", "dispatch",
                             "reply"]
    assert trace.gaps(0.0) == []
    sids = [s.sid for s in trace.spans]
    assert len(sids) == len(set(sids))       # sid_base offset: no clash


# -- dispatch accounting ----------------------------------------------------

def test_dispatch_counting_inactive_is_default():
    dispatch.record("predict", batch=4, hidden=8)    # no collector: no-op
    with dispatch.counting() as counts:
        dispatch.record("predict", batch=4, hidden=8)
        dispatch.record("predict", batch=4, hidden=8)
        dispatch.record("replay", batch=8, hidden=8, impl="xla")
    assert counts["predict"] == 2
    assert counts["replay"] == 1
    assert counts.total() == 3
    # keys carry (backend, op, impl, shape)
    (key, n), = [(k, v) for k, v in counts.counts.items()
                 if k[1] == "replay"]
    backend, op, impl, shape = key
    assert backend == jax.default_backend()
    assert impl == "xla" and shape == (8, 8)
    # collector uninstalled on exit
    dispatch.record("predict", batch=4, hidden=8)
    assert counts["predict"] == 2


def test_forecaster_dispatch_counts(forecaster):
    """The performance claims of PRs 4-5, asserted: a batched step_many
    is ONE fused dispatch per decode-lane chunk, a replay is ONE scan
    dispatch, a predict is ONE fused dispatch."""
    W = forecaster.decode_width
    carries = [forecaster.init_carry(1) for _ in range(W)]
    xs = np.zeros((W, CFG.input_dim), np.float32)
    forecaster.step_many(xs, carries)                    # warm
    with dispatch.counting() as counts:
        forecaster.step_many(xs, [forecaster.init_carry(1)
                                  for _ in range(W)])
    assert counts["decode_many"] == 1                    # one lane chunk
    assert counts.total() == 1                           # and nothing else

    window = np.zeros((1, CFG.window, CFG.input_dim), np.float32)
    forecaster.replay(window)                            # warm
    with dispatch.counting() as counts:
        forecaster.replay(window)
    assert counts["decode_replay"] == 1                  # one scan
    assert counts.total() == 1

    with dispatch.counting() as counts:
        forecaster.predict(_windows(4, seed=3))
    assert counts["predict"] == 1


def test_engine_step_flush_is_one_fused_dispatch(registry):
    """Tier-1 guard on the slots decode path (ISSUE 8): once sessions
    are lane-resident, a step flush is exactly ONE fused
    ``slots_generate`` dispatch — ZERO host gather/scatter ops
    (``decode_many``), zero per-session steps, zero inserts."""
    clients = [f"client-{i}" for i in range(BCFG.max_batch)]
    x = np.zeros(CFG.input_dim, np.float32)
    with ServingEngine(registry, BCFG) as eng:
        eng.warmup("m", lengths=(CFG.window,))
        # round 1: sessions enter lanes (one slots_insert each)
        for f in [eng.submit_step("m", c, x) for c in clients]:
            f.result(timeout=10.0)
        flushes_before = eng.telemetry.step_batches
        # round 2 = steady state: everything is already resident
        with dispatch.counting() as counts:
            futs = [eng.submit_step("m", c, x) for c in clients]
            for f in futs:
                f.result(timeout=10.0)
        flushes = eng.telemetry.step_batches - flushes_before
    assert flushes >= 1
    assert counts["slots_generate"] == flushes
    assert counts["decode_many"] == 0       # no host gather/scatter
    assert counts["decode_step"] == 0       # nothing went per-session
    assert counts["slots_insert"] == 0      # no lane churn at steady state
    assert counts["decode_replay"] == 0     # no cache miss hit replay
    assert counts.total() == flushes        # and nothing else at all


def test_engine_step_gather_scatter_path_when_slots_disabled(registry):
    """decode_slots=0 keeps the PR-5 gather/scatter contract: one
    decode_many dispatch per flush wave."""
    cfg = dataclasses.replace(BCFG, decode_slots=0)
    with ServingEngine(registry, cfg) as eng:
        eng.warmup("m", lengths=(CFG.window,))
        with dispatch.counting() as counts:
            futs = [eng.submit_step("m", f"gs-{i}",
                                    np.zeros(CFG.input_dim, np.float32))
                    for i in range(cfg.max_batch)]
            for f in futs:
                f.result(timeout=10.0)
    flushes = eng.telemetry.step_batches
    assert flushes >= 1
    assert counts["decode_many"] == flushes
    assert counts["slots_generate"] == 0


# -- traces through the serving stack --------------------------------------

def test_engine_trace_covers_submit_to_reply(registry):
    tracer = Tracer()
    with ServingEngine(registry, BCFG, tracer=tracer) as eng:
        eng.warmup("m", lengths=(CFG.window,))
        fut = eng.submit("m", _windows(1, seed=2)[0], client_id="alice")
        fut.result(timeout=10.0)
    assert _wait(lambda: len(tracer.traces()) == 1)
    trace = tracer.traces()[0]
    assert trace.status == "ok"
    assert trace.names() == ["submit", "queue", "gather", "flush",
                             "dispatch", "scatter", "reply"]
    # chained spans: gapless with NO epsilon (single process)
    assert trace.gaps(0.0) == []
    flush = trace.span("flush")
    for inner in ("gather", "dispatch", "scatter"):
        s = trace.span(inner)
        assert flush.t0 <= s.t0 and s.t1 <= flush.t1
    assert trace.duration > 0


def test_engine_step_traces(registry):
    tracer = Tracer()
    with ServingEngine(registry, BCFG, tracer=tracer) as eng:
        eng.warmup("m", lengths=(CFG.window,))
        futs = [eng.submit_step("m", f"c{i}",
                                np.zeros(CFG.input_dim, np.float32))
                for i in range(3)]
        for f in futs:
            f.result(timeout=10.0)
    assert _wait(lambda: len(tracer.traces()) == 3)
    for trace in tracer.traces():
        assert trace.op == "step"
        assert trace.names() == ["submit", "queue", "dispatch", "flush",
                                 "scatter", "reply"]
        assert trace.gaps(0.0) == []


def test_engine_trace_error_status(registry):
    """A synchronously rejected submit finishes the trace with status
    'error' instead of dangling open."""
    tracer = Tracer()
    with ServingEngine(registry, BCFG, tracer=tracer) as eng:
        with pytest.raises(KeyError):
            eng.submit("no-such-model", _windows(1)[0])
        with pytest.raises(ValueError):
            eng.submit_step("m", "alice", np.zeros(99, np.float32))
    assert len(tracer.traces()) == 2
    assert [t.status for t in tracer.traces()] == ["error", "error"]
    assert tracer.stats()["finished"] == tracer.stats()["started"] == 2


def test_engine_tracing_disabled_records_nothing(registry):
    tracer = Tracer(enabled=False)
    with ServingEngine(registry, BCFG, tracer=tracer) as eng:
        eng.warmup("m", lengths=(CFG.window,))
        eng.submit("m", _windows(1)[0]).result(timeout=10.0)
    assert tracer.traces() == []
    assert tracer.stats()["started"] == 0


def test_mesh_trace_has_route_span(registry):
    tracer = Tracer()
    with ShardedServingEngine(registry, BCFG, n_shards=2,
                              tracer=tracer) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        futs = [mesh.submit("m", w, client_id=f"c{i}")
                for i, w in enumerate(_windows(6, seed=4))]
        for f in futs:
            f.result(timeout=10.0)
    assert _wait(lambda: len(tracer.traces()) == 6)
    for trace in tracer.traces():
        assert trace.names()[0] == "route"
        assert trace.names()[-1] == "reply"
        assert trace.gaps(0.0) == []
        assert trace.span("route").meta["shard"] in (0, 1)


def test_cross_process_stitched_trace(forecaster):
    """ISSUE 6 acceptance: a request through the multi-process mesh
    yields ONE trace whose spans cover submit -> reply across the
    process boundary, with no gaps beyond the clock-skew epsilon."""
    reg = ModelRegistry()
    reg.register("m", forecaster)
    tracer = Tracer()
    with MultiProcessServingEngine(reg, BCFG, n_shards=1,
                                   tracer=tracer) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        fut = mesh.submit("m", _windows(1, seed=5)[0], client_id="alice")
        fut.result(timeout=30.0)
        # the synchronous step path stitches too
        y, p = mesh.step("m", "alice", np.zeros(CFG.input_dim, np.float32),
                         history=np.zeros((2, CFG.input_dim), np.float32))
    traces = {t.op: t for t in tracer.traces()}
    assert set(traces) == {"predict", "step"}

    trace = traces["predict"]
    assert trace.status == "ok"
    names = trace.names()
    # router half ... worker half ... final reply, one stitched trace
    assert names[:3] == ["route", "submit", "transport"]
    assert names[-1] == "reply"
    for worker_span in ("queue", "gather", "dispatch", "scatter"):
        assert worker_span in names
    assert trace.gaps(EPS_CROSS_PROCESS_S) == []
    sids = [s.sid for s in trace.spans]
    assert len(sids) == len(set(sids))       # router/worker sids disjoint
    # covers submit -> reply: the reply span is the last thing recorded
    reply = trace.span("reply")
    assert reply.t1 == trace.t_end

    strace = traces["step"]
    assert "transport" in strace.names() and "dispatch" in strace.names()
    assert strace.gaps(EPS_CROSS_PROCESS_S) == []


# -- telemetry: batch reservoir + history ring ------------------------------

def test_snapshot_exposes_batch_percentiles():
    """Regression for the dead ``_batch_sizes`` reservoir: recorded
    batch sizes must surface as batch_p50/batch_p95."""
    tel = Telemetry()
    for n in (1, 2, 2, 3, 8):
        tel.record_batch(n, 8)
    snap = tel.snapshot()
    assert snap["batch_p50"] == 2.0
    assert snap["batch_p95"] == 8.0
    # merge pools the reservoirs across shards
    other = Telemetry()
    other.record_batch(4, 8)
    merged = Telemetry.merge([tel, other])
    assert merged["batch_p50"] in (2.0, 3.0)
    assert merged["batch_p95"] == 8.0


def test_percentiles_single_sort_matches_per_call():
    from repro.serving.telemetry import _percentile, _percentiles

    rng = np.random.default_rng(1)
    data = list(rng.standard_normal(257))
    ps = (50, 95, 99)
    assert _percentiles(data, ps) == [_percentile(data, p) for p in ps]
    assert _percentiles([], ps) == [0.0, 0.0, 0.0]


def test_history_ring_and_sampler():
    tel = Telemetry()
    tel.record_request(0.01)
    snap = tel.sample()
    assert "ts" in snap
    assert tel.history() == [snap]
    tel.start_sampler(interval_s=0.02)
    tel.start_sampler(interval_s=0.02)       # idempotent
    assert _wait(lambda: len(tel.history()) >= 3)
    tel.stop_sampler()
    n = len(tel.history())
    time.sleep(0.06)
    assert len(tel.history()) == n           # stopped means stopped
    assert len(tel.history(2)) == 2
    # bounded ring
    for _ in range(Telemetry.HISTORY_CAPACITY + 10):
        tel.sample()
    assert len(tel.history()) == Telemetry.HISTORY_CAPACITY


# -- export surface ---------------------------------------------------------

def test_render_prometheus_scalars_and_labels():
    text = render_prometheus(
        {"requests": 10, "p95_ms": 1.5, "enabled": True,
         "requests_by_version": {1: 7, 2: 3},
         "requests_by_shard": [6, 4],
         "note": "skipped"},
        prefix="repro", labels={"shard": "fleet"})
    assert 'repro_requests{shard="fleet"} 10' in text
    assert 'repro_p95_ms{shard="fleet"} 1.5' in text
    assert 'repro_enabled{shard="fleet"} 1' in text
    assert 'repro_requests_by_version{shard="fleet",version="1"} 7' in text
    assert 'repro_requests_by_shard{shard="fleet",shard="0"} 6' not in text
    assert 'shard="0"' in text               # list indexed by label
    assert "# TYPE repro_requests gauge" in text
    assert "note" not in text                # non-numeric skipped
    assert text.endswith("\n")


def test_event_log_ring_and_jsonl(tmp_path):
    path = tmp_path / "events.jsonl"
    log = EventLog(capacity=4, path=str(path))
    for i in range(6):
        log.log("tick", i=i)
    assert len(log) == 4                     # ring bounded
    assert [e["i"] for e in log.events()] == [2, 3, 4, 5]
    log.close()
    lines = [json.loads(line) for line in
             path.read_text().strip().splitlines()]
    assert [e["i"] for e in lines] == list(range(6))   # file keeps all
    assert all(e["kind"] == "tick" and "ts" in e for e in lines)


def test_metrics_server_endpoints(registry):
    tracer = Tracer()
    events = EventLog()
    events.log("phase", name="test")
    with ServingEngine(registry, BCFG, tracer=tracer) as eng:
        eng.warmup("m", lengths=(CFG.window,))
        eng.submit("m", _windows(1)[0]).result(timeout=10.0)
        with MetricsServer(eng.telemetry.snapshot, port=0,
                           tracer=tracer, events=events,
                           history_fn=eng.telemetry.history) as srv:
            def get(route):
                with urllib.request.urlopen(f"{srv.url}{route}",
                                            timeout=5.0) as r:
                    return r.read().decode()

            text = get("/metrics")
            assert "repro_requests 1" in text
            snap = json.loads(get("/metrics.json"))
            assert snap["requests"] == 1
            eng.telemetry.sample()
            hist = json.loads(get("/history"))
            assert len(hist) == 1 and hist[0]["requests"] == 1
            assert _wait(lambda: len(tracer.traces()) == 1)
            traces = json.loads(get("/traces"))
            assert len(traces) == 1
            assert [s["name"] for s in traces[0]["spans"]][0] == "submit"
            ev = [json.loads(line) for line in
                  get("/events").strip().splitlines()]
            assert ev[0]["name"] == "test"
            with pytest.raises(urllib.error.HTTPError):
                get("/nope")

"""Sharded serving mesh: routing correctness and affinity, fleet-wide
swap propagation under the staleness skew bound, sharded session cache
semantics, and cross-shard telemetry merge."""

import threading
import time
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.models.rnn import RNNConfig, init_rnn
from repro.serving import (BatcherConfig, LSTMForecaster, ModelRegistry,
                           ServingEngine, ShardSwarm, ShardedServingEngine,
                           ShardedSessionCache, Telemetry, WeightPublisher)

CFG = RNNConfig(input_dim=5, hidden=16, num_layers=2, fc_dims=(8, 4),
                window=20, evl_head=True)


@pytest.fixture(scope="module")
def forecaster():
    fc = LSTMForecaster(cfg=CFG, params=init_rnn(jax.random.PRNGKey(0), CFG))
    rng = np.random.default_rng(0)
    fc.calibrate(rng.standard_normal((64, CFG.window, 5)).astype(np.float32)
                 * 0.02)
    return fc


def _windows(n, t=20, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, t, 5)).astype(np.float32) * 0.02


def _mesh(forecaster, n_shards=3, **kw):
    reg = ModelRegistry()
    reg.register("m", forecaster)
    return ShardedServingEngine(reg, BatcherConfig(
        max_batch=4, max_wait_ms=2.0, length_buckets=(CFG.window,)),
        n_shards=n_shards, **kw)


# -- mesh serving ----------------------------------------------------------

def test_mesh_matches_single_engine(forecaster):
    """The mesh must produce the single engine's numbers (same weights;
    different micro-batch tilings allow float32-ulp differences only)."""
    wins = _windows(12)
    reg = ModelRegistry()
    reg.register("m", forecaster)
    cfg = BatcherConfig(max_batch=4, max_wait_ms=2.0,
                        length_buckets=(CFG.window,))
    with ServingEngine(reg, cfg) as eng:
        ref = [eng.predict("m", w, timeout=30.0) for w in wins]
    with _mesh(forecaster) as mesh:
        futs = [mesh.submit("m", w, client_id=f"c{i}")
                for i, w in enumerate(wins)]
        got = [f.result(timeout=30.0) for f in futs]
    np.testing.assert_allclose([y for y, _ in got], [y for y, _ in ref],
                               atol=1e-7, rtol=1e-6)
    np.testing.assert_allclose([p for _, p in got], [p for _, p in ref],
                               atol=1e-7, rtol=1e-6)


def test_mesh_client_affinity(forecaster):
    """Every request of one client lands on the same shard."""
    with _mesh(forecaster) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        mesh.reset_clock()
        sid = mesh.shard_for("sticky-client")
        for w in _windows(6, seed=1):
            mesh.predict("m", w, client_id="sticky-client", timeout=30.0)
        counts = [tel.requests for tel in mesh.shard_telemetries]
    assert counts[sid] == 6
    assert sum(counts) == 6
    assert mesh.shard_for("sticky-client") == sid     # still stable


def test_mesh_anonymous_requests_spread(forecaster):
    """Anonymous submits round-robin their (model, bucket) group: an
    even burst splits exactly evenly across shards."""
    with _mesh(forecaster, n_shards=2) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        mesh.reset_clock()
        futs = [mesh.submit("m", w) for w in _windows(32, seed=2)]
        for f in futs:
            f.result(timeout=30.0)
        counts = [tel.requests for tel in mesh.shard_telemetries]
    assert counts == [16, 16]


def test_mesh_rejects_router_mutation_without_worker(forecaster):
    """Membership changes go through add_shard/remove_shard; mutating
    the router directly leaves a shard id with no worker behind it,
    which must fail loudly instead of mis-routing."""
    with _mesh(forecaster, n_shards=2) as mesh:
        mesh.router.add_shard(7)
        bad = next(cid for cid in (f"c{i}" for i in range(64))
                   if mesh.router.shard_for(cid) == 7)
        with pytest.raises(KeyError):
            mesh.submit("m", _windows(1)[0], client_id=bad)


# -- live membership -------------------------------------------------------

def test_mesh_add_shard_serves_new_clients(forecaster):
    """A joining shard pulls weights + warms BEFORE taking traffic, then
    serves exactly the clients the rendezvous hash moves to it."""
    with _mesh(forecaster, n_shards=2) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        before = {f"c{i}": mesh.shard_for(f"c{i}") for i in range(64)}
        sid = mesh.add_shard()
        assert sid == 2 and sorted(mesh.shards) == [0, 1, 2]
        # the new replica already hosts the model at the primary version
        vec = mesh.version_vector("m")
        assert vec[sid] == vec["primary"]
        # minimal disruption: clients either stay put or move to the
        # new shard
        moved = []
        for cid, old in before.items():
            now = mesh.shard_for(cid)
            assert now in (old, sid)
            if now == sid:
                moved.append(cid)
        assert moved                      # 64 clients: some must move
        mesh.reset_clock()
        for cid in moved[:4]:
            mesh.predict("m", _windows(1)[0], client_id=cid, timeout=30.0)
        assert mesh.shards[sid].telemetry.requests == len(moved[:4])


def test_mesh_remove_shard_drains_and_rehomes(forecaster):
    """Removing a shard mid-traffic: queued requests complete (zero
    drops), only the departing shard's clients are re-homed."""
    with _mesh(forecaster, n_shards=3) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        before = {f"c{i}": mesh.shard_for(f"c{i}") for i in range(48)}
        futs = [mesh.submit("m", w, client_id=f"c{i}")
                for i, w in enumerate(_windows(48, seed=3))]
        victim = 1
        mesh.remove_shard(victim)
        results = [f.result(timeout=30.0) for f in futs]   # none dropped
        assert len(results) == 48
        assert all(np.isfinite(y) and 0.0 <= p <= 1.0 for y, p in results)
        for cid, old in before.items():
            now = mesh.shard_for(cid)
            if old != victim:
                assert now == old         # survivors keep their clients
            else:
                assert now != victim
        # more traffic serves fine on the shrunken mesh
        assert mesh.predict("m", _windows(1)[0], client_id="c0",
                            timeout=30.0)
        mesh.remove_shard(mesh.shard_ids[0])      # down to one shard
        with pytest.raises(ValueError):
            mesh.remove_shard(mesh.shard_ids[0])  # never below one


def test_mesh_membership_migrates_session_carries(forecaster):
    """Session caches attached via ``session_cache()`` follow membership
    changes: a departing shard's clients keep their carries (migrated to
    the new owners), unmoved clients are untouched."""
    from repro.serving import RecurrentSessionRunner

    with _mesh(forecaster, n_shards=3) as mesh:
        cache = mesh.session_cache(max_sessions=64)
        runner = RecurrentSessionRunner(forecaster, cache)
        w = _windows(8, seed=9)
        half = CFG.window // 2
        for c in range(8):
            for t in range(half):
                runner.step(f"s{c}", w[c][t])
        owners = {f"s{c}": cache.shard_for(f"s{c}") for c in range(8)}
        victim = owners["s0"]
        mesh.remove_shard(victim)
        # every session survived the membership change, on its new owner
        for c in range(8):
            assert f"s{c}" in cache
            assert cache.shard_for(f"s{c}") == (
                owners[f"s{c}"] if owners[f"s{c}"] != victim
                else cache.shard_for(f"s{c}"))
        # streams continue bitwise-uninterrupted (carries moved, not
        # rebuilt): finish each stream and compare to a clean replay
        finals = {}
        for c in range(8):
            for t in range(half, CFG.window):
                finals[c] = runner.step(f"s{c}", w[c][t])
        for c in range(8):
            y_ref, p_ref, _ = forecaster.replay(w[c][None])
            assert finals[c] == (float(y_ref[0]), float(p_ref[0]))


def test_zoo_forecaster_with_params_shares_compiled_forward():
    """The zoo hot-swap constructor must not rebuild/re-jit the forward
    (a swarm pull would otherwise retrace per shard per publish)."""
    from repro.serving import build_zoo_forecaster

    fc = build_zoo_forecaster("qwen1.5-4b", calibrate_batch=0)
    clone = fc.with_params(fc.params)
    assert clone is not fc
    assert clone._fwd is fc._fwd and clone._model is fc._model
    assert clone.version == 0 and clone.published_at is None


def test_mesh_rejects_bad_submissions(forecaster):
    with _mesh(forecaster) as mesh:
        with pytest.raises(KeyError):
            mesh.submit("nope", _windows(1)[0])
        with pytest.raises(ValueError):
            mesh.submit("m", np.zeros((20,), np.float32), client_id="c")


# -- swap propagation ------------------------------------------------------

def _stub(tag):
    """Stampable stand-in forecaster (no params -> reference pulls)."""
    return SimpleNamespace(tag=tag)


def test_swarm_seeds_replicas_and_registers_through():
    primary = ModelRegistry()
    primary.register("a", _stub("a1"))
    swarm = ShardSwarm(3, primary=primary)
    for sid in range(3):
        assert swarm.registry_for(sid).get("a").tag == "a1"
    swarm.register("b", _stub("b1"))
    for sid in range(3):
        assert swarm.registry_for(sid).get("b").tag == "b1"


def test_swarm_bounded_staleness_and_version_skip():
    swarm = ShardSwarm(2, max_skew=2)
    swarm.register("m", _stub("v1"))
    assert swarm.version_vector("m") == {"primary": 1, 0: 1, 1: 1}
    # v2, v3: within the bound — replicas may (and do) skip them
    swarm.swap("m", _stub("v2"))
    swarm.swap("m", _stub("v3"))
    vec = swarm.version_vector("m")
    assert vec["primary"] == 3 and vec[0] == 1 and vec[1] == 1
    # v4 blows the bound for v1 replicas: they pull the LATEST (v4),
    # never serving v2/v3 — that's the amortization bounded skew buys
    swarm.swap("m", _stub("v4"))
    vec = swarm.version_vector("m")
    assert vec == {"primary": 4, 0: 4, 1: 4}
    assert swarm.staleness("m") == 0 and swarm.skew("m") == 0


def test_swarm_max_skew_zero_is_lockstep():
    swarm = ShardSwarm(3, max_skew=0)
    swarm.register("m", _stub("v1"))
    for i in range(2, 6):
        swarm.swap("m", _stub(f"v{i}"))
        vec = swarm.version_vector("m")
        assert set(vec.values()) == {i}, vec


def test_swarm_propagate_converges_and_counts_pulls():
    swarm = ShardSwarm(2, max_skew=5)
    swarm.register("m", _stub("v1"))
    for i in range(2, 5):
        swarm.swap("m", _stub(f"v{i}"))
    assert swarm.staleness("m") == 3          # bound not hit: replicas lag
    pulled = swarm.propagate("m")
    assert pulled == 2
    assert swarm.version_vector("m") == {"primary": 4, 0: 4, 1: 4}


def test_swarm_direct_primary_publish_propagates():
    """Publishes made against the primary registry itself (not the
    facade) reach the replicas via the subscription callback."""
    primary = ModelRegistry()
    swarm = ShardSwarm(2, primary=primary, max_skew=0)
    primary.register("m", _stub("v1"))
    assert swarm.version_vector("m") == {"primary": 1, 0: 1, 1: 1}
    primary.swap("m", _stub("v2"))
    assert swarm.version_vector("m") == {"primary": 2, 0: 2, 1: 2}


def test_swarm_device_transfer_preserves_predictions(forecaster):
    swarm = ShardSwarm(2, max_skew=0, transfer="device")
    swarm.register("m", forecaster)
    w = _windows(3, seed=5)
    y_ref, p_ref = forecaster.predict(w)
    for sid in range(2):
        replica_fc = swarm.registry_for(sid).get("m")
        assert replica_fc is not forecaster     # per-shard clone
        y, p = replica_fc.predict(w)
        np.testing.assert_allclose(y, y_ref, atol=1e-7, rtol=1e-6)
        np.testing.assert_allclose(p, p_ref, atol=1e-7, rtol=1e-6)
    assert swarm.bytes_pulled > 0


def test_swarm_skew_bound_holds_under_concurrent_publishes():
    """A publish storm on one thread, an observer on another: every
    atomically-sampled version vector respects max_skew."""
    swarm = ShardSwarm(3, max_skew=1)
    swarm.register("m", _stub("v1"))
    stop = threading.Event()
    violations = []

    def observer() -> None:
        while not stop.is_set():
            vec = swarm.version_vector("m")
            lag = vec["primary"] - min(v for k, v in vec.items()
                                       if k != "primary")
            if lag > 1:
                violations.append(vec)

    t = threading.Thread(target=observer)
    t.start()
    try:
        for i in range(2, 60):
            swarm.swap("m", _stub(f"v{i}"))
    finally:
        stop.set()
        t.join()
    assert not violations, violations[:3]


def test_weight_publisher_into_swarm(forecaster):
    """The PR-2 publisher works unchanged against the swarm facade."""
    swarm = ShardSwarm(2, max_skew=0)
    pub = WeightPublisher(swarm, "m", template=forecaster)
    v1 = pub.publish(forecaster.params)
    v2 = pub.publish(jax.tree.map(lambda a: a * 1.01, forecaster.params))
    assert (v1, v2) == (1, 2)
    assert swarm.version_vector("m") == {"primary": 2, 0: 2, 1: 2}
    # each replica serves the published weights
    y0, _ = swarm.registry_for(0).get("m").predict(_windows(2, seed=6))
    y1, _ = swarm.registry_for(1).get("m").predict(_windows(2, seed=6))
    np.testing.assert_array_equal(y0, y1)


def test_mesh_swap_storm_zero_drops_full_attribution(forecaster):
    """Traffic over the mesh while a publisher storms weight versions:
    nothing dropped, every request attributed to some version, skew
    bound held throughout."""
    with _mesh(forecaster, n_shards=2, max_skew=1) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        mesh.reset_clock()
        pub = WeightPublisher(mesh.swarm, "m", template=forecaster)
        stop = threading.Event()

        def storm() -> None:
            i = 0
            while not stop.is_set():
                pub.publish(jax.tree.map(
                    lambda a, s=1.0 + 0.01 * (i % 3): a * s,
                    forecaster.params))
                i += 1
                time.sleep(0.002)

        t = threading.Thread(target=storm)
        t.start()
        try:
            wins = _windows(48, seed=7)
            futs = [mesh.submit("m", w, client_id=f"c{i % 9}")
                    for i, w in enumerate(wins)]
            results = [f.result(timeout=30.0) for f in futs]
        finally:
            stop.set()
            t.join()
        assert mesh.swarm.staleness("m") <= 1
        snap = mesh.snapshot()
    assert len(results) == 48
    assert all(np.isfinite(y) and 0.0 <= p <= 1.0 for y, p in results)
    assert snap["requests"] == 48
    assert sum(snap["requests_by_version"].values()) == 48
    assert snap["pulls"] >= 2                 # propagation actually ran


def test_swarm_detach_stops_fanout_attach_reconciles():
    """A detached swarm ignores direct primary publishes (a stopped
    mesh must not keep pulling); attach catches the replicas up; facade
    publishes propagate even while detached."""
    primary = ModelRegistry()
    swarm = ShardSwarm(2, primary=primary, max_skew=0)
    swarm.register("m", _stub("v1"))
    swarm.detach()
    primary.swap("m", _stub("v2"))           # direct: unobserved
    vec = swarm.version_vector("m")
    assert vec == {"primary": 2, 0: 1, 1: 1}
    swarm.swap("m", _stub("v3"))             # facade: still propagates
    assert swarm.version_vector("m") == {"primary": 3, 0: 3, 1: 3}
    primary.swap("m", _stub("v4"))
    swarm.attach()                           # reconciles missed publishes
    assert swarm.version_vector("m") == {"primary": 4, 0: 4, 1: 4}


def test_stopped_mesh_does_not_pull(forecaster):
    reg = ModelRegistry()
    reg.register("m", forecaster)
    mesh = _mesh(forecaster, n_shards=2, max_skew=0)
    primary = mesh.swarm.primary
    with mesh:
        v_live = primary.swap("m", forecaster.with_params(forecaster.params))
        assert mesh.version_vector("m")[0] == v_live
    pulls_when_stopped = mesh.swarm.pulls
    primary.swap("m", forecaster.with_params(forecaster.params))
    assert mesh.swarm.pulls == pulls_when_stopped      # no dead fan-out
    with mesh:                               # restart reconciles
        assert mesh.version_vector("m")[0] == primary.version("m")


def test_calibration_flip_reuses_compiled_program():
    """Calibrating (tail None -> fitted) must not compile a new serving
    program: uncalibrated and calibrated predicts share one jit entry
    (the alert head's activity is a traced flag)."""
    fc = LSTMForecaster(cfg=CFG, params=init_rnn(jax.random.PRNGKey(1),
                                                 CFG))
    w = _windows(4, seed=11)
    y0, p0 = fc.predict(w)                    # compiles, tail inactive
    predict_jit = fc._fns["predict"]
    size_before = (predict_jit._cache_size()
                   if hasattr(predict_jit, "_cache_size") else None)
    fc.calibrate(w)
    y1, p1 = fc.predict(w)                    # same program, tail active
    np.testing.assert_array_equal(y0, y1)     # forecast unchanged by tail
    if size_before is not None:
        assert predict_jit._cache_size() == size_before


# -- sharded session cache -------------------------------------------------

def test_sharded_session_cache_respects_fleet_budget():
    cache = ShardedSessionCache(n_shards=3, max_sessions=4)
    assert [cache.shards[i].max_sessions for i in range(3)] == [2, 1, 1]
    for i in range(32):                       # hammer one fleet of puts
        cache.put(f"c{i}", i, 8)
    assert len(cache) <= 4                    # never over the fleet budget
    with pytest.raises(ValueError):
        ShardedSessionCache(n_shards=4, max_sessions=3)

def test_sharded_session_cache_routes_and_aggregates():
    cache = ShardedSessionCache(n_shards=2, max_sessions=8)
    for i in range(6):
        cache.put(f"client-{i}", f"carry-{i}", 8, version=i)
    assert len(cache) == 6
    for i in range(6):
        assert f"client-{i}" in cache
        assert cache.get_entry(f"client-{i}") == (f"carry-{i}", i)
        # the entry lives on exactly the routed shard
        sid = cache.shard_for(f"client-{i}")
        assert f"client-{i}" in cache.shards[sid]
        assert f"client-{i}" not in cache.shards[1 - sid]
    assert cache.drop("client-0") and "client-0" not in cache
    st = cache.stats()
    assert st["sessions"] == 5 and st["shards"] == 2
    assert sum(st["sessions_by_shard"]) == 5
    assert st["hits"] == cache.hits


def test_sharded_session_cache_evicts_shard_locally():
    cache = ShardedSessionCache(n_shards=2, max_sessions=4)  # 2 per shard
    on_zero = [f"k{i}" for i in range(64) if cache.shard_for(f"k{i}") == 0]
    for k in on_zero[:3]:
        cache.put(k, k, 8)
    assert len(cache.shards[0]) == 2           # shard-local LRU evicted
    assert len(cache.shards[1]) == 0
    assert cache.evictions == 1


def test_mesh_session_cache_shares_router(forecaster):
    mesh = _mesh(forecaster, n_shards=3)
    cache = mesh.session_cache(max_sessions=12)
    for cid in ("a", "b", "c", "zz-9"):
        assert cache.shard_for(cid) == mesh.shard_for(cid)


def test_sharded_cache_works_with_session_runner(forecaster):
    from repro.serving import RecurrentSessionRunner

    runner = RecurrentSessionRunner(
        forecaster, ShardedSessionCache(n_shards=2, max_sessions=8))
    w = _windows(1, seed=8)[0]
    for t in range(CFG.window):
        y_sharded, p_sharded = runner.step("client", w[t])
    y_ref, p_ref, _ = forecaster.replay(w[None])
    assert y_sharded == float(y_ref[0]) and p_sharded == float(p_ref[0])


# -- batched decode over the mesh ------------------------------------------

def test_mesh_streaming_steps_affine_and_batched(forecaster):
    """Streaming steps route to the client's owning shard, flush as
    fused batches there, and match the single-engine decode path
    bitwise."""
    n, T = 8, 10
    rng = np.random.default_rng(44)
    xs = rng.standard_normal((T, n, 5)).astype(np.float32) * 0.02
    reg = ModelRegistry()
    reg.register("m", forecaster)
    cfg = BatcherConfig(max_batch=16, max_wait_ms=2.0,
                        length_buckets=(CFG.window,))
    ref = {}
    with ServingEngine(reg, cfg) as eng:
        for t in range(T):
            for i in range(n):
                ref[(t, i)] = eng.step("m", f"c{i}", xs[t, i],
                                       timeout=30.0)
    with _mesh(forecaster) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        mesh.reset_clock()
        futs = {}
        for t in range(T):
            for i in range(n):
                futs[(t, i)] = mesh.submit_step("m", f"c{i}", xs[t, i])
            for i in range(n):
                futs[(t, i)].result(timeout=30.0)
        got = {k: f.result(timeout=30.0) for k, f in futs.items()}
        # session affinity: each client's state is resident on exactly
        # the shard the router names (in that shard's decode lanes,
        # spilling to its session cache under pressure)
        for i in range(n):
            sid = mesh.shard_for(f"c{i}")
            assert f"c{i}" in mesh.shards[sid].session_clients()
        snap = mesh.snapshot()
    assert got == ref
    assert snap["step_requests"] == n * T
    assert snap["step_batches"] < n * T            # fused flushes


def test_mesh_remove_shard_migrates_streaming_sessions(forecaster):
    """Removing a shard mid-stream re-homes its engine-resident session
    carries: clients keep streaming with NO change in their numbers."""
    n, T = 6, 12
    rng = np.random.default_rng(45)
    xs = rng.standard_normal((T, n, 5)).astype(np.float32) * 0.02
    reg = ModelRegistry()
    reg.register("m", forecaster)
    cfg = BatcherConfig(max_batch=16, max_wait_ms=2.0,
                        length_buckets=(CFG.window,))
    ref = {}
    with ServingEngine(reg, cfg) as eng:
        for t in range(T):
            for i in range(n):
                ref[i] = eng.step("m", f"c{i}", xs[t, i], timeout=30.0)
    with _mesh(forecaster) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        half = T // 2
        for t in range(half):
            for i in range(n):
                mesh.step("m", f"c{i}", xs[t, i], timeout=30.0)
        victim = mesh.shard_for("c0")  # at least c0's carry must move
        mesh.remove_shard(victim)
        got = {}
        for t in range(half, T):
            for i in range(n):
                got[i] = mesh.step("m", f"c{i}", xs[t, i], timeout=30.0)
    assert got == ref                  # bitwise: carries moved intact


# -- telemetry merge -------------------------------------------------------

def test_telemetry_merge_sums_and_pools():
    t1, t2 = Telemetry(), Telemetry()
    t1.record_batch(3, 4)
    t1.record_requests([0.010, 0.020, 0.030], version=1, staleness_s=0.5)
    t2.record_batch(2, 2)
    t2.record_requests([0.040, 0.050], version=2, staleness_s=1.5)
    t2.record_swap()
    snap = Telemetry.merge([t1, t2])
    assert snap["shards"] == 2
    assert snap["requests"] == 5
    assert snap["requests_by_shard"] == [3, 2]
    assert snap["batches"] == 2
    assert snap["requests_by_version"] == {1: 3, 2: 2}
    assert snap["swaps"] == 1
    assert snap["mean_batch"] == pytest.approx(2.5)
    assert snap["batch_occupancy"] == pytest.approx(5 / 6)
    # pooled percentiles span BOTH shards' reservoirs
    assert snap["p50_ms"] == pytest.approx(30.0)
    assert snap["p99_ms"] == pytest.approx(50.0)
    assert snap["staleness_p95_s"] == pytest.approx(1.5)
    assert "p50" in Telemetry.format(snap)    # format() accepts merges


def test_telemetry_merge_attribution_across_versions():
    tels = [Telemetry() for _ in range(3)]
    for sid, tel in enumerate(tels):
        tel.record_requests([0.001] * (sid + 1), version=sid % 2)
    snap = Telemetry.merge(tels)
    assert snap["requests"] == 6
    assert snap["requests_by_version"] == {0: 4, 1: 2}


# -- registry subscriptions ------------------------------------------------

def test_registry_subscribe_sees_all_publish_paths(tmp_path, forecaster):
    reg = ModelRegistry()
    events = []
    reg.subscribe(lambda key, version: events.append((key, version)))
    reg.register("m", forecaster)
    reg.swap("m", forecaster.with_params(forecaster.params))
    path = str(tmp_path / "m.npz")
    reg.save("m", path)
    reg.load(path, key="m2")
    assert events == [("m", 1), ("m", 2), ("m2", 2)]

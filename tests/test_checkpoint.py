"""Checkpoint roundtrip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptError, load_checkpoint,
                              save_checkpoint)
from repro.checkpoint.io import load_checkpoint_bytes


def test_roundtrip(tmp_path):
    tree = {"layers": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree, metadata={"round": 3, "note": "hi"})
    like = jax.tree.map(lambda a: np.zeros(a.shape, a.dtype), tree)
    loaded, meta = load_checkpoint(path, like=like)
    assert meta == {"round": 3, "note": "hi"}
    np.testing.assert_allclose(np.asarray(loaded["layers"]["w"]),
                               np.asarray(tree["layers"]["w"]))
    assert loaded["step"] == 7


def test_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "c.npz")
    save_checkpoint(path, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        load_checkpoint(path, like={"w": np.zeros((3, 3))})


def test_missing_key_raises(tmp_path):
    path = str(tmp_path / "c.npz")
    save_checkpoint(path, {"w": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        load_checkpoint(path, like={"w2": np.zeros((2,))})


def test_flat_load(tmp_path):
    path = str(tmp_path / "c.npz")
    save_checkpoint(path, {"a": {"b": jnp.ones((2,))}})
    flat, meta = load_checkpoint(path)
    assert "a/b" in flat and meta is None


def test_truncated_checkpoint_raises_clean_error(tmp_path):
    """A torn write (here: truncation, the common power-cut shape) must
    surface as CheckpointCorruptError naming the file — never a numpy
    zip internal the caller can't act on, and never silent garbage."""
    path = str(tmp_path / "c.npz")
    save_checkpoint(path, {"w": jnp.arange(64.0)},
                    metadata={"round": 1})
    data = open(path, "rb").read()
    for cut in (len(data) // 2, 10, 0):
        with open(path, "wb") as f:
            f.write(data[:cut])
        with pytest.raises(CheckpointCorruptError, match="c.npz"):
            load_checkpoint(path)
        with pytest.raises(CheckpointCorruptError):
            load_checkpoint_bytes(data[:cut])


def test_interrupted_save_never_tears_the_checkpoint(tmp_path,
                                                     monkeypatch):
    """Crash mid-save (simulated: os.replace never runs) leaves the
    previous checkpoint intact and loadable — the tmp file may be torn,
    the published path never is."""
    import repro.checkpoint.io as io_mod

    path = str(tmp_path / "c.npz")
    save_checkpoint(path, {"w": jnp.zeros((4,))}, metadata={"round": 1})

    def _boom(*a, **k):
        raise OSError("simulated crash before rename")

    monkeypatch.setattr(io_mod.os, "replace", _boom)
    with pytest.raises(OSError):
        save_checkpoint(path, {"w": jnp.ones((4,))},
                        metadata={"round": 2})
    monkeypatch.undo()
    loaded, meta = load_checkpoint(path, like={"w": np.zeros((4,))})
    assert meta == {"round": 1}          # the OLD checkpoint, whole
    np.testing.assert_array_equal(np.asarray(loaded["w"]), np.zeros(4))

"""Checkpoint roundtrip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint


def test_roundtrip(tmp_path):
    tree = {"layers": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree, metadata={"round": 3, "note": "hi"})
    like = jax.tree.map(lambda a: np.zeros(a.shape, a.dtype), tree)
    loaded, meta = load_checkpoint(path, like=like)
    assert meta == {"round": 3, "note": "hi"}
    np.testing.assert_allclose(np.asarray(loaded["layers"]["w"]),
                               np.asarray(tree["layers"]["w"]))
    assert loaded["step"] == 7


def test_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "c.npz")
    save_checkpoint(path, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        load_checkpoint(path, like={"w": np.zeros((3, 3))})


def test_missing_key_raises(tmp_path):
    path = str(tmp_path / "c.npz")
    save_checkpoint(path, {"w": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        load_checkpoint(path, like={"w2": np.zeros((2,))})


def test_flat_load(tmp_path):
    path = str(tmp_path / "c.npz")
    save_checkpoint(path, {"a": {"b": jnp.ones((2,))}})
    flat, meta = load_checkpoint(path)
    assert "a/b" in flat and meta is None

"""Logical-axis sharding constraints: no-op without context; correct
specs with one."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.pshard import clear_context, constrain, sharding_context


def test_noop_without_context():
    x = jnp.ones((4, 8))
    y = constrain(x, "batch", None)
    np.testing.assert_array_equal(x, y)


def test_rank_mismatch_raises():
    mesh = jax.make_mesh((1,), ("data",))
    with sharding_context(mesh, "data"):
        with pytest.raises(ValueError):
            constrain(jnp.ones((2, 2)), "batch")


def test_context_applies_and_clears():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with sharding_context(mesh, "data"):
        y = constrain(jnp.ones((2, 4)), "batch", "model")
        assert y.shape == (2, 4)
    # cleared: back to no-op
    y2 = constrain(jnp.ones((3,)), "batch")
    assert y2.shape == (3,)


def test_unknown_axis_dropped():
    mesh = jax.make_mesh((1,), ("data",))
    with sharding_context(mesh, "data"):
        # 'model' axis not in this mesh: silently unsharded
        y = constrain(jnp.ones((2, 4)), "batch", "model")
        assert y.shape == (2, 4)
    clear_context()


def test_cache_mode_selection():
    from repro.configs import ARCHS
    from repro.models.transformer import _attn_cache_mode
    mixtral = ARCHS["mixtral-8x7b"]
    assert _attn_cache_mode(mixtral, 32768) == ("ring", 4096)
    dense = ARCHS["qwen2.5-32b"]
    assert _attn_cache_mode(dense, 32768) == ("full", 32768)
    assert _attn_cache_mode(dense, 524288) == ("ring", 4096)  # long variant

"""Multi-process mesh transport (ISSUE 4 acceptance): the serving mesh
over >= 2 OS processes behind the socket transport — cross-process
serving correctness, weight pushes under the staleness skew bound, and
live shard join/leave mid-traffic with zero dropped requests, session
affinity for unmoved clients, and carry migration for moved ones.

Worker processes are spawned (not forked): each initializes its own jax
backend and compiles its own programs, so this module costs a few
seconds of process startup — kept bounded by a tiny model config.
"""

import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.models.rnn import RNNConfig, init_rnn
from repro.serving import (BatcherConfig, LSTMForecaster, ModelRegistry,
                           MultiProcessServingEngine, WeightPublisher)

CFG = RNNConfig(input_dim=3, hidden=8, num_layers=1, fc_dims=(4,),
                window=8, evl_head=True)
BCFG = BatcherConfig(max_batch=4, max_wait_ms=2.0, length_buckets=(8,))


@pytest.fixture(scope="module")
def forecaster():
    fc = LSTMForecaster(cfg=CFG, params=init_rnn(jax.random.PRNGKey(0),
                                                 CFG))
    rng = np.random.default_rng(0)
    fc.calibrate(rng.standard_normal((64, CFG.window, 3)).astype(np.float32)
                 * 0.02)
    return fc


def _windows(n, t=CFG.window, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, t, 3)).astype(np.float32) * 0.02


def _mesh(forecaster, n_shards=2, **kw):
    reg = ModelRegistry()
    reg.register("m", forecaster)
    return MultiProcessServingEngine(reg, BCFG, n_shards=n_shards, **kw)


def test_transport_serves_across_os_processes(forecaster):
    """Two shard worker PROCESSES serve the same numbers the forecaster
    computes locally; per-shard telemetry and per-client attribution
    cross the process boundary."""
    wins = _windows(16, seed=1)
    with _mesh(forecaster) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        stats = mesh.shard_stats()
        pids = {st["pid"] for st in stats.values()}
        assert len(pids) == 2 and os.getpid() not in pids
        futs = [mesh.submit("m", w, client_id=f"c{i % 5}")
                for i, w in enumerate(wins)]
        got = [f.result(timeout=60.0) for f in futs]
        y_ref, p_ref = forecaster.predict(wins)
        np.testing.assert_allclose([y for y, _ in got], y_ref,
                                   atol=1e-7, rtol=1e-6)
        np.testing.assert_allclose([p for _, p in got], p_ref,
                                   atol=1e-7, rtol=1e-6)
        snap = mesh.snapshot()
        assert snap["requests"] == 16
        assert len(snap["requests_by_shard"]) == 2
        assert all(n > 0 for n in snap["requests_by_shard"])
        assert snap["unique_clients"] == 5
        assert sum(snap["requests_by_client"].values()) == 16

        # streaming sessions live in the OWNING worker's shard-local
        # cache, numerically identical to a local replay
        w = wins[0]
        for t in range(CFG.window):
            y, p = mesh.step("m", "stream-client", w[t])
        y_r, p_r, _ = forecaster.replay(w[None])
        assert (y, p) == (float(y_r[0]), float(p_r[0]))
        sid = mesh.shard_for("stream-client")
        assert "stream-client" in mesh.shard_stats()[sid]["clients"]

        # stopping with submits in flight: the workers drain before
        # acking the goodbye, so every future resolves (zero drops on
        # shutdown — parity with the thread mesh)
        parting = [mesh.submit("m", w, client_id=f"c{i % 5}")
                   for i, w in enumerate(_windows(8, seed=4))]
    assert all(np.isfinite(f.result(timeout=60.0)[0]) for f in parting)


def test_transport_publish_pushes_within_skew_bound(forecaster):
    """Publishes against the primary registry ship serialized
    checkpoints to the workers; every version vector respects max_skew,
    and max_skew=0 is lockstep."""
    with _mesh(forecaster, max_skew=0) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        pub = WeightPublisher(mesh.registry, "m", template=forecaster)
        for i in range(4):
            pub.publish(jax.tree.map(lambda a, s=1.0 + 0.01 * i: a * s,
                                     forecaster.params))
            vec = mesh.version_vector("m")
            shard_vs = [v for k, v in vec.items() if k != "primary"]
            assert set(shard_vs) == {vec["primary"]}, vec
        assert mesh.pulls >= 2 * 4
        assert mesh.bytes_pulled > 0
        # served requests are attributed to the pushed version
        y, p = mesh.predict("m", _windows(1)[0], client_id="c0",
                            timeout=60.0)
        snap = mesh.snapshot()
        assert max(snap["requests_by_version"]) == vec["primary"]


def test_transport_join_leave_mid_traffic(forecaster):
    """THE acceptance scenario: a shard joins and a shard leaves while
    traffic, a publish storm and streaming sessions are all in flight —
    zero dropped requests, the staleness bound holds in every sampled
    version vector, unmoved clients keep their session affinity, and
    moved clients' carries migrate across processes."""
    max_skew = 1
    clients = [f"c{i}" for i in range(16)]
    sess_clients = [f"s{i}" for i in range(6)]
    wins = _windows(32, seed=2)
    sess_wins = _windows(len(sess_clients), seed=3)
    half = CFG.window // 2

    with _mesh(forecaster, n_shards=2, max_skew=max_skew) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        owners_before = {c: mesh.shard_for(c) for c in clients}
        sess_owners = {c: mesh.shard_for(c) for c in sess_clients}

        # stream the first half of every session before any churn
        for i, c in enumerate(sess_clients):
            for t in range(half):
                mesh.step("m", c, sess_wins[i][t])

        stop = threading.Event()
        futures, flock = [], threading.Lock()
        errors = []

        def traffic():
            i = 0
            while not stop.is_set():
                try:
                    f = mesh.submit("m", wins[i % len(wins)],
                                    client_id=clients[i % len(clients)])
                    with flock:
                        futures.append(f)
                except Exception as e:  # noqa: BLE001 — a drop IS the failure
                    errors.append(e)
                i += 1
                time.sleep(0.002)

        # publish through the mesh FACADE: primary publish + worker
        # pushes are then atomic under the lock version_vector samples
        pub = WeightPublisher(mesh, "m", template=forecaster)
        def storm():
            i = 0
            while not stop.is_set():
                pub.publish(jax.tree.map(
                    lambda a, s=1.0 + 0.01 * (i % 3): a * s,
                    forecaster.params))
                i += 1
                time.sleep(0.01)

        skew_violations = []
        def sampler():
            while not stop.is_set():
                stale = mesh.staleness("m")
                if stale > max_skew:
                    skew_violations.append(stale)
                time.sleep(0.002)

        threads = [threading.Thread(target=fn, name=f"storm-{fn.__name__}")
                   for fn in (traffic, storm, sampler)]
        for t in threads:
            t.start()
        try:
            time.sleep(0.3)
            joined = mesh.add_shard()          # join mid-traffic
            time.sleep(0.3)
            mesh.remove_shard(0)               # leave mid-traffic
            time.sleep(0.3)
        finally:
            stop.set()
            for t in threads:
                t.join()

        assert not errors, errors[:3]
        with flock:
            pending = list(futures)
        results = [f.result(timeout=60.0) for f in pending]  # zero drops
        assert len(results) >= 30
        assert all(np.isfinite(y) and 0.0 <= p <= 1.0 for y, p in results)
        assert not skew_violations, skew_violations[:5]

        # membership: exactly one joined, one left
        assert joined == 2 and mesh.shard_ids == [1, 2]

        # affinity: clients that neither lived on the departed shard nor
        # were won by the new one kept their shard assignment
        moved = 0
        for c in clients:
            now = mesh.shard_for(c)
            if owners_before[c] not in (0,) and now != joined:
                assert now == owners_before[c]
            else:
                moved += 1
        assert 0 < moved < len(clients)

        # pin the fleet back to the ORIGINAL weights (the storm cycled
        # scaled variants) so the session streams below have a
        # deterministic local reference, and converge every worker
        pub.publish(forecaster.params)
        mesh.propagate("m")
        vec = mesh.version_vector("m")
        assert set(v for k, v in vec.items() if k != "primary") \
            == {vec["primary"]}

        # sessions: finish every stream; carries survived the churn (on
        # unmoved shards untouched, on moved shards migrated across the
        # process boundary), so each stream ends exactly where an
        # uninterrupted local replay does — the carries were built under
        # the original weights, and the step path carries them across
        # the swap storm's version bumps
        for i, c in enumerate(sess_clients):
            for t in range(half, CFG.window):
                y, p = mesh.step("m", c, sess_wins[i][t])
            y_r, p_r, _ = forecaster.replay(sess_wins[i][None])
            assert (y, p) == (float(y_r[0]), float(p_r[0])), c
        # session affinity: a client owned by neither the departed nor
        # the joined shard is resident exactly where it always was
        stats = mesh.shard_stats()
        unmoved_sessions = [c for c in sess_clients
                            if sess_owners[c] not in (0, joined)]
        for c in unmoved_sessions:
            assert mesh.shard_for(c) == sess_owners[c]
            assert c in stats[sess_owners[c]]["clients"]


def test_transport_rejects_bad_ops(forecaster):
    with _mesh(forecaster) as mesh:
        with pytest.raises(RuntimeError, match="KeyError"):
            mesh.predict("nope", _windows(1)[0], timeout=60.0)
        with pytest.raises(KeyError):
            mesh.remove_shard(99)
        with pytest.raises(ValueError):
            mesh.add_shard(0)                  # already exists
        mesh.remove_shard(0)
        with pytest.raises(ValueError):
            mesh.remove_shard(1)               # never below one shard


# -- PR 7: crash supervision, remote join, hot-path bug sweep --------------

def test_request_fails_fast_when_worker_dies(forecaster):
    """ISSUE 7 satellite: a request issued against a dead worker must
    fail with ConnectionError within the heartbeat budget, NOT hang for
    the full 60 s RPC timeout (the reader loop flags EOF; `_request`
    refuses to register futures nobody will resolve)."""
    import signal

    with _mesh(forecaster, n_shards=2, supervise=False) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        worker = mesh.workers[0]
        os.kill(worker.process.pid, signal.SIGKILL)
        t0 = time.monotonic()
        with pytest.raises(ConnectionError):
            # the send may land in the OS buffer (future fails via
            # reader EOF) or be refused outright — both must be fast
            worker.submit("m", _windows(1)[0]).result(timeout=30.0)
        assert time.monotonic() - t0 < 5.0
        # and once the death is observed, requests fail IMMEDIATELY
        worker.process.join(5.0)
        t0 = time.monotonic()
        for _ in range(3):
            with pytest.raises(ConnectionError):
                worker.submit("m", _windows(1)[0]).result(timeout=30.0)
        assert time.monotonic() - t0 < 1.0


def test_warmup_on_empty_fleet_raises_clear_error(forecaster):
    """ISSUE 7 satellite: warmup before start() (or after the whole
    fleet crashed) used to die with a bare `ValueError: max() arg is an
    empty sequence`."""
    mesh = _mesh(forecaster)                   # never started
    with pytest.raises(RuntimeError, match="no live shards"):
        mesh.warmup("m", lengths=(CFG.window,))


def test_submit_normalizes_wire_dtype(forecaster):
    """ISSUE 7 satellite: submit frames used to ship the caller's dtype
    (float64 by default — 2x the wire bytes); now they normalize to the
    serving dtype at pack time, with results bitwise-equal to the
    in-process engine fed the same float64 window."""
    from repro.serving import ServingEngine

    win64 = _windows(4, seed=7).astype(np.float64)
    with _mesh(forecaster, n_shards=1) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        worker = mesh.workers[0]
        frames = []
        orig_send = worker._conn.send

        def spy(msg):
            frames.append(msg)
            orig_send(msg)

        worker._conn.send = spy
        try:
            got = [mesh.predict("m", w, timeout=60.0) for w in win64]
        finally:
            worker._conn.send = orig_send
        submits = [f for f in frames if f.get("op") == "submit"]
        assert len(submits) == len(win64)
        assert all(f["window"]["dtype"] == "<f4" for f in submits)

    reg = ModelRegistry()
    reg.register("m", forecaster)
    with ServingEngine(reg, BCFG) as local:
        local.warmup("m", lengths=(CFG.window,))
        ref = [local.predict("m", w, timeout=60.0) for w in win64]
    assert got == ref                          # bitwise, not allclose


def test_stats_race_free_under_live_traffic(forecaster):
    """ISSUE 7 satellite: the worker's stats op used to read telemetry
    reservoir buffers unlocked while the flush thread appends — hammer
    stats against live traffic (a race manifests as corrupt frames or
    worker errors, failing the RPC)."""
    wins = _windows(8, seed=9)
    with _mesh(forecaster, n_shards=1) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        stop = threading.Event()
        errors = []

        def traffic():
            i = 0
            while not stop.is_set():
                try:
                    mesh.predict("m", wins[i % len(wins)], timeout=60.0)
                except Exception as e:  # noqa: BLE001
                    errors.append(e)
                i += 1

        t = threading.Thread(target=traffic)
        t.start()
        try:
            for _ in range(100):
                st = mesh.shard_stats()[0]
                assert all(isinstance(v, float)
                           for v in st["latency_s"])
                assert all(isinstance(v, float)
                           for v in st["staleness_s"])
        finally:
            stop.set()
            t.join()
        assert not errors, errors[:3]


def test_telemetry_raw_samples_locked():
    """Unit half of the stats race fix: raw_samples() snapshots under
    the telemetry lock while writers append concurrently."""
    from repro.serving.telemetry import Telemetry

    tel = Telemetry()
    stop = threading.Event()

    def writer():
        i = 0
        while not stop.is_set():
            tel.record_requests([1e-3, 2e-3], version=1, staleness_s=0.1)
            i += 1

    threads = [threading.Thread(target=writer) for _ in range(3)]
    for t in threads:
        t.start()
    try:
        for _ in range(500):
            raw = tel.raw_samples()
            assert set(raw) == {"latency_s", "staleness_s",
                                "batch_sizes", "step_latency_s"}
            for vals in raw.values():
                assert all(isinstance(v, (int, float)) for v in vals)
    finally:
        stop.set()
        for t in threads:
            t.join()


def test_remote_worker_joins_by_address(forecaster):
    """ISSUE 7 tentpole (a): a shard worker started standalone
    (serve_shard — what `python -m repro.launch.shard_worker` runs)
    joins the mesh by address via the hello handshake, receives the
    hosted weights, and serves traffic like any spawned shard."""
    from repro.serving import serve_shard

    bound = {}
    ready = threading.Event()

    def on_bound(port):
        bound["port"] = port
        ready.set()

    srv = threading.Thread(target=serve_shard,
                           args=("127.0.0.1", 0),
                           kwargs={"on_bound": on_bound}, daemon=True)
    srv.start()
    assert ready.wait(10.0)

    wins = _windows(12, seed=11)
    with _mesh(forecaster, n_shards=1) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        sid = mesh.connect_shard(f"127.0.0.1:{bound['port']}")
        assert sid == 1 and mesh.shard_ids == [0, 1]
        assert mesh.workers[sid].addr == f"127.0.0.1:{bound['port']}"
        # the joiner acked every hosted model before taking traffic
        vec = mesh.version_vector("m")
        assert vec[sid] == vec["primary"]
        futs = [mesh.submit("m", w, client_id=f"rc{i}")
                for i, w in enumerate(wins)]
        got = [f.result(timeout=60.0) for f in futs]
        y_ref, p_ref = forecaster.predict(wins)
        np.testing.assert_allclose([y for y, _ in got], y_ref,
                                   atol=1e-7, rtol=1e-6)
        # both shards took some of it
        snap = mesh.snapshot()
        assert len(snap["requests_by_shard"]) == 2
        assert all(n > 0 for n in snap["requests_by_shard"])
    srv.join(10.0)
    assert not srv.is_alive()


def test_socket_steps_fuse_into_batched_decode(forecaster):
    """ISSUE 7 acceptance + tentpole (c): N concurrent cross-process
    streaming steps ride EngineShard.submit_step on the worker — the
    dispatch count shows fused decode_many flushes, NOT N independent
    dispatches (the old recv loop ran runner.step inline, one dispatch
    per frame)."""
    n = 8
    cfg = BatcherConfig(max_batch=8, max_wait_ms=25.0, length_buckets=(8,))
    reg = ModelRegistry()
    reg.register("m", forecaster)
    with MultiProcessServingEngine(reg, cfg, n_shards=1) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        worker = mesh.workers[0]
        xs = _windows(1, seed=13)[0]           # [T, F]: one step per row
        before = worker.stats()["telemetry"]
        worker.count_start()
        futs = [mesh.submit_step("m", f"fuse-{i}", xs[i % CFG.window])
                for i in range(n)]
        got = [f.result(timeout=60.0) for f in futs]
        counts = worker.count_stop()
        after = worker.stats()["telemetry"]
        assert all(np.isfinite(y) for y, _ in got)
        step_requests = after["step_requests"] - before["step_requests"]
        step_batches = after["step_batches"] - before["step_batches"]
        assert step_requests == n
        # fused: strictly fewer flushes than steps, and exactly one
        # slots_generate dispatch per flush (fresh clients additionally
        # insert into their device lanes — once each; the host
        # gather/scatter path stays cold)
        assert 0 < step_batches < n
        assert counts["slots_generate"] == step_batches
        assert counts["slots_insert"] == n     # one lane entry per client
        assert counts["decode_many"] == 0      # no host gather/scatter
        assert counts["decode_step"] == 0      # nothing went per-session

"""Optimizer transforms."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.optimizers import (adam, apply_updates, clip_by_global_norm,
                                    global_norm, sgd)


def _tree():
    return {"a": jnp.array([1.0, 2.0]), "b": jnp.array([[3.0]])}


def test_sgd_matches_manual():
    opt = sgd()
    p = _tree()
    g = jax.tree.map(jnp.ones_like, p)
    state = opt.init(p)
    upd, state = opt.update(g, state, p, 0.1)
    newp = apply_updates(p, upd)
    np.testing.assert_allclose(newp["a"], p["a"] - 0.1)


def test_sgd_momentum_accumulates():
    opt = sgd(momentum=0.9)
    p = _tree()
    g = jax.tree.map(jnp.ones_like, p)
    state = opt.init(p)
    upd1, state = opt.update(g, state, p, 1.0)
    upd2, state = opt.update(g, state, p, 1.0)
    np.testing.assert_allclose(upd2["a"], 1.9 * np.ones(2), rtol=1e-6)


def test_adam_first_step_size():
    """First Adam step is ~lr regardless of gradient scale."""
    opt = adam()
    p = _tree()
    g = jax.tree.map(lambda x: 123.0 * jnp.ones_like(x), p)
    state = opt.init(p)
    upd, state = opt.update(g, state, p, 1e-3)
    np.testing.assert_allclose(upd["a"], 1e-3, rtol=1e-4)


def test_clip_by_global_norm():
    t = {"a": jnp.array([3.0, 4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
    c = clip_by_global_norm(t, 1.0)
    assert abs(float(global_norm(c)) - 1.0) < 1e-5
    c2 = clip_by_global_norm(t, 10.0)  # under the cap: unchanged
    np.testing.assert_allclose(c2["a"], t["a"])


def test_adam_weight_decay():
    opt = adam(weight_decay=0.1)
    p = {"a": jnp.array([10.0])}
    g = {"a": jnp.array([0.0])}
    state = opt.init(p)
    upd, _ = opt.update(g, state, p, 1.0)
    assert float(upd["a"][0]) > 0.5  # decay pulls toward zero

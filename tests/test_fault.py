"""Fault injection for the crash-supervised process mesh (ISSUE 7
acceptance): SIGKILL a shard worker under mixed submit/step traffic and
hard-assert the recovery story — detection within the heartbeat budget,
pending futures failed fast (not the 60 s RPC timeout), zero dropped
requests on the surviving shards, supervised respawn with the crash and
recovery visible in the EventLog and telemetry counters, dead-shard
sessions re-primed bitwise against an uninterrupted reference, and the
publish skew bound holding across the respawn. A crashed REMOTE worker
(joined by address) is parked for re-join instead of respawned.

ISSUE 10 adds the durable-state acceptance scenarios: SIGKILL the
ROUTER (whole-fleet power cut) and cold-restart from the
``DurableStore`` — last acknowledged weight versions recovered, fresh
sessions bitwise, stale ones re-primed and counted — and a partition
re-adoption that reconciles a ``--forever`` worker's resident carries
against the store instead of discarding them.

Worker processes are spawned (own jax backend + compile set), so this
module costs process startup — bounded by the tiny model config.
"""

import os
import signal
import threading
import time

import jax
import numpy as np
import pytest

from repro.models.rnn import RNNConfig, init_rnn
from repro.obs import EventLog
from repro.serving import (BatcherConfig, LSTMForecaster, ModelRegistry,
                           MultiProcessServingEngine, WeightPublisher)

CFG = RNNConfig(input_dim=3, hidden=8, num_layers=1, fc_dims=(4,),
                window=8, evl_head=True)
BCFG = BatcherConfig(max_batch=4, max_wait_ms=2.0, length_buckets=(8,))

HEARTBEAT_S = 0.1
MISS_BUDGET = 4
# detection budget (heartbeat * misses) + repair slack: the respawn
# itself costs a process start + jax init + warmup, so RECOVERY gets a
# generous ceiling while DETECTION is asserted tightly
DETECT_BUDGET_S = HEARTBEAT_S * MISS_BUDGET + 1.0
RECOVER_BUDGET_S = 90.0


def _build_fc(seed):
    """Deterministic forecaster — rebuildable on BOTH sides of a
    process boundary (the durable-restart test's child router and the
    asserting parent must agree bitwise on the model)."""
    fc = LSTMForecaster(cfg=CFG, params=init_rnn(jax.random.PRNGKey(seed),
                                                 CFG))
    rng = np.random.default_rng(0)
    fc.calibrate(rng.standard_normal((64, CFG.window, 3)).astype(np.float32)
                 * 0.02)
    return fc


@pytest.fixture(scope="module")
def forecaster():
    return _build_fc(0)


def _windows(n, t=CFG.window, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, t, 3)).astype(np.float32) * 0.02


def _mesh(forecaster, n_shards=2, **kw):
    reg = ModelRegistry()
    reg.register("m", forecaster)
    kw.setdefault("heartbeat_s", HEARTBEAT_S)
    kw.setdefault("miss_budget", MISS_BUDGET)
    return MultiProcessServingEngine(reg, BCFG, n_shards=n_shards, **kw)


def _await(predicate, timeout_s, what):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout_s:
        if predicate():
            return time.monotonic() - t0
        time.sleep(0.02)
    raise AssertionError(f"timed out after {timeout_s}s waiting for {what}")


def test_sigkill_worker_under_traffic_is_supervised(forecaster):
    """THE fault-injection scenario: SIGKILL one worker while submit
    and step traffic flows to the whole fleet. The supervisor must
    detect within the heartbeat budget, fail the victim's in-flight
    futures fast, keep the survivors at zero drops, respawn the shard,
    and leave an audit trail in events + counters. Afterward the dead
    shard's sessions re-prime bitwise against an uninterrupted
    reference and the publish path converges the whole fleet again."""
    events = EventLog()
    clients = [f"c{i}" for i in range(16)]
    wins = _windows(32, seed=2)
    half = CFG.window // 2

    with _mesh(forecaster, n_shards=2, events=events) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        victim_sid = 0
        victim = mesh.workers[victim_sid]
        victim_pid = victim.process.pid
        survivor_clients = [c for c in clients
                            if mesh.shard_for(c) != victim_sid]
        victim_clients = [c for c in clients
                          if mesh.shard_for(c) == victim_sid]
        assert survivor_clients and victim_clients

        # streaming sessions on the VICTIM shard: half the stream now,
        # the rest after the crash — their carries die with the worker,
        # so the post-crash steps must re-prime from history
        sess = {c: _windows(1, seed=30 + i)[0]
                for i, c in enumerate(victim_clients[:3])}
        for c, w in sess.items():
            for t in range(half):
                mesh.step("m", c, w[t])

        stop = threading.Event()
        survivor_futs, victim_errors, flock = [], [], threading.Lock()
        survivor_errors = []

        def survivor_traffic():
            i = 0
            while not stop.is_set():
                try:
                    c = survivor_clients[i % len(survivor_clients)]
                    f = mesh.submit("m", wins[i % len(wins)], client_id=c)
                    with flock:
                        survivor_futs.append(f)
                except Exception as e:  # noqa: BLE001 — a drop IS the failure
                    survivor_errors.append(e)
                i += 1
                time.sleep(0.002)

        def victim_traffic():
            # requests routed at the dead shard are ALLOWED to fail —
            # but only fast (ConnectionError / re-route), never a hang
            i = 0
            while not stop.is_set():
                c = victim_clients[i % len(victim_clients)]
                t0 = time.monotonic()
                try:
                    mesh.submit("m", wins[i % len(wins)],
                                client_id=c).result(timeout=30.0)
                except Exception as e:  # noqa: BLE001
                    victim_errors.append((type(e).__name__,
                                          time.monotonic() - t0))
                i += 1
                time.sleep(0.002)

        threads = [threading.Thread(target=fn) for fn in
                   (survivor_traffic, victim_traffic)]
        for t in threads:
            t.start()
        try:
            time.sleep(0.3)                    # steady state first
            t_kill = time.monotonic()
            os.kill(victim_pid, signal.SIGKILL)

            # detection: the crash event lands within the budget
            detect_s = _await(
                lambda: any(e["kind"] == "shard_crash"
                            for e in events.events()),
                DETECT_BUDGET_S, "shard_crash event")
            # recovery: membership back to full strength, new process
            _await(lambda: mesh.shard_ids == [0, 1]
                   and mesh.workers[victim_sid].pid != victim_pid
                   and any(e["kind"] == "shard_respawn"
                           for e in events.events()),
                   RECOVER_BUDGET_S, "supervised respawn")
            time.sleep(0.3)                    # post-recovery traffic
        finally:
            stop.set()
            for t in threads:
                t.join()

        # survivors: ZERO drops, every future resolves
        assert not survivor_errors, survivor_errors[:3]
        with flock:
            pending = list(survivor_futs)
        results = [f.result(timeout=60.0) for f in pending]
        assert len(results) >= 50
        assert all(np.isfinite(y) and 0.0 <= p <= 1.0 for y, p in results)

        # victim requests that failed did so FAST (fail-fast + repair),
        # never the 60 s RPC timeout — and traffic resumed after repair
        assert all(dt < DETECT_BUDGET_S + 5.0
                   for _, dt in victim_errors), victim_errors[:5]

        # audit trail: crash + respawn in events and counters
        kinds = [e["kind"] for e in events.events()]
        assert "shard_crash" in kinds and "shard_respawn" in kinds
        crash = next(e for e in events.events()
                     if e["kind"] == "shard_crash")
        assert crash["shard"] == victim_sid and crash["pid"] == victim_pid
        snap = mesh.snapshot()
        assert snap["crashes"] == 1
        assert snap["respawns"] == 1
        assert mesh.crashes == 1 and mesh.respawns == 1
        assert detect_s <= DETECT_BUDGET_S

        # skew bound across the respawn: a publish storm converges the
        # WHOLE fleet, replacement included, then pins the original
        # weights so the session references below are deterministic
        pub = WeightPublisher(mesh, "m", template=forecaster)
        for i in range(3):
            pub.publish(jax.tree.map(lambda a, s=1.0 + 0.01 * i: a * s,
                                     forecaster.params))
        pub.publish(forecaster.params)
        mesh.propagate("m")
        vec = mesh.version_vector("m")
        shard_vs = {v for k, v in vec.items() if k != "primary"}
        assert shard_vs == {vec["primary"]}, vec
        assert set(vec) == {"primary", 0, 1}   # replacement in the vector

        # dead-shard sessions: their carries died with the worker, so
        # finish each stream passing the history prefix — the miss
        # replay re-primes and the stream ends bitwise where an
        # uninterrupted local replay does
        for c, w in sess.items():
            for t in range(half, CFG.window):
                y, p = mesh.step("m", c, w[t], history=w[:t])
            y_r, p_r, _ = forecaster.replay(w[None])
            assert (y, p) == (float(y_r[0]), float(p_r[0])), c


def test_crashed_remote_shard_parks_for_rejoin(forecaster):
    """A worker joined by ADDRESS cannot be respawned from the router's
    machine: on crash it is removed from the router, parked in
    ``awaiting_rejoin``, and re-adopted by a later connect_shard —
    sessions and weights re-pushed through the normal join path."""
    import multiprocessing as mp

    from repro.serving.transport import _worker_main

    events = EventLog()
    ctx = mp.get_context("spawn")

    def _standalone():
        parent, child = ctx.Pipe()
        proc = ctx.Process(target=_worker_main, args=(child, "127.0.0.1"),
                           daemon=True)
        proc.start()
        child.close()
        assert parent.poll(60.0)
        port = parent.recv()
        parent.close()
        return proc, port

    with _mesh(forecaster, n_shards=1, events=events) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        proc, port = _standalone()
        sid = mesh.connect_shard(f"127.0.0.1:{port}")
        assert mesh.workers[sid].addr == f"127.0.0.1:{port}"
        assert mesh.shard_ids == [0, sid]

        os.kill(proc.pid, signal.SIGKILL)
        _await(lambda: sid in mesh.awaiting_rejoin,
               DETECT_BUDGET_S + 5.0, "remote shard parked for rejoin")
        assert mesh.awaiting_rejoin[sid] == f"127.0.0.1:{port}"
        assert mesh.shard_ids == [0]           # router shrank
        assert mesh.respawns == 0              # NOT respawned locally
        assert any(e["kind"] == "shard_await_rejoin"
                   for e in events.events())
        # the surviving local shard keeps serving everything
        y, p = mesh.predict("m", _windows(1)[0], client_id="r0",
                            timeout=60.0)
        assert np.isfinite(y)

        # the operator restarts the worker (new port) and re-joins it
        proc2, port2 = _standalone()
        try:
            rejoined = mesh.add_shard(shard_id=sid,
                                      addr=f"127.0.0.1:{port2}")
            assert rejoined == sid
            assert sid not in mesh.awaiting_rejoin
            assert mesh.shard_ids == [0, sid]
            vec = mesh.version_vector("m")
            assert vec[sid] == vec["primary"]
            futs = [mesh.submit("m", w, client_id=f"rc{i}")
                    for i, w in enumerate(_windows(8, seed=5))]
            assert all(np.isfinite(f.result(timeout=60.0)[0])
                       for f in futs)
        finally:
            proc2.terminate()
        proc.join(5.0)


def _durable_router_main(conn, state_dir):
    """Child-process router for the whole-fleet-kill test: serve real
    traffic with durable checkpointing, report the acked state over the
    pipe, then spin until SIGKILLed (no clean shutdown — the last
    durable state is whatever the async daemon committed)."""
    from repro.serving import CheckpointDaemon, DurableStore

    store = DurableStore(state_dir)
    reg = ModelRegistry()
    reg.register("m", _build_fc(0))
    mesh = MultiProcessServingEngine(reg, BCFG, n_shards=2,
                                     supervise=False, durable=store)
    mesh.start()
    half = CFG.window // 2
    # stale streams: stepped + checkpointed under v1, then the model
    # moves on to v2 — their stored carries become version-stale
    for i in range(3):
        w = _windows(1, seed=80 + i)[0]
        for t in range(half):
            mesh.step("m", f"stale{i}", w[t])
    daemon = CheckpointDaemon(store, mesh, interval_s=30.0)
    daemon.checkpoint_now()
    mesh.swap("m", _build_fc(1))               # v2
    mesh.propagate("m")                        # force every worker's ack
    # fresh streams: stepped AND checkpointed under the acked v2
    for i in range(3):
        w = _windows(1, seed=90 + i)[0]
        for t in range(half):
            mesh.step("m", f"fresh{i}", w[t])
    daemon.checkpoint_now()
    conn.send({"router": os.getpid(),
               "workers": [w.process.pid for w in mesh.workers.values()],
               "acked": mesh.version_vector("m")})
    while True:                                # await the axe
        time.sleep(1.0)


def test_router_sigkill_cold_restart_from_durable_store(tmp_path):
    """THE durable-state acceptance scenario (ISSUE 10): SIGKILL the
    mesh OWNER (router) mid-service, kill its orphaned workers too — a
    whole-fleet power cut — then cold-boot a brand-new mesh from the
    ``DurableStore``. The restored weight versions must match the last
    acknowledged publish, sessions checkpointed under the live version
    resume bitwise vs an uninterrupted replay with NO history, and
    version-stale sessions re-prime from history, visible in the
    ``restored_stale`` counter."""
    import multiprocessing as mp

    from repro.serving import DurableStore

    ctx = mp.get_context("spawn")
    state_dir = str(tmp_path / "state")
    parent, child = ctx.Pipe()
    # NOT daemonic: the child router spawns its own worker processes
    proc = ctx.Process(target=_durable_router_main,
                       args=(child, state_dir))
    proc.start()
    child.close()
    info = None
    try:
        assert parent.poll(300.0), "child router never reached steady state"
        info = parent.recv()
        parent.close()
    finally:
        os.kill(proc.pid, signal.SIGKILL)
        proc.join(10.0)
        for pid in (info or {}).get("workers", ()):   # orphaned workers
            try:
                os.kill(pid, signal.SIGKILL)
            except ProcessLookupError:
                pass
    acked = info["acked"]
    assert acked["primary"] == 2
    assert all(v == 2 for k, v in acked.items() if k != "primary"), acked

    fc2 = _build_fc(1)
    half = CFG.window // 2
    with MultiProcessServingEngine(ModelRegistry(), BCFG, n_shards=2,
                                   supervise=False) as mesh:
        out = mesh.restore_from(DurableStore(state_dir))
        # weights: exactly the last ACKNOWLEDGED publish, fleet-wide
        assert mesh.version("m") == acked["primary"] == 2
        vec = mesh.version_vector("m")
        assert all(v == 2 for v in vec.values()), vec
        # sessions: all 6 re-homed; the 3 v1-stamped ones are stale
        assert out["restored_sessions"] == 6
        assert out["restored_stale"] == 3
        snap = mesh.snapshot()
        assert snap["restored_sessions"] == 6
        assert snap["restored_stale"] == 3

        # fresh streams: resume bitwise with NO history — the restored
        # carry IS the uninterrupted carry
        for i in range(3):
            w = _windows(1, seed=90 + i)[0]
            for t in range(half, CFG.window):
                y, p = mesh.step("m", f"fresh{i}", w[t])
            y_r, p_r, _ = fc2.replay(w[None])
            assert (y, p) == (float(y_r[0]), float(p_r[0])), f"fresh{i}"
        # stale streams: version fence re-primes from history and the
        # stream still ends bitwise where an uninterrupted v2 replay does
        for i in range(3):
            w = _windows(1, seed=80 + i)[0]
            for t in range(half, CFG.window):
                y, p = mesh.step("m", f"stale{i}", w[t], history=w[:t])
            y_r, p_r, _ = fc2.replay(w[None])
            assert (y, p) == (float(y_r[0]), float(p_r[0])), f"stale{i}"
        assert mesh.snapshot()["reprimes"] >= 3


def _forever_worker_main(pipe, host):
    """Standalone ``--forever`` worker: keeps its serving state across
    router connections (the partition re-adoption scenario)."""
    from repro.serving.transport import serve_shard

    def _report(port):
        pipe.send(port)
        pipe.close()

    serve_shard(host, 0, forever=True, on_bound=_report)


def test_partition_rejoin_reconciles_with_durable_store(forecaster,
                                                        tmp_path):
    """Partition re-adoption (ISSUE 10): a ``--forever`` worker loses
    its router (socket severed — the process and its carries survive),
    the mesh parks it in ``awaiting_rejoin``, the model moves on to v2
    and some of its clients keep streaming on the survivor. On re-adopt
    the worker's residents are RECONCILED against the durable store
    instead of discarded: residents superseded by survivor copies are
    evicted (the v2 streams resume bitwise with no history — a stale v1
    resident shadowing them would force a wrong-carry re-prime), and
    untouched residents stay put."""
    import multiprocessing as mp

    from repro.serving import CheckpointDaemon, DurableStore

    store = DurableStore(str(tmp_path / "state"))
    ctx = mp.get_context("spawn")
    half = CFG.window // 2
    with _mesh(forecaster, n_shards=1) as mesh:
        mesh.attach_durable(store)
        parent, child = ctx.Pipe()
        proc = ctx.Process(target=_forever_worker_main,
                           args=(child, "127.0.0.1"), daemon=True)
        proc.start()
        child.close()
        assert parent.poll(60.0)
        port = parent.recv()
        parent.close()
        addr = f"127.0.0.1:{port}"
        sid = mesh.connect_shard(addr)

        remote_clients = [c for c in (f"p{i}" for i in range(64))
                          if mesh.shard_for(c) == sid][:3]
        assert len(remote_clients) == 3
        wins = {c: _windows(1, seed=50 + i)[0]
                for i, c in enumerate(remote_clients)}
        for c, w in wins.items():
            for t in range(half):
                mesh.step("m", c, w[t])
        CheckpointDaemon(store, mesh, interval_s=30.0).checkpoint_now()

        # PARTITION: sever the socket; the worker process loops back to
        # accept with its state intact, the router parks the shard
        mesh.workers[sid]._conn.close()
        _await(lambda: sid in mesh.awaiting_rejoin,
               DETECT_BUDGET_S + 5.0, "partitioned shard parked")

        # the world moves on without the partitioned worker: v2 ships,
        # and two clients keep streaming — on the survivor, which
        # re-primes them from history under v2
        fc2 = _build_fc(1)
        assert mesh.swap("m", fc2) == 2
        mesh.propagate("m")                    # survivor acks v2
        moved_on = remote_clients[:2]
        for c in moved_on:
            w = wins[c]
            for t in range(half, half + 2):
                mesh.step("m", c, w[t], history=w[:t])

        # RE-ADOPT at the same address: reconcile runs against the store
        assert mesh.add_shard(shard_id=sid, addr=addr) == sid
        assert sid not in mesh.awaiting_rejoin
        vec = mesh.version_vector("m")
        assert vec[sid] == vec["primary"] == 2, vec
        assert mesh.rehomed_sessions >= 2      # survivor copies moved in

        try:
            # the moved-on streams finish bitwise with NO history: their
            # survivor v2 carries won over the worker's stale residents
            for c in moved_on:
                w = wins[c]
                for t in range(half + 2, CFG.window):
                    y, p = mesh.step("m", c, w[t])
                y_r, p_r, _ = fc2.replay(w[None])
                assert (y, p) == (float(y_r[0]), float(p_r[0])), c
            # the untouched resident kept its carry (v1-stamped): the
            # version fence re-primes it from history under v2
            c = remote_clients[2]
            w = wins[c]
            for t in range(half, CFG.window):
                y, p = mesh.step("m", c, w[t], history=w[:t])
            y_r, p_r, _ = fc2.replay(w[None])
            assert (y, p) == (float(y_r[0]), float(p_r[0])), c
        finally:
            proc.terminate()
            proc.join(5.0)


def test_repair_is_idempotent_and_stop_safe(forecaster):
    """Supervision bookkeeping: a single crash produces exactly one
    crash/respawn event pair even with an aggressive heartbeat, and
    stopping the mesh mid-storm neither hangs nor leaks workers."""
    events = EventLog()
    with _mesh(forecaster, n_shards=2, events=events,
               heartbeat_s=0.05) as mesh:
        mesh.warmup("m", lengths=(CFG.window,))
        os.kill(mesh.workers[1].process.pid, signal.SIGKILL)
        _await(lambda: mesh.respawns == 1, RECOVER_BUDGET_S, "respawn")
        time.sleep(0.5)                        # give false repairs a chance
        assert mesh.crashes == 1
        kinds = [e["kind"] for e in events.events()]
        assert kinds.count("shard_crash") == 1
        assert kinds.count("shard_respawn") == 1
    # post-stop: supervisor is down, no worker processes left behind
    assert mesh._supervisor is None
    assert not mesh.workers

"""The HLO cost analyzer must multiply while bodies by trip count (the
reason it exists: XLA's own cost_analysis counts loop bodies once)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_flops_scaled_by_trip_count():
    M, K, N, L = 128, 256, 256, 10

    def f(x, w):
        def body(x, _):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, None, length=L)
        return x

    c = _compile(f, jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((K, N), jnp.float32))
    got = analyze_hlo(c.as_text())["flops"]
    want = 2 * M * K * N * L
    assert 0.9 * want < got < 1.3 * want, (got, want)


def test_single_dot_flops():
    def f(a, b):
        return a @ b
    c = _compile(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 32), jnp.float32))
    got = analyze_hlo(c.as_text())["flops"]
    want = 2 * 64 * 128 * 32
    assert 0.9 * want < got < 1.2 * want


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(x, _):
            def inner(x, _):
                return x @ w, None
            x, _ = jax.lax.scan(inner, x, None, length=4)
            return x, None
        x, _ = jax.lax.scan(outer, x, None, length=3)
        return x

    c = _compile(f, jax.ShapeDtypeStruct((32, 64), jnp.float32),
                 jax.ShapeDtypeStruct((64, 64), jnp.float32))
    got = analyze_hlo(c.as_text())["flops"]
    want = 2 * 32 * 64 * 64 * 12
    assert 0.8 * want < got < 1.4 * want


def test_collectives_counted():
    import os
    # this test runs in the default single-device process: simulate via
    # a jit with psum under shard_map only if >1 device; otherwise just
    # check the parser on a synthetic HLO snippet.
    hlo = """
HloModule test

ENTRY %main (p: f32[16,1024]) -> f32[16,1024] {
  %p = f32[16,1024]{1,0} parameter(0)
  ROOT %all-reduce.1 = f32[16,1024]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
}
"""
    out = analyze_hlo(hlo)
    assert out["collectives"]["all-reduce"] == 16 * 1024 * 4

"""Weight hot-swapping: atomic registry swaps under concurrent serving
(the online-learning bridge), version attribution, staleness telemetry,
session-carry validity across swaps, and the registry listing race."""

import threading
import time

import jax
import numpy as np
import pytest

from repro.models.rnn import RNNConfig, init_rnn
from repro.serving import (BatcherConfig, LSTMForecaster, ModelRegistry,
                           RecurrentSessionRunner, ServingEngine,
                           SessionCache, WeightPublisher,
                           stop_the_world_swap)

CFG = RNNConfig(input_dim=3, hidden=8, num_layers=1, fc_dims=(4,),
                window=8, evl_head=True)


def _params(seed: int, scale: float = 1.0):
    p = init_rnn(jax.random.PRNGKey(seed), CFG)
    if scale != 1.0:
        p = jax.tree.map(lambda a: a * scale, p)
    return p


def _forecaster(seed: int = 0) -> LSTMForecaster:
    fc = LSTMForecaster(cfg=CFG, params=_params(seed))
    rng = np.random.default_rng(seed)
    fc.calibrate(rng.standard_normal((32, CFG.window, 3)).astype(np.float32)
                 * 0.02)
    return fc


def _windows(n, t=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, t, 3)).astype(np.float32) * 0.02


# -- registry versioning ---------------------------------------------------

def test_register_and_swap_bump_versions_monotonically():
    reg = ModelRegistry()
    fc1, fc2, fc3 = _forecaster(0), _forecaster(1), _forecaster(2)
    reg.register("m", fc1)
    assert reg.version("m") == 1 and fc1.version == 1
    assert reg.swap("m", fc2) == 2
    assert reg.get("m") is fc2 and fc2.published_at is not None
    # explicit versions must still increase
    assert reg.swap("m", fc3, version=7) == 7
    with pytest.raises(ValueError):
        reg.swap("m", fc1, version=7)
    with pytest.raises(KeyError):
        reg.swap("nope", fc1)
    assert reg.swap_count == 2
    # re-register of an existing key keeps the monotone sequence
    reg.register("m", fc1)
    assert reg.version("m") == 8


def test_registry_entry_snapshot_and_len():
    reg = ModelRegistry()
    reg.register("a", _forecaster(0))
    reg.register("b", _forecaster(1))
    assert len(reg) == 2
    entries = dict(reg.entries())
    assert entries["a"].version == 1
    assert [k for k, _ in reg.items()] == ["a", "b"]


def test_registry_listing_race_register_unregister():
    """register/unregister/swap from other threads must never make a
    hosted-model listing raise (listings are snapshots under the lock)."""
    reg = ModelRegistry()
    for i in range(8):
        reg.register(f"m{i}", _forecaster(0))
    stop = threading.Event()
    errors: list[BaseException] = []

    def churn(seed: int) -> None:
        rng = np.random.default_rng(seed)
        fc = _forecaster(0)
        try:
            while not stop.is_set():
                i = int(rng.integers(0, 8))
                op = int(rng.integers(0, 3))
                if op == 0:
                    reg.register(f"m{i}", fc)
                elif op == 1:
                    reg.unregister(f"m{i}")
                else:
                    try:
                        reg.swap(f"m{i}", fc)
                    except KeyError:
                        pass       # unregistered by the other thread: fine
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=churn, args=(s,)) for s in (1, 2)]
    for t in threads:
        t.start()
    try:
        deadline = time.perf_counter() + 1.0
        while time.perf_counter() < deadline:
            for key, fc in reg.items():        # snapshot: safe to iterate
                assert isinstance(key, str)
            for key, entry in reg.entries():
                assert entry.version >= 1
            reg.keys()
            try:
                reg.get("m0")
            except KeyError:
                pass               # unregistered is a valid outcome,
                # a RuntimeError from mutation-during-iteration is not
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert not errors


def test_checkpoint_version_roundtrip(tmp_path):
    reg = ModelRegistry()
    fc = _forecaster(0)
    reg.register("m", fc)
    reg.swap("m", _forecaster(1))
    reg.swap("m", _forecaster(2))
    path = str(tmp_path / "m.npz")
    reg.save("m", path)

    fresh = ModelRegistry()
    loaded = fresh.load(path, key="m")
    assert fresh.version("m") == 3          # saved version preserved
    y0, p0 = reg.get("m").predict(_windows(3))
    y1, p1 = loaded.predict(_windows(3))
    np.testing.assert_array_equal(y0, y1)
    np.testing.assert_array_equal(p0, p1)

    # a registry whose key already moved past the saved version bumps
    # instead of rewinding
    ahead = ModelRegistry()
    ahead.register("m", _forecaster(3), version=9)
    ahead.load(path, key="m")
    assert ahead.version("m") == 10


# -- swap semantics under the engine ---------------------------------------

def test_flush_serves_swapped_weights_and_attributes_version():
    """A flush that starts before a swap serves the old weights; the next
    flush serves the new ones — and every future says which version."""
    reg = ModelRegistry()
    fc1 = _forecaster(0)
    reg.register("m", fc1)
    w = _windows(1)[0]
    cfg = BatcherConfig(max_batch=4, max_wait_ms=1.0, length_buckets=(8,))
    with ServingEngine(reg, cfg) as eng:
        f1 = eng.submit("m", w)
        y1, _ = f1.result(timeout=10.0)
        fc2 = fc1.with_params(_params(1))
        assert fc2.version == 0            # unpublished until swapped
        assert reg.swap("m", fc2) == 2
        f2 = eng.submit("m", w)
        y2, _ = f2.result(timeout=10.0)
    assert f1.model_version == 1 and f2.model_version == 2
    # different weights, different forecast (same input)
    y1_ref, _ = fc1.predict(w[None])
    y2_ref, _ = fc2.predict(w[None])
    assert y1 == float(y1_ref[0]) and y2 == float(y2_ref[0])
    assert y1 != y2
    snap = eng.telemetry.snapshot()
    assert snap["requests_by_version"] == {1: 1, 2: 1}
    assert snap["staleness_p95_s"] >= 0.0


def test_hotswap_storm_drops_nothing_and_attributes_every_response():
    """ISSUE acceptance: one thread swapping weights every few ms while N
    threads predict — zero dropped/failed requests, every response
    attributable to a registered version, consistent final registry."""
    reg = ModelRegistry()
    fc0 = _forecaster(0)
    reg.register("m", fc0)
    variants = [_params(0, scale=1.0 + 0.1 * i) for i in range(3)]

    cfg = BatcherConfig(max_batch=8, max_wait_ms=1.0, length_buckets=(8,))
    eng = ServingEngine(reg, cfg)
    publisher = WeightPublisher(reg, "m", template=fc0,
                                telemetry=eng.telemetry)
    n_threads, n_requests = 4, 30
    results: dict[int, list] = {i: [] for i in range(n_threads)}
    errors: list[BaseException] = []
    stop = threading.Event()

    def swapper() -> None:
        i = 0
        try:
            while not stop.is_set() and i < 2000:
                publisher.publish(variants[i % len(variants)])
                i += 1
                time.sleep(0.002)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    with eng:
        eng.warmup("m", lengths=(8,))
        eng.telemetry.reset_clock()

        def client(tid: int) -> None:
            try:
                for j in range(n_requests):
                    fut = eng.submit("m", _windows(1, seed=tid * 100 + j)[0])
                    y, p = fut.result(timeout=30.0)
                    results[tid].append((y, p, fut.model_version))
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        sw = threading.Thread(target=swapper, name="swapper")
        clients = [threading.Thread(target=client, args=(i,))
                   for i in range(n_threads)]
        sw.start()
        for c in clients:
            c.start()
        for c in clients:
            c.join()
        stop.set()
        sw.join()
        snap = eng.telemetry.snapshot()

    assert not errors                       # zero dropped/failed requests
    total = sum(len(r) for r in results.values())
    assert total == n_threads * n_requests
    final_version = reg.version("m")
    assert publisher.published >= 1
    assert final_version == publisher.last_version
    for r in results.values():
        for y, p, version in r:
            assert np.isfinite(y) and 0.0 <= p <= 1.0
            assert isinstance(version, int) and 1 <= version <= final_version
    # telemetry accounted every engine-served request to some version
    assert sum(snap["requests_by_version"].values()) == total
    assert snap["swaps"] == publisher.published
    # final registry state consistent: hosted forecaster carries the
    # version the registry reports
    entry = reg.get_entry("m")
    assert entry.forecaster.version == entry.version == final_version


def test_stop_the_world_swap_rejects_requests_while_stopped():
    """The baseline the hot swap replaces: engine halted around the
    weight update, so a submit in that window is a dropped request."""
    reg = ModelRegistry()
    fc = _forecaster(0)
    reg.register("m", fc)
    eng = ServingEngine(reg, BatcherConfig(max_batch=2, max_wait_ms=1.0,
                                           length_buckets=(8,)))
    eng.start()
    try:
        assert eng.predict("m", _windows(1)[0], timeout=10.0)
        eng.stop()
        with pytest.raises(RuntimeError):
            eng.submit("m", _windows(1)[0])    # the dropped request
        eng.start()
        v = stop_the_world_swap(eng, reg, "m", fc.with_params(_params(1)))
        assert v == 2
        fut = eng.submit("m", _windows(1)[0])
        fut.result(timeout=10.0)
        assert fut.model_version == 2
    finally:
        eng.stop()


# -- publisher -------------------------------------------------------------

def test_publisher_recalibrates_tail_on_publish():
    reg = ModelRegistry()
    fc0 = _forecaster(0)
    reg.register("m", fc0)
    calib = _windows(32, seed=5)
    pub = WeightPublisher(reg, "m", calib_windows=calib)
    v = pub.publish(_params(1))
    fc1 = reg.get("m")
    assert v == 2 and fc1.version == 2
    assert fc1.tail is not None
    # calibration ran on the *new* weights' forecast distribution
    expect = fc0.with_params(_params(1)).calibrate(calib).tail
    assert fc1.tail == pytest.approx(expect)


def test_publisher_rate_limit_and_first_publish_registers():
    reg = ModelRegistry()
    template = _forecaster(0)
    pub = WeightPublisher(reg, "m", template=template, min_interval_s=60.0)
    assert "m" not in reg
    assert pub.publish(_params(1), round_idx=1) == 1   # registers key
    assert "m" in reg and pub.last_round == 1
    assert pub.publish(_params(2), round_idx=2) is None  # rate-limited
    assert pub.skipped == 1 and reg.version("m") == 1
    # tail/eps carried over from the template when not recalibrating
    assert reg.get("m").tail == pytest.approx(template.tail)
    # flush publishes the freshest rate-limited round (the trained final
    # weights are never left behind the served ones), then clears it
    assert pub.flush() == 2
    assert reg.version("m") == 2 and pub.last_round == 2
    y_flush, _ = reg.get("m").predict(_windows(2))
    y_want, _ = template.with_params(_params(2)).predict(_windows(2))
    np.testing.assert_array_equal(y_flush, y_want)
    assert pub.flush() is None


# -- sessions across swaps -------------------------------------------------

def test_session_carry_reprimes_with_history_after_swap():
    """A live session must survive a hot swap: with history the carry is
    replayed through the new weights (numbers match a fresh replay)."""
    reg = ModelRegistry()
    fc1 = _forecaster(0)
    reg.register("m", fc1)
    runner = RecurrentSessionRunner(lambda: reg.get("m"),
                                    SessionCache(max_sessions=4))
    w = _windows(1, seed=9)[0]
    half = CFG.window // 2
    for t in range(half):
        runner.step("c", w[t])

    fc2 = fc1.with_params(_params(1))
    reg.swap("m", fc2)
    for t in range(half, CFG.window):
        y_live, _ = runner.step("c", w[t], history=w[:t])
    assert runner.reprimes == 1             # re-primed once, then v2 carry

    # reference: the same stream served on v2 from scratch
    runner2 = RecurrentSessionRunner(fc2, SessionCache(max_sessions=4))
    for t in range(CFG.window):
        y_ref, _ = runner2.step("c2", w[t])
    assert y_live == y_ref


def test_session_carry_survives_swap_without_history():
    """Without history the carry is kept (not dropped): serving continues
    on the new weights, and the carry stays marked stale so history
    arriving on ANY later step still triggers the lazy re-prime."""
    reg = ModelRegistry()
    fc1 = _forecaster(0)
    reg.register("m", fc1)
    runner = RecurrentSessionRunner(lambda: reg.get("m"),
                                    SessionCache(max_sessions=4))
    w = _windows(1, seed=11)[0]
    for t in range(4):
        runner.step("c", w[t])
    fc2 = fc1.with_params(_params(2))
    reg.swap("m", fc2)
    y, p = runner.step("c", w[4])           # no history: must not raise
    assert np.isfinite(y) and 0.0 <= p <= 1.0
    assert runner.carried_across_swap == 1
    runner.step("c", w[5])                  # still no history: still stale
    assert runner.carried_across_swap == 2 and runner.reprimes == 0
    # history finally arrives -> re-primed through the new weights,
    # bitwise equal to a v2-only session from scratch
    y_live, _ = runner.step("c", w[6], history=w[:6])
    assert runner.reprimes == 1
    runner.step("c", w[7])
    assert runner.carried_across_swap == 2  # current again: no more carries
    runner2 = RecurrentSessionRunner(fc2, SessionCache(max_sessions=4))
    y_ref = None
    for t in range(7):
        y_ref, _ = runner2.step("c2", w[t])
    assert y_live == y_ref

"""Core invariants of the paper's technique (async local SGD)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.async_local_sgd import (AsyncLocalSGD, LocalSGDConfig,
                                        broadcast_to_workers,
                                        local_sgd_round, sync_step,
                                        worker_mean)
from repro.core.schedules import SampleSchedule, StepSizeSchedule
from repro.optim.optimizers import apply_updates, sgd


def quad_loss(params, batch):
    x, y = batch
    pred = x @ params["w"] + params["b"]
    return jnp.mean((pred - y) ** 2)


def _data(n, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 3)).astype(np.float32)
    w_true = np.array([1.0, -2.0, 0.5], np.float32)
    y = x @ w_true + 0.1
    return x, y.astype(np.float32)


def _params():
    return {"w": jnp.zeros((3,)), "b": jnp.zeros(())}


def test_single_worker_single_step_equals_serial_sgd():
    """W=1, H=1 local SGD == one plain SGD step, exactly."""
    opt = sgd()
    x, y = _data(8)
    p = _params()
    stacked = jax.tree.map(lambda a: a[None], p)
    opt_state = jax.vmap(opt.init)(stacked)
    batches = (x[None, None], y[None, None])  # [W=1, H=1, ...]
    newp, _, losses = local_sgd_round(quad_loss, opt, stacked, opt_state,
                                      batches, 0.1)
    # serial
    g = jax.grad(quad_loss)(p, (x, y))
    upd, _ = opt.update(g, opt.init(p), p, 0.1)
    want = apply_updates(p, upd)
    got = jax.tree.map(lambda a: a[0], newp)
    np.testing.assert_allclose(got["w"], want["w"], rtol=1e-6)
    np.testing.assert_allclose(got["b"], want["b"], rtol=1e-6)


def test_model_vs_gradient_exchange_equal_for_plain_sgd():
    """At H=1 with plain SGD, averaging models == averaging gradients
    (linearity) — the regime where the paper's two exchange modes agree."""
    opt = sgd()
    x, y = _data(16)
    p = _params()
    W = 4
    stacked = jax.tree.map(lambda a: jnp.broadcast_to(a[None],
                                                      (W,) + a.shape), p)
    opt_state = jax.vmap(opt.init)(stacked)
    xb = x.reshape(W, 4, 3)
    yb = y.reshape(W, 4)
    p_m, _, _ = sync_step(quad_loss, opt, stacked, opt_state, (xb, yb),
                          0.1, exchange="model")
    p_g, _, _ = sync_step(quad_loss, opt, stacked, opt_state, (xb, yb),
                          0.1, exchange="gradient")
    np.testing.assert_allclose(
        np.asarray(jax.tree.map(lambda a: a[0], p_m)["w"]),
        np.asarray(jax.tree.map(lambda a: a[0], p_g)["w"]), rtol=1e-5)


def test_identical_workers_identical_data_stay_identical():
    opt = sgd()
    x, y = _data(8)
    p = _params()
    W, H = 3, 2
    stacked = jax.tree.map(lambda a: jnp.broadcast_to(a[None],
                                                      (W,) + a.shape), p)
    opt_state = jax.vmap(opt.init)(stacked)
    xb = np.broadcast_to(x[None, None], (W, H, 8, 3))
    yb = np.broadcast_to(y[None, None], (W, H, 8))
    newp, _, _ = local_sgd_round(quad_loss, opt, stacked, opt_state,
                                 (jnp.asarray(xb), jnp.asarray(yb)), 0.05)
    for leaf in jax.tree_util.tree_leaves(newp):
        for w in range(1, W):
            np.testing.assert_allclose(leaf[0], leaf[w], rtol=1e-6)


def test_worker_mean_and_broadcast_roundtrip():
    t = {"w": jnp.arange(6.0).reshape(3, 2)}
    avg = worker_mean(t)
    np.testing.assert_allclose(avg["w"], t["w"].mean(0))
    back = broadcast_to_workers(avg, t)
    assert back["w"].shape == t["w"].shape


def test_trainer_accounting_and_convergence():
    x, y = _data(512)
    cfg = LocalSGDConfig(n_workers=2, schedule=SampleSchedule(a=4),
                         stepsize=StepSizeSchedule(eta0=0.05, beta=0.0))
    trainer = AsyncLocalSGD(quad_loss, sgd(), cfg)
    stacked, opt_state = trainer.init(_params())
    rng = np.random.default_rng(0)
    for r in range(1, 13):
        h = trainer.local_steps_for_round(r)
        idx = rng.integers(0, 512, size=(2, h, 32))
        batches = (x[idx], y[idx])
        stacked, opt_state, loss = trainer.run_round(stacked, opt_state,
                                                     batches)
    assert trainer.rounds_done == 12
    assert trainer.communications == 12
    # linear schedule: iterations >> rounds
    assert trainer.iterations_done > 5 * trainer.rounds_done
    assert trainer.loss_history[-1] < trainer.loss_history[0] * 0.2
    assert trainer.communication_bytes(stacked) == \
        12 * 2 * 2 * trainer.model_bytes(stacked)


def test_delayed_average_consumed_exactly_at_round_r_plus_tau():
    """Definition 1, exactly: with staleness tau the round-r average is
    consumed at round r + tau — verified against a numpy simulation of
    the recursion w <- avg^{(r)} + (w - w^{(r)}), whose values shift if
    consumption is off by even one round."""
    W, H, B, tau, R = 3, 2, 4, 2, 6
    lr = 0.05

    def lin_loss(params, batch):
        # gradient wrt w is exactly mean(x, axis=0): every local step is
        # a predictable constant move, so the whole run is replayable
        (x,) = batch
        return jnp.vdot(params["w"], jnp.mean(x, axis=0))

    cfg = LocalSGDConfig(n_workers=W, tau=tau,
                         stepsize=StepSizeSchedule(eta0=lr, beta=0.0))
    trainer = AsyncLocalSGD(lin_loss, sgd(), cfg)
    stacked, opt_state = trainer.init({"w": jnp.zeros((3,))})

    rng = np.random.default_rng(7)
    rounds = [rng.standard_normal((W, H, B, 3)).astype(np.float32)
              for _ in range(R)]

    # numpy reference of the paper's recursion
    pw = np.zeros((W, 3), np.float64)
    queue, expected_consumed = [], []
    for r, g in enumerate(rounds, start=1):
        for w in range(W):
            for h in range(H):
                pw[w] -= lr * g[w, h].mean(axis=0)
        queue.append((pw.mean(axis=0), pw.copy(), r))
        if len(queue) > tau:
            avg_old, snap_old, r_old = queue.pop(0)
            expected_consumed.append((r, r_old))
            pw = avg_old[None] + (pw - snap_old)

    for g in rounds:
        stacked, opt_state, _ = trainer.run_round(stacked, opt_state, (g,))

    assert trainer.consumed_rounds == expected_consumed
    # consumption starts at round tau + 1 and lags by exactly tau
    assert expected_consumed == [(r, r - tau) for r in range(tau + 1, R + 1)]
    np.testing.assert_allclose(np.asarray(stacked["w"]), pw, rtol=1e-5,
                               atol=1e-6)


def test_gradient_exchange_forces_single_local_step():
    """Paper footnote **: gradient exchange communicates every iteration,
    so a round collapses to H == 1 — the trainer enforces it."""
    opt = sgd()
    x, y = _data(16)
    cfg = LocalSGDConfig(n_workers=4, exchange="gradient",
                         schedule=SampleSchedule(a=16),
                         stepsize=StepSizeSchedule(eta0=0.1, beta=0.0))
    trainer = AsyncLocalSGD(quad_loss, opt, cfg)
    # the schedule may ask for many local steps; gradient exchange pins 1
    for i in (1, 2, 5, 20):
        assert trainer.local_steps_for_round(i) == 1

    stacked, opt_state = trainer.init(_params())
    xb = x.reshape(4, 1, 4, 3)
    yb = y.reshape(4, 1, 4)
    newp, _, _ = trainer.run_round(stacked, opt_state, (xb, yb))
    assert trainer.iterations_done == 4 and trainer.communications == 1
    # matches the synchronous gradient-averaging baseline exactly
    want, _, _ = sync_step(quad_loss, opt, stacked, opt_state,
                           (xb[:, 0], yb[:, 0]), trainer.cfg.stepsize(0),
                           exchange="gradient")
    np.testing.assert_allclose(np.asarray(newp["w"]),
                               np.asarray(want["w"]), rtol=1e-6)

    # an H=2 round is a contract violation, not a silent average
    xb2 = np.broadcast_to(x.reshape(4, 1, 4, 3), (4, 2, 4, 3))
    yb2 = np.broadcast_to(y.reshape(4, 1, 4), (4, 2, 4))
    with pytest.raises(ValueError, match="H == 1"):
        trainer.run_round(newp, opt_state, (jnp.asarray(xb2),
                                            jnp.asarray(yb2)))


def test_gradient_exchange_config_validation():
    with pytest.raises(ValueError):
        LocalSGDConfig(exchange="gradient", tau=1)  # staleness is a
        # model-exchange concept; gradient exchange is synchronous
    with pytest.raises(ValueError):
        LocalSGDConfig(exchange="momentum")


def test_stale_averaging_satisfies_definition_1():
    """tau=1: the model applied at round r contains the global average of
    round r-1 — never older (Definition 1 with constant tau)."""
    x, y = _data(64)
    cfg = LocalSGDConfig(n_workers=2, tau=1,
                         schedule=SampleSchedule(a=2),
                         stepsize=StepSizeSchedule(eta0=0.05, beta=0.0))
    trainer = AsyncLocalSGD(quad_loss, sgd(), cfg)
    stacked, opt_state = trainer.init(_params())
    rng = np.random.default_rng(1)
    for r in range(1, 6):
        h = trainer.local_steps_for_round(r)
        idx = rng.integers(0, 64, size=(2, h, 16))
        stacked, opt_state, _ = trainer.run_round(stacked, opt_state,
                                                  (x[idx], y[idx]))
        assert len(trainer._avg_queue) <= cfg.tau
    # still converges despite staleness
    assert trainer.loss_history[-1] < trainer.loss_history[0]

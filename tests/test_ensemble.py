"""Ensemble forecasting plane (ISSUE 9): composable model-set serving
with EVT-weighted fusion and the anomaly-aware alert path.

Acceptance pinned here:

- an N-member ensemble predict (and step flush) issues exactly N fused
  per-model dispatches — never N×batch singles (asserted via
  ``kernels.dispatch.counting()``);
- each member's row in the fused result is bitwise-identical to serving
  that member solo through the same engine;
- ensemble specs validate members at registration and swap atomically
  under a monotone version;
- anomaly mode widens the alert threshold and tightens the batcher's
  effective ``max_wait``;
- the mesh co-locates every member of a client's ensemble request on
  ONE shard (rendezvous on client_id only);
- per-member ``model`` labels flow through telemetry into the
  Prometheus export.
"""

import time

import jax
import numpy as np
import pytest

from repro.kernels import dispatch
from repro.models.rnn import RNNConfig, init_rnn
from repro.obs import render_prometheus
from repro.serving import (BatcherConfig, EnsembleForecaster, EnsembleFuser,
                           EnsembleSpec, LSTMForecaster, ModelRegistry,
                           ServingEngine, ShardedServingEngine, Telemetry,
                           fusion_weights)

CFG = RNNConfig(input_dim=5, hidden=16, num_layers=2, fc_dims=(8, 4),
                window=20, evl_head=True)


def _forecaster(seed: int) -> LSTMForecaster:
    fc = LSTMForecaster(cfg=CFG, params=init_rnn(jax.random.PRNGKey(seed),
                                                 CFG))
    rng = np.random.default_rng(seed)
    fc.calibrate(rng.standard_normal((64, CFG.window, 5)).astype(np.float32)
                 * 0.02)
    return fc


@pytest.fixture(scope="module")
def members():
    return _forecaster(0), _forecaster(1)


@pytest.fixture()
def registry(members):
    reg = ModelRegistry()
    reg.register("m1", members[0])
    reg.register("m2", members[1])
    reg.register_ensemble("ens", ["m1", "m2"])
    return reg


def _windows(n, t=20, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, t, 5)).astype(np.float32) * 0.02


# -- spec hosting ----------------------------------------------------------

def test_spec_validation(registry):
    with pytest.raises(KeyError):
        registry.register_ensemble("bad", ["m1", "ghost"])
    with pytest.raises(ValueError):
        registry.register_ensemble("m1", ["m1"])    # name is a model key
    with pytest.raises(ValueError):
        EnsembleSpec(members=("m1", "m1"))          # duplicate members
    with pytest.raises(ValueError):
        EnsembleSpec(members=())
    with pytest.raises(ValueError):
        EnsembleSpec(members=("m1",), anomaly_enter=0.3, anomaly_exit=0.6)
    with pytest.raises(KeyError):
        registry.swap_ensemble("ghost", ["m1"])


def test_spec_swap_is_atomic_and_versioned(registry):
    assert registry.ensemble_version("ens") == 1
    v = registry.swap_ensemble("ens", ["m2"])
    assert v == 2
    assert registry.ensemble("ens").members == ("m2",)
    # invalid swap leaves the hosted spec untouched
    with pytest.raises(KeyError):
        registry.swap_ensemble("ens", ["m2", "ghost"])
    assert registry.ensemble("ens").members == ("m2",)
    assert registry.ensemble_version("ens") == 2


def test_spec_wire_roundtrip():
    spec = EnsembleSpec(members=("a", "b"), temperature=0.5,
                        alert_threshold=0.8, anomaly_wait_scale=0.5)
    assert EnsembleSpec.from_wire(spec.to_wire()) == spec


def test_install_ensemble_skips_stale(registry):
    spec_new = EnsembleSpec(members=("m2",))
    assert registry.install_ensemble("ens", spec_new, 5)
    # older version must not clobber the newer spec
    assert not registry.install_ensemble(
        "ens", EnsembleSpec(members=("m1",)), 3)
    assert registry.ensemble("ens").members == ("m2",)
    assert registry.ensemble_version("ens") == 5


# -- fusion weighting ------------------------------------------------------

def test_fusion_weights_basics():
    w = fusion_weights([1.0, 1.0], [0.0, 0.0])
    np.testing.assert_allclose(w, [0.5, 0.5])
    # lower rolling error -> more weight; sharper EVT prior -> more weight
    w = fusion_weights([1.0, 1.0], [0.1, 2.0])
    assert w[0] > w[1]
    w = fusion_weights([5.0, 1.0], [0.3, 0.3])
    assert w[0] > w[1]
    # single member: exactly 1.0 (not approximately)
    assert fusion_weights([3.0], [7.0])[0] == 1.0
    # pathological histories stay convex
    w = fusion_weights([np.inf, -1.0], [np.nan, np.inf])
    assert np.all(w >= 0.0) and np.isclose(w.sum(), 1.0)


def test_fuser_supervised_errors_shift_weights():
    fuser = EnsembleFuser(2, EnsembleSpec(members=("a", "b"),
                                          error_half_life=1.0))
    for _ in range(8):
        fuser.record_errors([0.0, 5.0])
    w = fuser.weights()
    assert w[0] > 0.9 > w[1]


# -- anomaly-aware alert path ----------------------------------------------

def test_anomaly_hysteresis_widens_alerts_and_tightens_wait():
    spec = EnsembleSpec(members=("a", "b"), alert_threshold=0.9,
                        anomaly_enter=0.6, anomaly_exit=0.3,
                        anomaly_alert_scale=0.5, anomaly_wait_scale=0.25,
                        anomaly_half_life=1.0)
    fuser = EnsembleFuser(2, spec)
    assert not fuser.anomaly
    assert fuser.alert_threshold() == pytest.approx(0.9)
    assert fuser.wait_scale() == 1.0
    calm = [np.zeros(4, np.float32)] * 2
    hot = [np.full(4, 0.95, np.float32)] * 2
    for _ in range(6):                        # extreme regime: EWMA rises
        fuser.fuse(calm, hot)
    assert fuser.anomaly
    assert fuser.alert_threshold() == pytest.approx(0.45)   # widened
    assert fuser.wait_scale() == pytest.approx(0.25)        # flush sooner
    # hysteresis: one calm batch (EWMA still >= exit) stays anomalous
    fuser.fuse(calm, [np.full(4, 0.35, np.float32)] * 2)
    assert fuser.anomaly
    for _ in range(8):                        # calm regime: EWMA decays
        fuser.fuse(calm, [np.zeros(4, np.float32)] * 2)
    assert not fuser.anomaly
    assert fuser.alert_threshold() == pytest.approx(0.9)


def test_engine_anomaly_tightens_effective_wait(registry):
    cfg = BatcherConfig(max_batch=8, max_wait_ms=2.0)
    with ServingEngine(registry, cfg) as eng:
        rt = eng._ensemble("ens")
        spec = registry.ensemble("ens")
        assert eng._wait_scale("ens") == 1.0
        hot = [np.full(2, 0.97, np.float32)] * 2
        for _ in range(40):                   # flip the fuser anomalous
            rt.fuser().fuse([np.zeros(2, np.float32)] * 2, hot)
        assert rt.fuser().anomaly
        eng._note_anomaly("ens", spec, rt)
        # the ensemble AND its members flush on the tightened deadline
        assert eng._wait_scale("ens") == pytest.approx(
            spec.anomaly_wait_scale)
        assert eng._wait_scale("m1") == pytest.approx(
            spec.anomaly_wait_scale)
        assert eng.telemetry.snapshot()["anomaly_mode"] == 1
        # recovery clears the overrides
        for _ in range(64):
            rt.fuser().fuse([np.zeros(2, np.float32)] * 2,
                            [np.zeros(2, np.float32)] * 2)
        eng._note_anomaly("ens", spec, rt)
        assert eng._wait_scale("ens") == 1.0
        assert eng._wait_scale("m1") == 1.0
        assert eng.telemetry.snapshot()["anomaly_mode"] == 0


# -- engine fan-out / fan-in -----------------------------------------------

def test_predict_fans_out_exactly_n_fused_dispatches(registry, members):
    cfg = BatcherConfig(max_batch=8, max_wait_ms=2.0)
    with ServingEngine(registry, cfg) as eng:
        eng.warmup("ens", lengths=(20,))
        w = _windows(1, seed=3)[0]
        eng.predict("ens", w, timeout=30.0)          # steady state
        with dispatch.counting() as counts:
            y, p = eng.predict("ens", w, timeout=30.0)
        # one ensemble request = exactly N per-model fused predicts
        assert counts.by_op() == {"predict": 2}
        assert np.isfinite(y) and 0.0 <= p <= 1.0


def test_fan_in_future_carries_member_attribution(registry, members):
    cfg = BatcherConfig(max_batch=8, max_wait_ms=2.0)
    with ServingEngine(registry, cfg) as eng:
        eng.warmup("ens", lengths=(20,))
        w = _windows(1, seed=4)[0]
        fut = eng.submit("ens", w)
        y, p = fut.result(timeout=30.0)
        assert sorted(fut.members) == ["m1", "m2"]
        assert fut.model_version == (1, 1)
        assert np.isclose(np.sum(fut.weights), 1.0)
        assert fut.alert == (p >= fut.alert_threshold)
        # fused forecast is the convex member combination
        ys = np.array([fut.members[k][0] for k in ("m1", "m2")])
        assert min(ys) - 1e-6 <= y <= max(ys) + 1e-6


def test_member_rows_bitwise_equal_solo_serving(registry, members):
    """Fanned-out member requests ride the same per-model buckets as
    solo traffic, so each member's row is bitwise what the member
    serves alone."""
    cfg = BatcherConfig(max_batch=8, max_wait_ms=2.0)
    w = _windows(1, seed=5)[0]
    with ServingEngine(registry, cfg) as eng:
        eng.warmup("ens", lengths=(20,))
        fut = eng.submit("ens", w)
        fut.result(timeout=30.0)
        solo = {k: eng.predict(k, w, timeout=30.0) for k in ("m1", "m2")}
    for k in ("m1", "m2"):
        assert fut.members[k][0] == solo[k][0]       # bitwise, not approx
        assert fut.members[k][1] == solo[k][1]


def test_step_flush_is_n_fused_dispatches(registry):
    """A streaming flush under an ensemble advances EVERY resident
    session through each member's fused decode lane: N slots_generate
    dispatches per tick, zero per-session singles."""
    cfg = BatcherConfig(max_batch=8, max_wait_ms=4.0, decode_slots=8)
    clients = [f"c{i}" for i in range(3)]
    with ServingEngine(registry, cfg) as eng:
        eng.warmup("ens", lengths=(20,))
        hist = _windows(1, seed=6)[0]
        x1 = _windows(1, seed=7)[0][0]
        # first wave: sessions replay + insert into the decode lanes
        futs = [eng.submit_step("ens", c, x1, history=hist)
                for c in clients]
        [f.result(timeout=30.0) for f in futs]
        before = eng.telemetry.snapshot()["step_batches"]
        with dispatch.counting() as counts:
            futs = [eng.submit_step("ens", c, x1) for c in clients]
            got = [f.result(timeout=30.0) for f in futs]
        flushes = eng.telemetry.snapshot()["step_batches"] - before
        by_op = counts.by_op()
        assert by_op.get("slots_generate", 0) == 2 * flushes
        assert "decode_step" not in by_op            # no singles
        assert by_op.get("decode_many", 0) == 0
        assert all(0.0 <= p <= 1.0 for _, p in got)


def test_ensemble_session_survives_spill(registry):
    """Composite {member: carry} session state spills off the decode
    lanes and reloads bitwise: steps after a spill continue the same
    stream. A singleton ensemble pins this bitwise (multi-member fused
    values evolve with the shared rolling-error state by design)."""
    registry.register_ensemble("solo", ["m1"])
    cfg = BatcherConfig(max_batch=8, max_wait_ms=4.0, decode_slots=8)
    with ServingEngine(registry, cfg) as eng:
        eng.warmup("solo", lengths=(20,))
        hist = _windows(1, seed=8)[0]
        xs = _windows(1, seed=9)[0]
        ref = []
        for t in range(3):
            ref.append(eng.step("solo", "spill-me", xs[t],
                                history=hist if t == 0 else None))
        # same stream, spilled off the lanes mid-way through
        for t in range(2):
            eng.step("solo", "spill-2", xs[t],
                     history=hist if t == 0 else None)
        eng.spill_sessions(["spill-2"])
        y, p = eng.step("solo", "spill-2", xs[2])
        assert (y, p) == ref[2]


def test_engine_swap_ensemble_changes_fusion(registry, members):
    cfg = BatcherConfig(max_batch=8, max_wait_ms=2.0)
    w = _windows(1, seed=10)[0]
    with ServingEngine(registry, cfg) as eng:
        eng.warmup("ens", lengths=(20,))
        rt = eng._ensemble("ens")
        v_before = rt.version
        registry.swap_ensemble("ens", ["m1"])
        assert rt.version != v_before         # session re-prime trigger
        y, p = eng.predict("ens", w, timeout=30.0)
        y1, p1 = eng.predict("m1", w, timeout=30.0)
        assert y == y1 and p == p1            # singleton == member solo


# -- telemetry + export ----------------------------------------------------

def test_per_member_model_labels_reach_prometheus(registry):
    cfg = BatcherConfig(max_batch=8, max_wait_ms=2.0)
    with ServingEngine(registry, cfg) as eng:
        eng.warmup("ens", lengths=(20,))
        futs = [eng.submit("ens", w) for w in _windows(3, seed=11)]
        [f.result(timeout=30.0) for f in futs]
        snap = eng.telemetry.snapshot()
    assert snap["requests_by_model"]["m1"] == 3
    assert snap["requests_by_model"]["m2"] == 3
    assert snap["ensemble_requests"] == 3
    text = render_prometheus(snap, prefix="repro")
    assert 'repro_requests_by_model{model="m1"} 3' in text
    assert 'repro_requests_by_model{model="m2"} 3' in text
    assert "repro_ensemble_requests 3" in text
    assert "repro_anomaly_mode 0" in text
    line = Telemetry.format(snap)
    assert "by model" in line and "ensemble 3 fused" in line


def test_telemetry_merge_sums_model_labels():
    a, b = Telemetry(), Telemetry()
    a.record_requests([0.01] * 2, model="m1")
    b.record_requests([0.01] * 3, model="m1")
    b.record_requests([0.01], model="m2")
    b.record_ensemble(alerts=1, n=2, anomaly=True)
    merged = Telemetry.merge([a, b])
    assert merged["requests_by_model"] == {"m1": 5, "m2": 1}
    assert merged["ensemble_requests"] == 2
    assert merged["ensemble_alerts"] == 1
    assert merged["anomaly_mode"] == 1


# -- mesh ------------------------------------------------------------------

def test_mesh_colocates_members_on_owning_shard(members):
    """Rendezvous keys on client_id alone: every member of a client's
    ensemble request lands on the client's shard — the fan-in never
    crosses a shard boundary."""
    reg = ModelRegistry()
    reg.register("m1", members[0])
    reg.register("m2", members[1])
    mesh = ShardedServingEngine(reg, BatcherConfig(max_batch=8,
                                                   max_wait_ms=2.0),
                                n_shards=2)
    mesh.register_ensemble("ens", ["m1", "m2"])
    with mesh:
        mesh.warmup("ens", lengths=(20,))
        mesh.reset_clock()
        sid = mesh.shard_for("alice")
        futs = [mesh.submit("ens", w, client_id="alice")
                for w in _windows(4, seed=12)]
        [f.result(timeout=30.0) for f in futs]
        tels = {s: t.snapshot() for s, t in
                zip(sorted(mesh.shards), mesh.shard_telemetries)}
    owner, other = tels[sid], tels[[s for s in tels if s != sid][0]]
    assert owner["requests_by_model"] == {"m1": 4, "m2": 4}
    assert owner["ensemble_requests"] == 4
    assert other.get("requests_by_model", {}) == {}
    assert other["requests"] == 0


def test_mesh_ensemble_swap_propagates(members):
    reg = ModelRegistry()
    reg.register("m1", members[0])
    reg.register("m2", members[1])
    mesh = ShardedServingEngine(reg, BatcherConfig(max_batch=8,
                                                   max_wait_ms=2.0),
                                n_shards=2)
    mesh.register_ensemble("ens", ["m1", "m2"])
    with mesh:
        for replica in mesh.swarm.replicas.values():
            assert replica.ensemble("ens").members == ("m1", "m2")
        mesh.swap_ensemble("ens", ["m2"])
        for replica in mesh.swarm.replicas.values():
            assert replica.ensemble("ens").members == ("m2",)
            assert replica.ensemble_version("ens") == 2
        w = _windows(1, seed=13)[0]
        y, p = mesh.predict("ens", w, client_id="bob", timeout=30.0)
        y2, p2 = mesh.predict("m2", w, client_id="bob", timeout=30.0)
        assert y == y2 and p == p2


def test_mesh_join_seeds_ensemble_specs(members):
    reg = ModelRegistry()
    reg.register("m1", members[0])
    reg.register("m2", members[1])
    mesh = ShardedServingEngine(reg, BatcherConfig(max_batch=8,
                                                   max_wait_ms=2.0),
                                n_shards=1)
    mesh.register_ensemble("ens", ["m1", "m2"])
    with mesh:
        sid = mesh.add_shard()
        replica = mesh.swarm.registry_for(sid)
        assert replica.ensemble("ens").members == ("m1", "m2")

"""Schedule properties — paper Table I / Remark 1."""

import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.schedules import (ConstantSchedule, SampleSchedule,
                                  StepSizeSchedule,
                                  communication_rounds_constant,
                                  round_step_sizes)


def test_paper_schedule_values():
    s = SampleSchedule(a=10, p=1, b=0)      # paper Table I
    assert [s.round_size(i) for i in (1, 2, 3)] == [10, 20, 30]
    assert s.cumulative(3) == 60


def test_rounds_scale_sqrt_k():
    """Remark 1: T ~ sqrt(2K/a) for linear s_i, vs T ~ K/s constant."""
    s = SampleSchedule(a=10, p=1, b=0)
    for k in (1_000, 10_000, 100_000):
        t = s.rounds_for_budget(k)
        assert abs(t - math.sqrt(2 * k / 10)) <= 2
        t_const = communication_rounds_constant(k, 10)
        assert t_const == math.ceil(k / 10)
        assert t < t_const / 3  # dramatic communication reduction


def test_sizes_for_budget_covers_exactly():
    s = SampleSchedule()
    sizes = s.sizes_for_budget(537)
    assert sum(sizes) == 537
    assert all(x >= 1 for x in sizes)


def test_constant_schedule():
    c = ConstantSchedule(size=7)
    assert c.round_size(1) == c.round_size(100) == 7


@given(st.integers(min_value=0, max_value=10**7))
@settings(max_examples=50, deadline=None)
def test_stepsize_positive_and_decreasing(t):
    eta = StepSizeSchedule(eta0=0.01, beta=0.01)   # paper Table I
    assert 0 < eta(t) <= 0.01
    assert eta(t + 1) <= eta(t)


@given(st.floats(min_value=0.5, max_value=100),
       st.floats(min_value=0.5, max_value=2.0),
       st.integers(min_value=1, max_value=200))
@settings(max_examples=50, deadline=None)
def test_schedule_monotone(a, p, i):
    s = SampleSchedule(a=a, p=p, b=0.0)
    assert s.round_size(i + 1) >= s.round_size(i) >= 1


def test_round_step_sizes_uses_cumulative_t():
    s = SampleSchedule(a=10)
    eta = StepSizeSchedule(eta0=0.01, beta=0.01)
    pairs = list(round_step_sizes(s, eta, 3))
    assert pairs[0] == (10, eta(0))
    assert pairs[1] == (20, eta(10))
    assert pairs[2] == (30, eta(30))

"""Launch-layer units that run in the default (1-device) process:
sharding rule construction, input specs, roofline math. The actual
512-device lower+compile runs via ``python -m repro.launch.dryrun``
(separate process; see tests/test_dryrun_subprocess.py)."""

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES
from repro.launch import specs as S
from repro.launch.roofline import roofline_terms
from repro.launch.shardings import batch_axes, cache_specs, param_specs

MS = {"data": 16, "model": 16}
MS3 = {"pod": 2, "data": 16, "model": 16}


def test_batch_axes():
    assert batch_axes(MS, 256) == "data"
    assert batch_axes(MS3, 256) == ("pod", "data")
    assert batch_axes(MS, 1) is None
    assert batch_axes(MS3, 2) == "pod"


def test_param_specs_cover_tree():
    for arch in ("mixtral-8x7b", "mamba2-370m", "whisper-medium",
                 "zamba2-2.7b", "qwen3-moe-235b-a22b"):
        cfg = ARCHS[arch]
        pshape = S.params_shape(cfg)
        spec = param_specs(cfg, pshape, MS)
        leaves_p = jax.tree_util.tree_leaves(pshape)
        leaves_s = jax.tree_util.tree_leaves(
            spec, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves_p) == len(leaves_s)
        # every spec's rank matches its leaf and divisibility holds
        for leaf, sp in zip(leaves_p, leaves_s):
            assert len(sp) <= leaf.ndim
            for dim, ax in zip(leaf.shape, tuple(sp) + (None,) * 8):
                if ax is not None:
                    size = np.prod([MS[a] for a in
                                    (ax if isinstance(ax, tuple) else (ax,))])
                    assert dim % size == 0, (arch, leaf.shape, sp)


def test_fully_sharded_biggest_model_fits():
    """qwen3-moe 235B x (bf16 + f32 m + f32 v) must divide below
    16 GiB/chip under the 2-D param sharding."""
    cfg = ARCHS["qwen3-moe-235b-a22b"]
    pshape = S.params_shape(cfg)
    spec = param_specs(cfg, pshape, MS)
    per_chip = 0
    for leaf, sp in zip(jax.tree_util.tree_leaves(pshape),
                        jax.tree_util.tree_leaves(
                            spec, is_leaf=lambda x: isinstance(x, P))):
        shards = 1
        for ax in sp:
            if ax:
                shards *= np.prod([MS[a] for a in
                                   (ax if isinstance(ax, tuple) else (ax,))])
        bytes_ = leaf.size * leaf.dtype.itemsize
        per_chip += bytes_ / shards * (1 + 4 + 4) / leaf.dtype.itemsize \
            if leaf.dtype == np.dtype("bfloat16") else bytes_ / shards
    # bf16 params + 2x f32 adam: ~10B/param fully sharded
    assert per_chip < 16 * 2**30


def test_input_specs_shapes():
    cfg = ARCHS["mixtral-8x7b"]
    tr = S.input_specs(cfg, "train_4k")
    assert tr["tokens"].shape == (256, 4096)
    de = S.input_specs(cfg, "decode_32k")
    assert de["token"].shape == (128,)
    assert "k" in de["cache"]
    # mixtral is native SWA: decode cache is a 4096-slot ring
    assert de["cache"]["k"].shape[2] == 4096
    lg = S.input_specs(cfg, "long_500k")
    assert lg["cache"]["k"].shape[2] == 4096


def test_full_cache_has_write_buffer():
    cfg = ARCHS["chameleon-34b"]
    de = S.input_specs(cfg, "decode_32k")
    assert de["cache"]["k"].shape[2] == 32768
    assert de["cache"]["kr"].shape[2] == cfg.decode_buffer
    spec = cache_specs(cfg, de["cache"], MS, 128)
    assert spec["kr"] == P(None, "data", None, None, None)
    # kv=8 not divisible by 16: main cache shards its sequence dim
    assert spec["k"] == P(None, "data", "model", None, None)


def test_ssm_cache_specs():
    cfg = ARCHS["mamba2-370m"]
    de = S.input_specs(cfg, "long_500k")
    assert "k" not in de["cache"]          # attention-free
    spec = cache_specs(cfg, de["cache"], MS, 1)
    assert spec["ssm"] == P(None, None, "model", None, None)


def test_roofline_terms_math():
    cfg = ARCHS["qwen1.5-4b"]
    shape = INPUT_SHAPES["train_4k"]
    r = roofline_terms(flops_per_chip=1.97e14, bytes_per_chip=819e9,
                       collective_bytes_per_chip=50e9, chips=256,
                       cfg=cfg, shape=shape)
    assert abs(r["compute_s"] - 1.0) < 1e-6
    assert abs(r["memory_s"] - 1.0) < 1e-6
    assert abs(r["collective_s"] - 1.0) < 1e-6
    assert r["dominant"] in ("compute", "memory", "collective")
    assert r["model_flops"] == 6 * cfg.active_param_count() * 256 * 4096

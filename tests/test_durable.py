"""DurableStore invariants (ISSUE 10): content addressing + dedup,
torn-write detection for blobs AND manifests, keep-last-K retention
with blob garbage collection, the monotone version-merge law, and the
session-frame codec's bitwise round trip. Pure host-side filesystem
tests — no jax, no processes — so this file is cheap.
"""

import os

import numpy as np
import pytest

from repro.serving.durable import (DurableStore, DurableStoreError,
                                   pack_frames_blob, pack_session_frame,
                                   unpack_frames_blob,
                                   unpack_session_frame)


def _store(tmp_path, **kw):
    return DurableStore(str(tmp_path / "state"), **kw)


def test_blob_content_addressing_and_dedup(tmp_path):
    store = _store(tmp_path)
    ref = store.put_blob(b"payload-a")
    assert ref.startswith("sha256:") and len(ref) == len("sha256:") + 64
    assert store.get_blob(ref) == b"payload-a"
    assert store.put_blob(b"payload-a") == ref      # same content, same ref
    assert store.blobs_written == 1 and store.blobs_deduped == 1
    assert store.has_blob(ref)
    assert not store.has_blob("sha256:" + "0" * 64)
    assert not store.has_blob("not-a-ref")


def test_corrupt_blob_refuses_to_load(tmp_path):
    store = _store(tmp_path)
    ref = store.put_blob(b"x" * 1024)
    path = os.path.join(store.blob_dir, ref.split(":", 1)[1])
    data = bytearray(open(path, "rb").read())
    data[100] ^= 0xFF                               # one flipped bit-rot byte
    open(path, "wb").write(bytes(data))
    with pytest.raises(DurableStoreError, match="checksum"):
        store.get_blob(ref)


def test_commit_and_latest_round_trip(tmp_path):
    store = _store(tmp_path)
    ref = store.put_blob(b"weights-v1")
    seq = store.commit({"models": {"m": {"version": 1, "ref": ref}}})
    assert seq == 1
    got_seq, state = store.latest()
    assert got_seq == 1
    assert state["models"]["m"] == {"version": 1, "ref": ref}


def test_torn_manifest_falls_back_to_previous_good(tmp_path):
    """A crash mid-commit leaves a torn newest manifest; latest() must
    skip it (checksum) and serve the previous complete snapshot."""
    store = _store(tmp_path)
    r1 = store.put_blob(b"v1")
    store.commit({"models": {"m": {"version": 1, "ref": r1}}})
    r2 = store.put_blob(b"v2")
    s2 = store.commit({"models": {"m": {"version": 2, "ref": r2}}})
    path = store._manifest_path(s2)
    raw = open(path, "rb").read()
    for torn in (raw[: len(raw) // 2], b"garbage", b""):
        open(path, "wb").write(torn)
        seq, state = store.latest()
        assert seq == s2 - 1
        assert state["models"]["m"]["version"] == 1
    # a manifest referencing a corrupt/missing blob is just as dead
    open(path, "wb").write(raw)                     # manifest healthy again
    os.remove(os.path.join(store.blob_dir, r2.split(":", 1)[1]))
    seq, state = store.latest()
    assert state["models"]["m"]["version"] == 1


def test_retention_keeps_last_k_and_gcs_blobs(tmp_path):
    store = _store(tmp_path, keep_last=2)
    refs = []
    for v in range(1, 6):
        ref = store.put_blob(f"weights-v{v}".encode())
        refs.append(ref)
        store.commit({"models": {"m": {"version": v, "ref": ref}}})
    assert store.manifest_seqs() == [4, 5]          # keep-last-2
    # the kept manifests reference the v4 and v5 blobs; everything
    # older was garbage-collected with its manifest
    assert all(store.has_blob(r) for r in refs[-2:])
    assert not any(store.has_blob(r) for r in refs[:-2])
    assert store.latest()[1]["models"]["m"]["version"] == 5


def test_uncommitted_blobs_survive_concurrent_commits(tmp_path):
    """A blob written ahead of its commit (the daemon serializes
    weights, then a publish commit lands first) must not be reaped by
    that interleaved commit's GC."""
    store = _store(tmp_path, keep_last=1)
    early = store.put_blob(b"checkpoint-in-flight")
    store.commit({"models": {"m": {"version": 1,
                                   "ref": store.put_blob(b"w1")}}})
    assert store.has_blob(early)                    # protected until...
    store.commit({"sessions": {"ref": early, "count": 0}})
    assert store.has_blob(early)                    # ...now referenced
    _, state = store.latest()
    assert state["sessions"]["ref"] == early


def test_merge_is_monotone_per_versioned_entry(tmp_path):
    """The monotone restore law at the store level: a commit carrying
    an OLDER version of an entry (a late daemon snapshot racing a
    publish) can never roll the manifest back; newer versions and
    unrelated keys merge in."""
    store = _store(tmp_path)
    r1, r2, r3 = (store.put_blob(d) for d in (b"1", b"2", b"3"))
    store.commit({"models": {"m": {"version": 2, "ref": r2}}})
    store.commit({"models": {"m": {"version": 1, "ref": r1},   # stale
                             "other": {"version": 7, "ref": r3}}})
    _, state = store.latest()
    assert state["models"]["m"]["version"] == 2     # not resurrected
    assert state["models"]["other"]["version"] == 7
    store.commit({"models": {"m": {"version": 3, "ref": r3}}})
    assert store.latest()[1]["models"]["m"]["version"] == 3


def test_session_frame_codec_round_trips_bitwise(tmp_path):
    rng = np.random.default_rng(0)
    carry = ((rng.standard_normal((1, 8)).astype(np.float32),
              rng.standard_normal((1, 8)).astype(np.float32)),)
    frame = pack_session_frame("client-7", carry, nbytes=64, version=3)
    frames = unpack_frames_blob(pack_frames_blob([frame]))
    cid, got, nbytes, version = unpack_session_frame(frames[0])
    assert (cid, nbytes, version) == ("client-7", 64, 3)
    for a, b in zip(carry[0], got[0]):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(b).dtype == np.float32


def test_keep_last_validation(tmp_path):
    with pytest.raises(ValueError):
        _store(tmp_path, keep_last=0)

"""Extreme-event modeling — paper eqs. (1)-(6)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.extreme.evl import bce_loss, evl_loss, evl_weights
from repro.extreme.evt import fit_tail, gev_cdf, tail_probability
from repro.extreme.indicators import (extreme_fractions, indicator_sequence,
                                      quantile_thresholds)


def test_indicator_partition():
    y = np.array([-5.0, -0.1, 0.0, 0.1, 5.0])
    v = np.asarray(indicator_sequence(y, eps1=1.0, eps2=1.0))
    assert v.tolist() == [-1, 0, 0, 0, 1]


@given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=1, max_size=64),
       st.floats(0.1, 5.0), st.floats(0.1, 5.0))
@settings(max_examples=50, deadline=None)
def test_indicator_total_partition(ys, e1, e2):
    v = np.asarray(indicator_sequence(np.array(ys, np.float32), e1, e2))
    assert set(np.unique(v)).issubset({-1, 0, 1})
    fr = extreme_fractions(v)
    assert abs(fr["normal"] + fr["right"] + fr["left"] - 1.0) < 1e-6


def test_indicator_rejects_bad_thresholds():
    with pytest.raises(ValueError):
        indicator_sequence(np.zeros(3), eps1=-1.0, eps2=1.0)


def test_gev_cdf_monotone_and_bounded():
    y = jnp.linspace(-3, 3, 50)
    for gamma in (0.0, 2.0, 5.0):
        c = np.asarray(gev_cdf(y, gamma))
        assert np.all(c >= 0) and np.all(c <= 1)
        assert np.all(np.diff(c) >= -1e-6)


def test_tail_probability_decreasing():
    # gamma=0 (Gumbel) has unbounded support; gamma!=0 clips at y/gamma=1
    p = fit_tail(np.random.default_rng(0).standard_t(3, 5000), q=0.95)
    ys = np.linspace(p["xi"], p["xi"] + 5 * p["scale"], 20)
    t = np.asarray(tail_probability(ys, p["xi"], p["scale"],
                                    p["tail_at_xi"], gamma=0.0))
    assert np.all(np.diff(t) <= 1e-9)
    # eq. (4) at y=xi gives (1 - log G(0)) = 2x the empirical tail mass
    assert t[0] <= 2 * p["tail_at_xi"] + 1e-6


@given(st.floats(0.01, 0.99), st.integers(0, 1))
@settings(max_examples=100, deadline=None)
def test_evl_nonnegative(u, v):
    loss = float(evl_loss(jnp.array([u]), jnp.array([v]),
                          beta0=0.9, beta1=0.1, gamma=2.0))
    assert loss >= 0.0
    assert np.isfinite(loss)


def test_evl_penalizes_missed_extremes_more():
    """beta0 (large, normal fraction) weights the extreme-class term: a
    missed extreme (v=1, u small) must cost more than a false alarm
    (v=0, u large) under imbalance."""
    missed = float(evl_loss(jnp.array([0.1]), jnp.array([1.0]),
                            beta0=0.95, beta1=0.05))
    false_alarm = float(evl_loss(jnp.array([0.9]), jnp.array([0.0]),
                                 beta0=0.95, beta1=0.05))
    assert missed > false_alarm


def test_evl_weight_structure():
    u = jnp.array([0.1, 0.5, 0.9])
    w_pos, w_neg = evl_weights(u, None, beta0=0.9, beta1=0.1, gamma=2.0)
    # low-confidence extreme detection penalized harder (w_pos decreasing)
    assert np.all(np.diff(np.asarray(w_pos)) < 0)
    assert np.all(np.diff(np.asarray(w_neg)) > 0)


def test_evl_reduces_to_weighted_bce_at_large_gamma():
    """As gamma -> inf, (1 - u/gamma)^gamma -> exp(-u): smooth weights;
    sanity: EVL with beta0=beta1=1, gamma huge ~ e^{-u}-weighted BCE."""
    u = jnp.array([0.3, 0.7])
    v = jnp.array([1.0, 0.0])
    evl = np.asarray(evl_loss(u, v, 1.0, 1.0, gamma=1e6, reduce="none"))
    bce = np.asarray(bce_loss(u, v, reduce="none"))
    w = np.exp(-np.array([0.3, 1 - 0.7]))
    np.testing.assert_allclose(evl, w * bce, rtol=5e-3)


def test_quantile_thresholds_positive():
    y = np.random.default_rng(1).normal(size=1000)
    e1, e2 = quantile_thresholds(y, 0.95)
    assert e1 > 0 and e2 > 0

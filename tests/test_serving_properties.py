"""Property tests for the serving subsystem: session-cache invariants
under arbitrary operation sequences, step-vs-replay carry equivalence
across arbitrary evict/re-prime points, and micro-batcher bucketing laws
(monotone, power-of-two, >= input).

Example counts come from the hypothesis profile (``--hypothesis-profile=ci``
bounds them for the tier-1 timing gate); the exhaustive variants carry the
``slow`` marker.
"""

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.rnn import RNNConfig, init_rnn
from repro.serving import (BatcherConfig, LSTMForecaster,
                           RecurrentSessionRunner, SessionCache)

CFG = RNNConfig(input_dim=3, hidden=8, num_layers=1, fc_dims=(4,),
                window=8, evl_head=True)


@pytest.fixture(scope="module")
def forecaster():
    return LSTMForecaster(cfg=CFG, params=init_rnn(jax.random.PRNGKey(0),
                                                   CFG))


# -- bucketing laws --------------------------------------------------------

def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


@given(st.integers(1, 2048), st.integers(1, 2048))
@settings(deadline=None)
def test_bucket_len_monotone_pow2_geq_without_buckets(t1, t2):
    cfg = BatcherConfig()
    b1, b2 = cfg.bucket_len(t1), cfg.bucket_len(t2)
    assert b1 >= t1 and _is_pow2(b1)
    if t1 <= t2:
        assert b1 <= b2
    # idempotent: a bucketed length is its own bucket
    assert cfg.bucket_len(b1) == b1


@given(st.lists(st.integers(1, 512), min_size=1, max_size=6, unique=True),
       st.integers(1, 600), st.integers(1, 600))
@settings(deadline=None)
def test_bucket_len_monotone_geq_with_buckets(buckets, t1, t2):
    cfg = BatcherConfig(length_buckets=tuple(buckets))
    b1, b2 = cfg.bucket_len(t1), cfg.bucket_len(t2)
    assert b1 >= t1
    assert b1 in buckets or b1 == t1     # a bucket, or its own group
    if t1 <= t2:
        assert b1 <= b2


@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64))
@settings(deadline=None)
def test_bucket_batch_monotone_pow2_geq(max_batch, n1, n2):
    cfg = BatcherConfig(max_batch=max_batch)
    n1, n2 = min(n1, max_batch), min(n2, max_batch)  # engine flushes
    # groups of at most max_batch requests
    b1, b2 = cfg.bucket_batch(n1), cfg.bucket_batch(n2)
    assert n1 <= b1 <= max_batch
    assert _is_pow2(b1) or b1 == max_batch
    if n1 <= n2:
        assert b1 <= b2
    assert BatcherConfig(max_batch=max_batch,
                         pad_batch=False).bucket_batch(n1) == n1


# -- session cache invariants ----------------------------------------------

_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("put"), st.integers(0, 5), st.integers(1, 16)),
        st.tuples(st.just("get"), st.integers(0, 5), st.just(0)),
        st.tuples(st.just("drop"), st.integers(0, 5), st.just(0)),
        st.tuples(st.just("tick"), st.just(0), st.integers(1, 8)),
    ),
    min_size=1, max_size=60)


def _run_cache_ops(ops, max_sessions, max_bytes, ttl_s):
    now = [0.0]
    cache = SessionCache(max_sessions=max_sessions, max_bytes=max_bytes,
                         ttl_s=ttl_s, clock=lambda: now[0])
    for op, k, arg in ops:
        key = f"c{k}"
        if op == "put":
            cache.put(key, f"carry-{k}", arg, version=arg)
        elif op == "get":
            entry = cache.get_entry(key)
            if entry is not None:
                assert entry[0] == f"carry-{k}"
        elif op == "drop":
            cache.drop(key)
        else:
            now[0] += arg
        # the invariants, after every single operation:
        stats = cache.stats()
        assert len(cache) <= max_sessions
        assert stats["sessions"] == len(cache)
        assert stats["nbytes_in_use"] >= 0
        if max_bytes is not None:
            # one oversize session is admitted rather than thrashing
            assert stats["nbytes_in_use"] <= max_bytes or len(cache) == 1
        assert stats["hits"] + stats["misses"] >= 0


@given(_OPS, st.integers(1, 4),
       st.one_of(st.none(), st.integers(8, 48)),
       st.one_of(st.none(), st.floats(1.0, 16.0)))
@settings(deadline=None)
def test_session_cache_never_exceeds_capacity(ops, max_sessions, max_bytes,
                                              ttl_s):
    _run_cache_ops(ops, max_sessions, max_bytes, ttl_s)


@pytest.mark.slow
@given(_OPS, st.integers(1, 4),
       st.one_of(st.none(), st.integers(8, 48)),
       st.one_of(st.none(), st.floats(1.0, 16.0)))
@settings(max_examples=300, deadline=None)
def test_session_cache_never_exceeds_capacity_exhaustive(ops, max_sessions,
                                                         max_bytes, ttl_s):
    _run_cache_ops(ops, max_sessions, max_bytes, ttl_s)


# -- step vs replay equivalence --------------------------------------------

def _stream(forecaster, w, evict_at):
    """Serve window ``w`` step by step, dropping the session (and
    re-priming from history) at every index in ``evict_at``."""
    runner = RecurrentSessionRunner(forecaster,
                                    SessionCache(max_sessions=4))
    y = p = None
    for t in range(w.shape[0]):
        if t in evict_at and t > 0:
            runner.cache.drop("c")
        y, p = runner.step("c", w[t], history=w[:t] if t > 0 else None)
    return y, p


@given(st.integers(0, 2 ** 16 - 1),
       st.sets(st.integers(1, CFG.window - 1), max_size=4))
@settings(deadline=None)
def test_step_replay_equivalence_across_evictions(forecaster, seed,
                                                  evict_at):
    """Evict/re-prime at arbitrary points must be invisible: the final
    forecast equals the uninterrupted session's, bitwise (both paths run
    the same compiled step function)."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((CFG.window, 3)).astype(np.float32) * 0.02
    y_evicted, p_evicted = _stream(forecaster, w, evict_at)
    y_clean, p_clean = _stream(forecaster, w, set())
    assert y_evicted == y_clean
    assert p_evicted == p_clean
    # and both equal a raw replay through the compiled step path
    y_ref, p_ref, _ = forecaster.replay(w[None])
    assert y_clean == float(y_ref[0]) and p_clean == float(p_ref[0])

"""Property tests for the serving subsystem: session-cache invariants
under arbitrary operation sequences, step-vs-replay carry equivalence
across arbitrary evict/re-prime points, micro-batcher bucketing laws
(monotone, power-of-two, >= input), consistent-hash routing laws
(stable, balanced, minimally disruptive on shard join/leave), the
swap-propagation staleness skew bound, and the durable restore laws
(restore is monotone in acknowledged publishes under arbitrary
publish/late-checkpoint/crash/restore interleavings; restored session
frames bitwise equal a spill/reload round trip).

Example counts come from the hypothesis profile (``--hypothesis-profile=ci``
bounds them for the tier-1 timing gate); the exhaustive variants carry the
``slow`` marker.
"""

from types import SimpleNamespace

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.rnn import RNNConfig, init_rnn
from repro.serving import (BatcherConfig, ConsistentRouter, LSTMForecaster,
                           ModelRegistry, RecurrentSessionRunner,
                           SessionCache, ShardSwarm, ShardedServingEngine,
                           ShardedSessionCache)

CFG = RNNConfig(input_dim=3, hidden=8, num_layers=1, fc_dims=(4,),
                window=8, evl_head=True)


@pytest.fixture(scope="module")
def forecaster():
    return LSTMForecaster(cfg=CFG, params=init_rnn(jax.random.PRNGKey(0),
                                                   CFG))


# -- bucketing laws --------------------------------------------------------

def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


@given(st.integers(1, 2048), st.integers(1, 2048))
@settings(deadline=None)
def test_bucket_len_monotone_pow2_geq_without_buckets(t1, t2):
    cfg = BatcherConfig()
    b1, b2 = cfg.bucket_len(t1), cfg.bucket_len(t2)
    assert b1 >= t1 and _is_pow2(b1)
    if t1 <= t2:
        assert b1 <= b2
    # idempotent: a bucketed length is its own bucket
    assert cfg.bucket_len(b1) == b1


@given(st.lists(st.integers(1, 512), min_size=1, max_size=6, unique=True),
       st.integers(1, 600), st.integers(1, 600))
@settings(deadline=None)
def test_bucket_len_monotone_geq_with_buckets(buckets, t1, t2):
    cfg = BatcherConfig(length_buckets=tuple(buckets))
    b1, b2 = cfg.bucket_len(t1), cfg.bucket_len(t2)
    assert b1 >= t1
    assert b1 in buckets or b1 == t1     # a bucket, or its own group
    if t1 <= t2:
        assert b1 <= b2


@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64))
@settings(deadline=None)
def test_bucket_batch_monotone_pow2_geq(max_batch, n1, n2):
    cfg = BatcherConfig(max_batch=max_batch)
    # a non-pow2 max_batch is rounded DOWN at construction, so every
    # emitted batch shape is a power of two — the fixed compile-set
    # contract ("{pow2 batches} x {length buckets}") holds unconditionally
    assert _is_pow2(cfg.max_batch) and cfg.max_batch <= max_batch
    n1, n2 = min(n1, cfg.max_batch), min(n2, cfg.max_batch)  # engine
    # flushes groups of at most (the effective) max_batch requests
    b1, b2 = cfg.bucket_batch(n1), cfg.bucket_batch(n2)
    assert n1 <= b1 <= cfg.max_batch
    assert _is_pow2(b1)
    if n1 <= n2:
        assert b1 <= b2
    assert BatcherConfig(max_batch=max_batch,
                         pad_batch=False).bucket_batch(n1) == n1


# -- consistent-hash routing laws ------------------------------------------

_CLIENT_IDS = st.lists(st.text(min_size=1, max_size=12), min_size=1,
                       max_size=40, unique=True)


@given(_CLIENT_IDS, st.integers(1, 8))
@settings(deadline=None)
def test_routing_stable_across_router_instances(client_ids, n_shards):
    """Same client -> same shard, on this router and on any freshly
    built router with the same shard set (the hash is keyed on bytes,
    not Python's per-process seeded hash)."""
    r1 = ConsistentRouter(range(n_shards))
    r2 = ConsistentRouter(range(n_shards))
    for cid in client_ids:
        sid = r1.shard_for(cid)
        assert 0 <= sid < n_shards
        assert r1.shard_for(cid) == sid          # idempotent
        assert r2.shard_for(cid) == sid          # instance-independent


@given(st.integers(2, 8))
@settings(deadline=None)
def test_routing_balanced_within_tolerance(n_shards):
    """Uniform scores split a large client population evenly-ish: every
    shard within ±50% of the fair share (loose — rendezvous hashing is
    binomially concentrated, ~±4 sigma here)."""
    router = ConsistentRouter(range(n_shards))
    n_clients = 256 * n_shards
    counts = [0] * n_shards
    for i in range(n_clients):
        counts[router.shard_for(f"client-{i}")] += 1
    fair = n_clients / n_shards
    assert min(counts) >= 0.5 * fair, counts
    assert max(counts) <= 1.5 * fair, counts


@given(_CLIENT_IDS, st.integers(2, 6), st.data())
@settings(deadline=None)
def test_routing_minimal_disruption_on_leave(client_ids, n_shards, data):
    """Removing a shard moves ONLY the clients that lived on it."""
    router = ConsistentRouter(range(n_shards))
    before = {cid: router.shard_for(cid) for cid in client_ids}
    victim = data.draw(st.integers(0, n_shards - 1))
    router.remove_shard(victim)
    for cid, old in before.items():
        new = router.shard_for(cid)
        if old != victim:
            assert new == old                    # survivors keep clients
        else:
            assert new != victim                 # victims are re-homed


@given(_CLIENT_IDS, st.integers(1, 6))
@settings(deadline=None)
def test_routing_minimal_disruption_on_join(client_ids, n_shards):
    """Adding a shard only moves clients TO the new shard — no client
    is shuffled between two surviving shards."""
    router = ConsistentRouter(range(n_shards))
    before = {cid: router.shard_for(cid) for cid in client_ids}
    router.add_shard(n_shards)
    for cid, old in before.items():
        assert router.shard_for(cid) in (old, n_shards)


# -- live membership: the assignment laws extend to the full stack --------

@given(_CLIENT_IDS, st.data())
@settings(deadline=None)
def test_sharded_cache_membership_assignment_laws(client_ids, data):
    """Across interleaved add_shard/remove_shard on a live
    ``ShardedSessionCache``, only the departing/arriving shards' clients
    move — and every cached carry survives every change, retrievable
    with its original value and version stamp."""
    cache = ShardedSessionCache(n_shards=3, max_sessions=256)
    for i, cid in enumerate(client_ids):
        cache.put(cid, f"carry-{cid}", 8, version=i)
    next_sid = 3
    for _ in range(data.draw(st.integers(1, 6))):
        owners = {cid: cache.shard_for(cid) for cid in client_ids}
        if len(cache.shards) > 1 and data.draw(st.booleans()):
            victim = data.draw(st.sampled_from(sorted(cache.shards)))
            cache.remove_shard(victim)
            for cid, old in owners.items():
                new = cache.shard_for(cid)
                if old != victim:
                    assert new == old            # survivors keep clients
                else:
                    assert new != victim         # victims are re-homed
        else:
            sid = next_sid
            next_sid += 1
            cache.add_shard(sid)
            for cid, old in owners.items():
                assert cache.shard_for(cid) in (old, sid)
        # the fleet budget is re-split, never exceeded
        assert sum(s.max_sessions for s in cache.shards.values()) <= 256
        # migration is lossless: every carry still lives on its
        # (possibly new) owner shard
        for i, cid in enumerate(client_ids):
            assert cache.get_entry(cid) == (f"carry-{cid}", i)
            assert cid in cache.shards[cache.shard_for(cid)]


class _StubForecaster:
    """Minimal forecaster for engine-level membership laws: token-shaped
    windows, instant predict (no jax on the property-test hot path)."""

    feature_dim = 0
    window = 8
    version = 1
    published_at = None

    def predict(self, x, lens):
        n = len(x)
        return (np.zeros((n,), np.float32), np.zeros((n,), np.float32))


@given(_CLIENT_IDS, st.data())
@settings(deadline=None)
def test_mesh_membership_assignment_laws(client_ids, data):
    """Interleaved add_shard/remove_shard on a LIVE ShardedServingEngine:
    routing keeps the assignment laws, and every client is still served
    (on its possibly-new shard) after each change."""
    reg = ModelRegistry()
    reg.register("m", _StubForecaster())
    mesh = ShardedServingEngine(
        reg, BatcherConfig(max_batch=4, max_wait_ms=1.0,
                           length_buckets=(8,)), n_shards=2)
    with mesh:
        for _ in range(data.draw(st.integers(1, 4))):
            owners = {cid: mesh.shard_for(cid) for cid in client_ids}
            if mesh.n_shards > 1 and data.draw(st.booleans()):
                victim = data.draw(st.sampled_from(mesh.shard_ids))
                mesh.remove_shard(victim)
                for cid, old in owners.items():
                    new = mesh.shard_for(cid)
                    if old != victim:
                        assert new == old
                    else:
                        assert new != victim
            else:
                sid = mesh.add_shard()
                for cid, old in owners.items():
                    assert mesh.shard_for(cid) in (old, sid)
            # router and worker set stay in lockstep
            assert sorted(mesh.router.shard_ids) == mesh.shard_ids
        futs = [mesh.submit("m", np.zeros((8,), np.int32), client_id=cid)
                for cid in client_ids[:8]]
        for f in futs:
            assert f.result(timeout=10.0) == (0.0, 0.0)


# -- swap-propagation staleness bound --------------------------------------

@given(st.integers(1, 4), st.integers(0, 3), st.integers(1, 10))
@settings(deadline=None)
def test_swap_propagation_skew_bound(n_shards, max_skew, n_publishes):
    """After every publish through the swarm facade, no shard lags the
    primary by more than max_skew versions — and a final propagate
    converges the whole fleet to the newest version."""
    swarm = ShardSwarm(n_shards, max_skew=max_skew)
    swarm.register("m", SimpleNamespace(tag="v1"))
    for i in range(2, n_publishes + 2):
        swarm.swap("m", SimpleNamespace(tag=f"v{i}"))
        vec = swarm.version_vector("m")
        shard_vs = [v for k, v in vec.items() if k != "primary"]
        assert vec["primary"] - min(shard_vs) <= max_skew, vec
        assert max(shard_vs) <= vec["primary"]   # replicas never ahead
    swarm.propagate("m")
    vec = swarm.version_vector("m")
    assert set(vec.values()) == {n_publishes + 1}, vec


# -- session cache invariants ----------------------------------------------

_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("put"), st.integers(0, 5), st.integers(1, 16)),
        st.tuples(st.just("get"), st.integers(0, 5), st.just(0)),
        st.tuples(st.just("drop"), st.integers(0, 5), st.just(0)),
        st.tuples(st.just("tick"), st.just(0), st.integers(1, 8)),
    ),
    min_size=1, max_size=60)


def _run_cache_ops(ops, max_sessions, max_bytes, ttl_s):
    now = [0.0]
    cache = SessionCache(max_sessions=max_sessions, max_bytes=max_bytes,
                         ttl_s=ttl_s, clock=lambda: now[0])
    for op, k, arg in ops:
        key = f"c{k}"
        if op == "put":
            cache.put(key, f"carry-{k}", arg, version=arg)
        elif op == "get":
            entry = cache.get_entry(key)
            if entry is not None:
                assert entry[0] == f"carry-{k}"
        elif op == "drop":
            cache.drop(key)
        else:
            now[0] += arg
        # the invariants, after every single operation:
        stats = cache.stats()
        assert len(cache) <= max_sessions
        assert stats["sessions"] == len(cache)
        assert stats["nbytes_in_use"] >= 0
        if max_bytes is not None:
            # one oversize session is admitted rather than thrashing
            assert stats["nbytes_in_use"] <= max_bytes or len(cache) == 1
        assert stats["hits"] + stats["misses"] >= 0


@given(_OPS, st.integers(1, 4),
       st.one_of(st.none(), st.integers(8, 48)),
       st.one_of(st.none(), st.floats(1.0, 16.0)))
@settings(deadline=None)
def test_session_cache_never_exceeds_capacity(ops, max_sessions, max_bytes,
                                              ttl_s):
    _run_cache_ops(ops, max_sessions, max_bytes, ttl_s)


@pytest.mark.slow
@given(_OPS, st.integers(1, 4),
       st.one_of(st.none(), st.integers(8, 48)),
       st.one_of(st.none(), st.floats(1.0, 16.0)))
@settings(max_examples=300, deadline=None)
def test_session_cache_never_exceeds_capacity_exhaustive(ops, max_sessions,
                                                         max_bytes, ttl_s):
    _run_cache_ops(ops, max_sessions, max_bytes, ttl_s)


# -- step vs replay equivalence --------------------------------------------

def _stream(forecaster, w, evict_at):
    """Serve window ``w`` step by step, dropping the session (and
    re-priming from history) at every index in ``evict_at``.  The
    session may be lane-resident, so a real eviction is spill (lane ->
    cache) followed by the cache drop."""
    runner = RecurrentSessionRunner(forecaster,
                                    SessionCache(max_sessions=4))
    y = p = None
    for t in range(w.shape[0]):
        if t in evict_at and t > 0:
            runner.spill(["c"])
            runner.cache.drop("c")
        y, p = runner.step("c", w[t], history=w[:t] if t > 0 else None)
    return y, p


@given(st.integers(0, 2 ** 16 - 1),
       st.sets(st.integers(1, CFG.window - 1), max_size=4))
@settings(deadline=None)
def test_step_replay_equivalence_across_evictions(forecaster, seed,
                                                  evict_at):
    """Evict/re-prime at arbitrary points must be invisible: the final
    forecast equals the uninterrupted session's, bitwise (both paths run
    the same compiled step function)."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((CFG.window, 3)).astype(np.float32) * 0.02
    y_evicted, p_evicted = _stream(forecaster, w, evict_at)
    y_clean, p_clean = _stream(forecaster, w, set())
    assert y_evicted == y_clean
    assert p_evicted == p_clean
    # and both equal a raw replay through the compiled step path
    y_ref, p_ref, _ = forecaster.replay(w[None])
    assert y_clean == float(y_ref[0]) and p_clean == float(p_ref[0])


# -- batched-step vs per-session-step equivalence --------------------------

def _check_batched_equals_sequential(forecaster, seed, n_clients, n_ticks,
                                     evictions):
    """Serving every tick as one ``step_many`` flush must produce
    BITWISE the results of the per-session ``step`` loop, under
    arbitrary mid-stream evictions (history is supplied, so evicted
    sessions re-prime in both modes)."""
    rng = np.random.default_rng(seed)
    xs = rng.standard_normal(
        (n_ticks, n_clients, 3)).astype(np.float32) * 0.02
    evict = {(t, c % n_clients) for t, c in evictions if t < n_ticks}

    def run(batched: bool):
        runner = RecurrentSessionRunner(
            forecaster, SessionCache(max_sessions=n_clients))
        outs = []
        for t in range(n_ticks):
            for c in range(n_clients):
                if (t, c) in evict:
                    runner.spill([f"c{c}"])
                    runner.cache.drop(f"c{c}")
            hist = lambda c: xs[:t, c] if t > 0 else None  # noqa: E731
            if batched:
                outs.append(runner.step_many(
                    [(f"c{c}", xs[t, c], hist(c))
                     for c in range(n_clients)]))
            else:
                outs.append([runner.step(f"c{c}", xs[t, c],
                                         history=hist(c))
                             for c in range(n_clients)])
        return outs

    assert run(batched=True) == run(batched=False)


@given(st.integers(0, 2 ** 16 - 1),
       st.integers(2, 5),                        # clients
       st.integers(3, CFG.window),               # ticks
       st.sets(st.tuples(st.integers(1, CFG.window - 1),
                         st.integers(0, 4)), max_size=4))
@settings(deadline=None)
def test_batched_step_equals_per_session_step_across_evictions(
        forecaster, seed, n_clients, n_ticks, evictions):
    _check_batched_equals_sequential(forecaster, seed, n_clients, n_ticks,
                                     evictions)


@pytest.mark.slow
@given(st.integers(0, 2 ** 16 - 1), st.integers(2, 8),
       st.integers(3, CFG.window),
       st.sets(st.tuples(st.integers(1, CFG.window - 1),
                         st.integers(0, 7)), max_size=8))
@settings(max_examples=150, deadline=None)
def test_batched_step_equivalence_exhaustive(forecaster, seed, n_clients,
                                             n_ticks, evictions):
    _check_batched_equals_sequential(forecaster, seed, n_clients, n_ticks,
                                     evictions)


# -- slot allocator laws ----------------------------------------------------

@pytest.fixture(scope="module")
def narrow_forecaster(forecaster):
    # decode_width=2 with num_slots=2 means any third active client
    # forces an LRU spill, so arbitrary interleavings below churn
    # through insert/generate/spill/reload continuously.
    return LSTMForecaster(cfg=CFG, params=forecaster.params,
                          decode_width=2)


_SLOT_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("step"),
                  st.sets(st.integers(0, 3), min_size=1, max_size=4)),
        st.tuples(st.just("spill"), st.integers(0, 3)),
        st.tuples(st.just("spill_all"), st.just(0)),
        st.tuples(st.just("evict"), st.integers(0, 3)),
    ),
    min_size=1, max_size=12)


def _check_slot_interleaving(narrow_forecaster, seed, ops):
    """Any interleaving of insert/generate (via ``step_many``), explicit
    spill, spill_all, and evict+reload must be invisible: every output
    is BITWISE the per-session ``step`` loop's on a slotless runner, and
    lane occupancy never exceeds ``num_slots``."""
    n_clients, num_slots = 4, 2
    rng = np.random.default_rng(seed)
    n_ticks = max(1, sum(1 for kind, _ in ops if kind == "step"))
    xs = rng.standard_normal(
        (n_ticks, n_clients, 3)).astype(np.float32) * 0.02

    def run(num_slots: int):
        runner = RecurrentSessionRunner(
            narrow_forecaster, SessionCache(max_sessions=n_clients),
            num_slots=num_slots)
        outs, t = [], [0] * n_clients
        for kind, arg in ops:
            if kind == "step":
                items = [(f"c{c}", xs[t[c], c],
                          xs[:t[c], c] if t[c] > 0 else None)
                         for c in sorted(arg)]
                outs.append(runner.step_many(items))
                for c in arg:
                    t[c] += 1
            elif kind == "spill":
                runner.spill([f"c{arg}"])
            elif kind == "spill_all":
                runner.spill_all()
            else:                            # evict: spill then drop;
                runner.spill([f"c{arg}"])    # the session re-primes
                runner.cache.drop(f"c{arg}")  # from history on reuse
            if runner.num_slots:
                assert len(runner.resident_clients()) <= runner.num_slots
                assert runner.slot_stats()["active"] <= runner.num_slots
        return outs

    assert run(num_slots=num_slots) == run(num_slots=0)


@given(st.integers(0, 2 ** 16 - 1), _SLOT_OPS)
@settings(deadline=None)
def test_slot_interleaving_equals_slotless_and_occupancy_bounded(
        narrow_forecaster, seed, ops):
    _check_slot_interleaving(narrow_forecaster, seed, ops)


@pytest.mark.slow
@given(st.integers(0, 2 ** 16 - 1), _SLOT_OPS)
@settings(max_examples=150, deadline=None)
def test_slot_interleaving_exhaustive(narrow_forecaster, seed, ops):
    _check_slot_interleaving(narrow_forecaster, seed, ops)


# -- telemetry merge laws ---------------------------------------------------

_LATS = st.lists(st.floats(1e-4, 0.5, allow_nan=False,
                           allow_infinity=False), max_size=16)
_SHARD_EVENTS = st.fixed_dictionaries({
    "lats": _LATS,                               # one predict flush
    "version": st.integers(1, 3),
    "batches": st.lists(st.integers(1, 8), max_size=8),
    "step_lats": _LATS,
    "swaps": st.integers(0, 3),
    "hits": st.integers(0, 5),
    "misses": st.integers(0, 5),
    "evictions": st.integers(0, 2),
    "slot_inserts": st.integers(0, 4),
    "slot_spills": st.integers(0, 4),
})


@given(st.lists(_SHARD_EVENTS, min_size=1, max_size=4))
@settings(deadline=None)
def test_telemetry_merge_laws(shards):
    """The fleet view must be an exact aggregate of the per-shard views:
    counters sum, per-version attribution sums key-wise, and pooled
    percentiles are actual recorded samples bounded by the per-shard
    sample extrema (nearest-rank on the pooled reservoir)."""
    from repro.serving.telemetry import Telemetry

    tels = []
    for ev in shards:
        tel = Telemetry()
        tel.record_requests(ev["lats"], version=ev["version"])
        for n_real in ev["batches"]:
            tel.record_batch(n_real, 8)
        if ev["step_lats"]:
            tel.record_step_batch(ev["step_lats"], n_padded=8)
        tel.record_swap(ev["swaps"])
        for _ in range(ev["hits"]):
            tel.record_cache(True)
        for _ in range(ev["misses"]):
            tel.record_cache(False)
        tel.record_eviction(ev["evictions"])
        tel.record_slots(inserts=ev["slot_inserts"],
                         spills=ev["slot_spills"],
                         active=min(ev["slot_inserts"], 4), lanes=4)
        tels.append(tel)

    snaps = [tel.snapshot() for tel in tels]
    merged = Telemetry.merge(tels)

    # counters: merged == sum over shards, exactly
    for key in ("requests", "batches", "swaps", "cache_evictions",
                "step_requests", "step_batches", "slot_inserts",
                "slot_spills", "slot_active", "slot_lanes"):
        assert merged[key] == sum(s[key] for s in snaps), key
    assert merged["shards"] == len(tels)
    assert merged["requests_by_shard"] == [s["requests"] for s in snaps]

    # per-version attribution sums key-wise (no version lost or invented)
    by_version: dict[int, int] = {}
    for s in snaps:
        for v, n in s["requests_by_version"].items():
            by_version[v] = by_version.get(v, 0) + n
    assert merged["requests_by_version"] == by_version
    assert sum(by_version.values()) == merged["requests"]

    # pooled percentiles: nearest-rank picks an ACTUAL sample, so the
    # fleet percentile is bounded by the per-shard sample extrema and
    # monotone in p (pooling can't extrapolate beyond any shard's data)
    all_lats = [x for ev in shards for x in ev["lats"]]
    if all_lats:
        lo, hi = min(all_lats) * 1e3, max(all_lats) * 1e3
        assert lo <= merged["p50_ms"] <= hi
        assert lo <= merged["p95_ms"] <= hi
        assert lo <= merged["p99_ms"] <= hi
        assert merged["p50_ms"] <= merged["p95_ms"] <= merged["p99_ms"]
    else:
        assert merged["p50_ms"] == merged["p99_ms"] == 0.0
    all_batches = [n for ev in shards for n in ev["batches"]]
    if all_batches:
        assert min(all_batches) <= merged["batch_p50"] <= max(all_batches)
        assert min(all_batches) <= merged["batch_p95"] <= max(all_batches)
        assert merged["batch_p50"] <= merged["batch_p95"]
        assert merged["mean_batch"] == pytest.approx(
            sum(all_batches) / len(all_batches))

    # derived ratios recompute from the summed counters
    hits = sum(ev["hits"] for ev in shards)
    lookups = hits + sum(ev["misses"] for ev in shards)
    assert merged["cache_hit_rate"] == pytest.approx(
        hits / lookups if lookups else 0.0)


# -- ensemble fusion laws ----------------------------------------------------

_ANY_FLOATS = st.floats(allow_nan=True, allow_infinity=True, width=64)


@given(st.integers(1, 6), st.data())
@settings(deadline=None)
def test_fusion_weights_convex_for_any_history(n, data):
    """Fusion weights are a convex combination for ANY priors/errors —
    nan, inf, negative, zero — and a single member always gets exactly
    weight 1.0."""
    from repro.serving import fusion_weights

    priors = data.draw(st.lists(_ANY_FLOATS, min_size=n, max_size=n))
    errors = data.draw(st.lists(_ANY_FLOATS, min_size=n, max_size=n))
    temp = data.draw(_ANY_FLOATS)
    w = fusion_weights(priors, errors, temperature=temp)
    assert w.shape == (n,)
    assert np.all(np.isfinite(w)) and np.all(w >= 0.0)
    assert np.isclose(w.sum(), 1.0, atol=1e-12)
    if n == 1:
        assert w[0] == 1.0


@given(st.integers(2, 4), st.lists(
    st.lists(_ANY_FLOATS, min_size=2, max_size=4), max_size=8),
    st.data())
@settings(deadline=None)
def test_fuser_weights_convex_under_arbitrary_error_updates(
        n, histories, data):
    """The rolling-error EWMA keeps the online weights convex no matter
    what error sequences arrive (supervised updates with nan/inf
    included)."""
    from repro.serving import EnsembleFuser, EnsembleSpec

    spec = EnsembleSpec(
        members=tuple(f"m{i}" for i in range(n)),
        error_half_life=data.draw(st.floats(0.1, 256.0)),
        temperature=data.draw(st.floats(0.01, 16.0)))
    fuser = EnsembleFuser(n, spec)
    for errs in histories:
        errs = (errs + [0.0] * n)[:n]
        fuser.record_errors(errs)
        w = fuser.weights()
        assert np.all(np.isfinite(w)) and np.all(w >= 0.0)
        assert np.isclose(w.sum(), 1.0, atol=1e-12)
        assert np.all(np.isfinite(fuser.errors()))


@given(st.integers(0, 2 ** 16 - 1), st.integers(1, 4))
@settings(deadline=None, max_examples=25)
def test_singleton_ensemble_bitwise_equals_member(forecaster, seed,
                                                  n_steps):
    """A single-member EnsembleForecaster is bitwise-identical to the
    member served solo on every path: step chains, replay, and the
    slotted decode lifecycle (insert -> generate -> extract)."""
    from repro.serving import EnsembleForecaster

    reg = ModelRegistry()
    reg.register("m", forecaster)
    reg.register_ensemble("solo", ["m"])
    ens = EnsembleForecaster(reg, "solo")
    rng = np.random.default_rng(seed)
    xs = rng.standard_normal((n_steps, 1, CFG.input_dim)).astype(
        np.float32) * 0.02
    win = rng.standard_normal((1, CFG.window, CFG.input_dim)).astype(
        np.float32) * 0.02

    # step chain
    c_m, c_e = forecaster.init_carry(), ens.init_carry()
    for t in range(n_steps):
        y_m, p_m, c_m = forecaster.step(xs[t], c_m)
        y_e, p_e, c_e = ens.step(xs[t], c_e)
        assert np.array_equal(y_m, y_e) and np.array_equal(p_m, p_e)
    # replay
    ry_m, rp_m, rc_m = forecaster.replay(win)
    ry_e, rp_e, rc_e = ens.replay(win)
    assert np.array_equal(ry_m, ry_e) and np.array_equal(rp_m, rp_e)
    # slots lifecycle, from the replayed carries
    s_m, s_e = forecaster.init_slots(4), ens.init_slots(4)
    forecaster.insert(s_m, 1, rc_m)
    ens.insert(s_e, 1, {"m": rc_m})
    for t in range(n_steps):
        x_m = np.zeros((s_m.num_slots, CFG.input_dim), np.float32)
        x_m[1] = xs[t][0]
        gy_m, gp_m, _ = forecaster.generate(s_m, x_m, lanes=[1])
        gy_e, gp_e, _ = ens.generate(
            s_e, x_m[:s_e.num_slots], lanes=[1])
        assert np.asarray(gy_m)[1] == np.asarray(gy_e)[1]
        assert np.asarray(gp_m)[1] == np.asarray(gp_e)[1]
    out_m = forecaster.extract(s_m, 1)
    out_e = ens.extract(s_e, 1)
    for (h_m, c2_m), (h_e, c2_e) in zip(out_m, out_e["m"]):
        assert np.array_equal(np.asarray(h_m), np.asarray(h_e))
        assert np.array_equal(np.asarray(c2_m), np.asarray(c2_e))

# -- durable restore laws --------------------------------------------------

@pytest.fixture(scope="module")
def published_models():
    """Distinct parameter sets for successive publishes (v1, v2, ...
    rotate through them)."""
    return [LSTMForecaster(cfg=CFG,
                           params=init_rnn(jax.random.PRNGKey(s), CFG))
            for s in range(3)]


_DURABLE_OPS = st.lists(
    st.one_of(st.just(("publish",)),
              st.tuples(st.just("late-checkpoint"), st.integers(0, 15)),
              st.just(("crash",)),
              st.just(("restore",))),
    min_size=1, max_size=10)


@given(_DURABLE_OPS, st.integers(1, 3))
@settings(deadline=None, max_examples=15)
def test_restore_is_monotone_in_acknowledged_versions(published_models,
                                                      ops, keep_last):
    """Arbitrary interleavings of publish, LATE daemon checkpoint (a
    snapshot serialized any number of publishes ago, committed after
    them), crash (fresh process, cold-boot restore) and restore must
    never resurrect a weight version older than the last acknowledged
    publish: the durable commit precedes the publish ack, and the
    manifest merge is monotone per versioned entry."""
    import shutil
    import tempfile

    from repro.serving.durable import DurableStore, restore_registry

    root = tempfile.mkdtemp(prefix="durable-law-")
    try:
        store = DurableStore(root, keep_last=keep_last)
        registry = ModelRegistry(durable=store)
        acked = 0
        history = []        # (version, ref) of every publish: stale fodder
        for op in ops:
            if op[0] == "publish":
                fc = published_models[acked % len(published_models)]
                if "m" in registry:
                    registry.swap("m", fc)
                else:
                    registry.register("m", fc)
                acked = registry.version("m")
                history.append(
                    (acked, store.put_blob(registry.save_bytes("m"))))
            elif op[0] == "late-checkpoint":
                if history:
                    v, ref = history[op[1] % len(history)]
                    store.commit({"models": {"m": {"version": v,
                                                   "ref": ref}}})
            elif op[0] == "crash":
                store = DurableStore(root, keep_last=keep_last)
                registry = ModelRegistry()          # process died; disk kept
                restore_registry(store, registry)   # the cold-boot recipe
                registry.attach_durable(store)
                if acked:
                    assert registry.version("m") == acked
            else:
                cold = ModelRegistry()
                out = restore_registry(store, cold)
                if acked:
                    assert out is not None and "m" in out["models"]
                    assert cold.version("m") == acked
                else:
                    assert out is None or "m" not in out["models"]
        cold = ModelRegistry()
        restore_registry(store, cold)
        if acked:                     # final restore lands on the last ack
            assert cold.version("m") == acked
            want = published_models[(acked - 1) % len(published_models)]
            for a, b in zip(jax.tree_util.tree_leaves(want.params),
                            jax.tree_util.tree_leaves(cold.get("m").params)):
                assert np.array_equal(np.asarray(a), np.asarray(b))
    finally:
        shutil.rmtree(root, ignore_errors=True)


@given(st.integers(0, 2 ** 16 - 1), st.integers(1, 4),
       st.integers(1, 6))
@settings(deadline=None, max_examples=15)
def test_restored_sessions_equal_spill_reload_roundtrip(forecaster, seed,
                                                        n_clients, n_ticks):
    """Checkpointed session frames, round-tripped through the store's
    blob codec and re-installed into a cold cache, are bitwise what a
    plain spill/reload of the live cache holds — restore is replay-free
    for fresh sessions."""
    import shutil
    import tempfile

    from repro.serving.durable import (DurableStore, pack_frames_blob,
                                       pack_session_frame,
                                       unpack_frames_blob,
                                       unpack_session_frame)

    rng = np.random.default_rng(seed)
    runner = RecurrentSessionRunner(forecaster,
                                    SessionCache(max_sessions=64))
    for t in range(n_ticks):
        runner.step_many([
            (f"c{i}", rng.standard_normal(3).astype(np.float32) * 0.02,
             None) for i in range(n_clients)])
    runner.spill()
    live = runner.cache.snapshot()
    frames = [pack_session_frame(cid, carry, nbytes, version)
              for cid, carry, nbytes, version in live]
    root = tempfile.mkdtemp(prefix="durable-rt-")
    try:
        store = DurableStore(root)
        blob = store.get_blob(store.put_blob(pack_frames_blob(frames)))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    cold = SessionCache(max_sessions=64)
    for frame in unpack_frames_blob(blob):
        cid, carry, nbytes, version = unpack_session_frame(frame)
        assert cold.put_new(cid, carry, nbytes, version=version)
    restored = {cid: (carry, nbytes, version)
                for cid, carry, nbytes, version in cold.snapshot()}
    assert set(restored) == {cid for cid, *_ in live}
    for cid, carry, nbytes, version in live:
        got, got_n, got_v = restored[cid]
        assert (got_n, got_v) == (nbytes, version)
        a_leaves = jax.tree_util.tree_leaves(carry)
        b_leaves = jax.tree_util.tree_leaves(got)
        assert len(a_leaves) == len(b_leaves)
        for a, b in zip(a_leaves, b_leaves):
            assert np.array_equal(np.asarray(a), np.asarray(b))
            assert np.asarray(b).dtype == np.asarray(a).dtype

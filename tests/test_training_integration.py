"""End-to-end behaviour: the paper's claims on the stock task.

These are the integration versions of EXPERIMENTS.md — small budgets so
CI stays fast; the benchmarks run the full-size versions."""

import numpy as np
import pytest

from repro.core.schedules import ConstantSchedule, SampleSchedule
from repro.extreme.resampling import (evl_sample_weights,
                                      oversample_extreme_windows)
from repro.training.loop import train_rnn_local_sgd, train_rnn_serial


@pytest.fixture(scope="module")
def results(stock_windows):
    train_ds, test_ds = stock_windows
    serial = train_rnn_serial(train_ds, test_ds, iterations=400, batch=16)
    dist2 = train_rnn_local_sgd(train_ds, test_ds, n_workers=2,
                                iterations=400, batch=16)
    return train_ds, test_ds, serial, dist2


def test_serial_baseline_learns(results):
    _, _, serial, _ = results
    assert np.mean(serial.loss_history[-20:]) < serial.loss_history[0] * 0.7
    assert serial.test_mse < 0.05


def test_distributed_matches_baseline_accuracy(results):
    """Paper Figs. 5-10: same level of prediction accuracy as the
    single-node baseline."""
    _, _, serial, dist2 = results
    assert dist2.test_mse < max(serial.test_mse * 3.0, 0.01)


def test_distributed_communicates_less_than_iterations(results):
    """Paper Remark 1: rounds ~ sqrt(K) — communication is a tiny
    fraction of gradient computations."""
    _, _, _, dist2 = results
    assert dist2.communications < dist2.iterations / 10


def test_linear_beats_constant_schedule_on_comm(stock_windows):
    train_ds, test_ds = stock_windows
    lin = train_rnn_local_sgd(train_ds, test_ds, n_workers=2,
                              iterations=300, batch=16,
                              schedule=SampleSchedule(a=10))
    const = train_rnn_local_sgd(train_ds, test_ds, n_workers=2,
                                iterations=300, batch=16,
                                schedule=ConstantSchedule(size=10))
    assert lin.communications < const.communications
    assert lin.test_mse < max(3.0 * const.test_mse, 0.02)


def test_stale_averaging_still_converges(stock_windows):
    train_ds, test_ds = stock_windows
    res = train_rnn_local_sgd(train_ds, test_ds, n_workers=2, tau=1,
                              iterations=300, batch=16)
    assert res.test_mse < 0.05


def test_heterogeneous_split_converges(stock_windows):
    train_ds, test_ds = stock_windows
    res = train_rnn_local_sgd(train_ds, test_ds, n_workers=2,
                              iterations=300, batch=16, split="contiguous")
    assert res.test_mse < 0.08


def test_evl_training_improves_extreme_recall(stock_windows):
    """Sensitivity study direction: adding the EVL head objective should
    not hurt MSE badly and should produce a usable extreme detector."""
    train_ds, test_ds = stock_windows
    plain = train_rnn_serial(train_ds, test_ds, iterations=400, batch=16,
                             evl_weight=0.0)
    evl = train_rnn_serial(train_ds, test_ds, iterations=400, batch=16,
                           evl_weight=0.5)
    assert evl.test_mse < max(3.0 * plain.test_mse, 0.02)
    if evl.test_extreme.get("n_extreme", 0) > 0:
        assert evl.test_extreme["recall"] >= 0.0  # detector produced


def test_oversampling_changes_epoch_composition(stock_windows):
    train_ds, _ = stock_windows
    idx = oversample_extreme_windows(train_ds.returns, train_ds.eps1,
                                     train_ds.eps2, target_fraction=0.3)
    v = np.asarray(train_ds.v)
    frac = np.mean(v[idx] != 0)
    base = np.mean(v != 0)
    assert frac > base  # extremes over-represented
    w = evl_sample_weights(train_ds.returns, train_ds.eps1, train_ds.eps2)
    assert w.shape == (len(train_ds),)
    assert w[v != 0].mean() > w[v == 0].mean()

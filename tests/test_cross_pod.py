"""Cross-pod collective classification (used by §Perf HC3 to measure the
paper's communication-reduction claim on the multi-pod mesh)."""

from repro.launch.hlo_analysis import _is_cross_pod


def test_explicit_groups():
    within = "all-reduce(%x), replica_groups={{0,1},{2,3}}, to_apply=%a"
    cross = "all-reduce(%x), replica_groups={{0,256},{1,257}}, to_apply=%a"
    assert not _is_cross_pod(within, 256)
    assert _is_cross_pod(cross, 256)


def test_iota_groups_within_pod():
    # 32 groups of 16 devices: data-axis groups on the (2,16,16) mesh,
    # device ids iota over [512] — consecutive 16-blocks stay in-pod
    line = "all-gather(%x), replica_groups=[32,16]<=[512], dimensions={0}"
    assert not _is_cross_pod(line, 256)


def test_iota_groups_cross_pod():
    # 256 groups of 2: {i, i+256} pairs — the cross-pod model exchange
    line = ("all-reduce(%x), replica_groups=[256,2]<=[2,256]T(1,0), "
            "to_apply=%add")
    assert _is_cross_pod(line, 256)


def test_collective_permute_pairs():
    assert _is_cross_pod(
        "collective-permute(%x), source_target_pairs={{0,256},{256,0}}",
        256)
    assert not _is_cross_pod(
        "collective-permute(%x), source_target_pairs={{0,1},{1,0}}", 256)

"""Per-architecture smoke tests: reduced variant of each assigned family
(2 layers, d_model<=256, <=4 experts) — one forward + one train step +
prefill/decode consistency on CPU, asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, list_archs
from repro.configs.base import reduced
from repro.data.tokens import synthetic_embedding_batch, synthetic_token_batch
from repro.models import transformer as tfm
from repro.models.model_zoo import build_model
from repro.optim.optimizers import adam, apply_updates

ALL_ARCHS = list_archs()
assert len(ALL_ARCHS) == 10


def _inputs(cfg, batch=2, seq=24, seed=0):
    toks = jnp.asarray(synthetic_token_batch(batch, seq, cfg.vocab,
                                             seed=seed))
    frames = None
    if cfg.family == "audio":
        frames = jnp.asarray(synthetic_embedding_batch(
            batch, cfg.n_frames, cfg.d_model, seed=seed))
    return toks, frames


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_config_constraints(arch):
    cfg = reduced(ARCHS[arch])
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks, frames = _inputs(cfg)
    logits, aux = model.forward(params, toks, frames)
    assert logits.shape == (2, 24, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss = model.loss(params, toks, frames)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_updates_and_finite(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks, frames = _inputs(cfg)
    opt = adam(clip_norm=1.0)
    state = opt.init(params)

    def loss_fn(p):
        return model.loss(p, toks, frames)

    loss0, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    upd, state = opt.update(grads, state, params, 1e-2)
    params2 = apply_updates(params, upd)
    loss1 = float(jax.jit(loss_fn)(params2))
    assert np.isfinite(float(loss0)) and np.isfinite(loss1)
    # at least one parameter actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32),
                        np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S, prompt = 24, 16
    toks, frames = _inputs(cfg, seq=S)
    logits_all, _ = model.forward(params, toks, frames)
    lp, cache = model.prefill(params, toks[:, :prompt], frames)
    np.testing.assert_allclose(
        np.asarray(lp, np.float32),
        np.asarray(logits_all[:, prompt - 1], np.float32),
        rtol=3e-2, atol=3e-2)

    full = model.init_cache(2, S)

    def place(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        if dst.ndim == src.ndim and dst.shape[2] != src.shape[2]:
            return dst.at[:, :, :src.shape[2]].set(src)
        return src

    cache = jax.tree.map(place, full, cache)
    for t in range(prompt, S):
        lg, cache = model.decode_step(params, toks[:, t], cache)
        want = np.asarray(logits_all[:, t], np.float32)
        got = np.asarray(lg, np.float32)
        denom = np.max(np.abs(want)) + 1e-9
        assert np.max(np.abs(got - want)) / denom < 0.05, (arch, t)
        # exercise the paged-KV flush (reduced configs use tiny buffers)
        if "kr" in cache and int(cache["len"] - cache["flushed"]) >= \
                cfg.decode_buffer:
            cache = tfm.flush_recent(cfg, cache)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "qwen3-moe-235b-a22b"])
def test_moe_router_balance_loss(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks, _ = _inputs(cfg)
    _, aux = model.forward(params, toks)
    # Switch aux loss is ~1 for a balanced router, >=1 otherwise
    assert 0.5 < float(aux) / cfg.n_layers < 4.0


def test_param_counts_close_to_nameplate():
    """Full-config parameter-count formulas land near the nameplate
    sizes (within ~20%, vocab padding and heads included)."""
    expect = {"chameleon-34b": 34e9, "granite-20b": 20e9,
              "qwen2.5-32b": 32e9, "nemotron-4-15b": 15e9,
              "mamba2-370m": 0.37e9, "mixtral-8x7b": 46e9,
              "zamba2-2.7b": 2.7e9, "qwen1.5-4b": 4e9,
              "qwen3-moe-235b-a22b": 235e9}
    for name, n in expect.items():
        got = ARCHS[name].param_count()
        assert 0.7 * n < got < 1.45 * n, (name, got, n)


def test_active_params_moe():
    cfg = ARCHS["qwen3-moe-235b-a22b"]
    active = cfg.active_param_count()
    total = cfg.param_count()
    assert active < total / 5     # 22B active of 235B

"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests
and benches must see the real single CPU device; only dryrun.py forces
512 placeholder devices (and only in its own process)."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def stock_windows():
    from repro.data import load_stock, make_windows, train_test_split
    ohlcv = load_stock("AAPL", n_days=600)
    tr, te = train_test_split(ohlcv)
    return make_windows(tr), make_windows(te)

"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests
and benches must see the real single CPU device; only dryrun.py forces
512 placeholder devices (and only in its own process)."""

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, settings

    # "ci" bounds example counts so property tests fit the tier-1 timing
    # gate (select with --hypothesis-profile=ci, as .github/workflows/ci.yml
    # does); the default/dev profiles keep fuller coverage. deadline=None
    # everywhere: jit compilation on a test's first example is slow.
    settings.register_profile(
        "ci", max_examples=20, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile("dev", max_examples=100, deadline=None)
except ImportError:                      # hypothesis is an optional extra
    pass


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def stock_windows():
    from repro.data import load_stock, make_windows, train_test_split
    ohlcv = load_stock("AAPL", n_days=600)
    tr, te = train_test_split(ohlcv)
    return make_windows(tr), make_windows(te)

"""Event-driven async simulator — determinism, bounded staleness,
speedup structure (paper Table II)."""

import jax
import numpy as np

from repro.core.simulator import AsyncSimulator, SimConfig
from repro.core.schedules import SampleSchedule
from repro.optim.optimizers import sgd


def quad_loss(params, batch):
    x, y = batch
    import jax.numpy as jnp
    pred = x @ params["w"] + params["b"]
    return jnp.mean((pred - y) ** 2)


def _setup(n_clients, k=300, seed=0, **kw):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((512, 3)).astype(np.float32)
    y = (x @ np.array([1.0, -2.0, 0.5]) + 0.1).astype(np.float32)
    import jax.numpy as jnp
    params = {"w": jnp.zeros((3,)), "b": jnp.zeros(())}

    def gen(r, h, b):
        idx = r.integers(0, 512, size=(h, b))
        return (x[idx], y[idx])

    cfg = SimConfig(n_clients=n_clients, total_iterations=k,
                    batch_size=16, seed=seed, **kw)
    sim = AsyncSimulator(quad_loss, sgd(), params,
                         [gen] * n_clients, cfg,
                         eval_fn=lambda p: quad_loss(p, (x, y)))
    return sim


def test_simulator_deterministic():
    s1 = _setup(3).run()
    s2 = _setup(3).run()
    assert s1["makespan"] == s2["makespan"]
    assert s1["communications"] == s2["communications"]
    assert s1["eval_log"] == s2["eval_log"]


def test_staleness_bounded():
    s = _setup(5, max_ahead=2).run()
    assert s["max_staleness"] <= 2 + 1  # bound + the in-flight round


def test_speedup_increases_with_clients():
    """Paper Table II structure: more nodes -> more speedup, with
    saturation below ideal (server aggregation cost)."""
    speedups = {n: _setup(n, k=400).run()["speedup"] for n in (1, 2, 5)}
    assert speedups[2] > speedups[1]
    assert speedups[5] > speedups[2]
    assert speedups[5] < 5.0  # saturation


def test_simulator_converges():
    s = _setup(2, k=600).run()
    first = s["eval_log"][0][1]
    last = s["eval_log"][-1][1]
    assert last < first * 0.5


def test_linear_schedule_fewer_communications():
    lin = _setup(2, k=400, schedule=SampleSchedule(a=10)).run()
    const = _setup(2, k=400, schedule=SampleSchedule(a=10, p=0.0)).run()
    # p=0: s_i = 10 constant -> ~40 rounds; linear: ~sqrt scaling
    assert lin["communications"] < const["communications"]

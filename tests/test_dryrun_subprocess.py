"""One real 512-device lower+compile smoke via the dryrun CLI (separate
process because XLA_FLAGS must be set before jax initializes). The full
40-pair matrix runs in benchmarks/EXPERIMENTS.md; here we verify one
cheap pair end-to-end so regressions in the launch layer fail CI."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_cli_single_pair(tmp_path):
    out = tmp_path / "dr.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-370m", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["memory"]["peak_bytes"] < 16 * 2**30
    assert rec["roofline"]["dominant"] in ("compute", "memory",
                                           "collective")

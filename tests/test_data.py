"""Data pipeline: synthetic generator stylized facts, windows, splits."""

import numpy as np
import pytest

from repro.data.sharding import client_splits
from repro.data.synthetic import SyntheticStockConfig, generate_ohlcv, log_returns
from repro.data.tokens import synthetic_embedding_batch, synthetic_token_batch
from repro.data.windows import make_windows, normalize_windows
from repro.data.sp500 import load_stock, train_test_split


def test_synthetic_deterministic_and_distinct():
    a1 = generate_ohlcv("AAPL")
    a2 = generate_ohlcv("AAPL")
    b = generate_ohlcv("AMZN")
    np.testing.assert_array_equal(a1, a2)
    assert not np.array_equal(a1, b)


def test_synthetic_ohlc_invariants():
    x = generate_ohlcv("TEST", SyntheticStockConfig(n_days=500))
    o, h, l, c, v = x.T
    assert np.all(h >= np.maximum(o, c) - 1e-4)
    assert np.all(l <= np.minimum(o, c) + 1e-4)
    assert np.all(l > 0) and np.all(v > 0)


def test_synthetic_heavy_tails():
    """The generator must produce heavy-tailed returns (excess kurtosis
    well above gaussian) — the premise of the paper's extreme-event
    study."""
    r = log_returns(generate_ohlcv("AAPL", SyntheticStockConfig(
        n_days=1430))[:, 3])
    z = (r - r.mean()) / r.std()
    kurtosis = float(np.mean(z ** 4))
    assert kurtosis > 4.0  # gaussian = 3


def test_make_windows_shapes():
    x = generate_ohlcv("AAPL", SyntheticStockConfig(n_days=300))
    ds = make_windows(x, window=20)
    assert ds.x.shape == (280, 20, 5)
    assert ds.y.shape == (280,)
    assert ds.v.shape == (280,)
    assert ds.eps1 > 0 and ds.eps2 > 0
    assert set(np.unique(ds.v)).issubset({-1, 0, 1})


def test_normalize_windows_base_zero():
    w = np.abs(np.random.default_rng(0).normal(
        10, 1, (4, 20, 5))).astype(np.float32)
    n = normalize_windows(w)
    np.testing.assert_allclose(n[:, 0, :], 0.0, atol=1e-6)


def test_window_too_short_raises():
    x = generate_ohlcv("AAPL", SyntheticStockConfig(n_days=10))
    with pytest.raises(ValueError):
        make_windows(x, window=20)


def test_train_test_split_chronological():
    x = np.arange(100, dtype=np.float32).reshape(-1, 1).repeat(5, 1)
    tr, te = train_test_split(x, 0.6)
    assert len(tr) == 60 and len(te) == 40
    assert tr[-1, 0] < te[0, 0]


def test_client_splits_modes():
    for mode in ("iid", "contiguous"):
        parts = client_splits(100, 3, mode)
        allidx = np.sort(np.concatenate(parts))
        np.testing.assert_array_equal(allidx, np.arange(100))
    shared = client_splits(100, 3, "shared")
    assert all(len(p) == 100 for p in shared)
    with pytest.raises(ValueError):
        client_splits(10, 2, "bogus")


def test_token_batches():
    t = synthetic_token_batch(4, 64, 1000, seed=1)
    assert t.shape == (4, 64) and t.dtype == np.int32
    assert t.min() >= 0 and t.max() < 1000
    e = synthetic_embedding_batch(2, 10, 16)
    assert e.shape == (2, 10, 16)


def test_load_stock_fallback_synthetic(tmp_path):
    x = load_stock("NOSUCH", data_dir=str(tmp_path), n_days=100)
    assert x.shape == (100, 5)


def test_load_stock_reads_csv(tmp_path):
    p = tmp_path / "FOO.csv"
    p.write_text("Date,Open,High,Low,Close,Volume\n"
                 "2012-01-01,1,2,0.5,1.5,100\n"
                 "2012-01-02,1.5,2.5,1.0,2.0,200\n")
    x = load_stock("FOO", data_dir=str(tmp_path))
    assert x.shape == (2, 5)
    np.testing.assert_allclose(x[0], [1, 2, 0.5, 1.5, 100])

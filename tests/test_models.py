"""Model building blocks: RNN, attention twin, norms, rope."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.attention import blocked_attention, reference_attention
from repro.models.layers import apply_rope, pad_vocab, rms_norm
from repro.models.rnn import RNNConfig, init_rnn, rnn_apply


def test_rnn_shapes():
    cfg = RNNConfig()
    params = init_rnn(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((7, cfg.window, cfg.input_dim))
    y, u = rnn_apply(params, x, cfg)
    assert y.shape == (7,)
    assert u.shape == (7,)
    assert np.all((np.asarray(u) >= 0) & (np.asarray(u) <= 1))


def test_rnn_no_evl_head():
    cfg = RNNConfig(evl_head=False)
    params = init_rnn(jax.random.PRNGKey(0), cfg)
    y, u = rnn_apply(params, jnp.zeros((3, 20, 5)), cfg)
    assert u is None


@given(st.integers(1, 3), st.integers(16, 64))
@settings(max_examples=10, deadline=None)
def test_blocked_attention_matches_reference(b, s):
    rng = np.random.default_rng(s)
    q = jnp.asarray(rng.standard_normal((b, s, 4, 32)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, s, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, s, 2, 32)).astype(np.float32))
    got = blocked_attention(q, k, v, causal=True, q_block=16, kv_block=16)
    want = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_rope_preserves_norm_and_relative():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 8, 2, 64)).astype(np.float32))
    pos = jnp.arange(8)[None]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # position 0 is the identity
    np.testing.assert_allclose(y[:, 0], x[:, 0], atol=1e-6)


def test_rms_norm_unit_scale():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (4, 64)).astype(np.float32))
    y = rms_norm(x, jnp.ones(64))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_pad_vocab():
    assert pad_vocab(50280) == 50432
    assert pad_vocab(256) == 256
    assert pad_vocab(1) == 256

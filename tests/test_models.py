"""Model building blocks: RNN, attention twin, norms, rope."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.attention import blocked_attention, reference_attention
from repro.models.layers import apply_rope, pad_vocab, rms_norm
from repro.models.rnn import RNNConfig, init_rnn, rnn_apply


def test_rnn_shapes():
    cfg = RNNConfig()
    params = init_rnn(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((7, cfg.window, cfg.input_dim))
    y, u = rnn_apply(params, x, cfg)
    assert y.shape == (7,)
    assert u.shape == (7,)
    assert np.all((np.asarray(u) >= 0) & (np.asarray(u) <= 1))


def test_rnn_no_evl_head():
    cfg = RNNConfig(evl_head=False)
    params = init_rnn(jax.random.PRNGKey(0), cfg)
    y, u = rnn_apply(params, jnp.zeros((3, 20, 5)), cfg)
    assert u is None


def test_stack_split_rnn_carries_roundtrip():
    from repro.models.rnn import (init_rnn_carry, split_rnn_carry,
                                  stack_rnn_carries)
    cfg = RNNConfig(hidden=16, num_layers=2)
    params = init_rnn(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    singles = []
    for i in range(3):
        c = init_rnn_carry(params, 1)
        singles.append(tuple(
            (h + i, cc - i) for h, cc in c))          # distinct values
    stacked = stack_rnn_carries(singles, pad_to=8)
    assert stacked[0][0].shape == (8, 16)
    back = split_rnn_carry(stacked, n=3)
    for want, got in zip(singles, back):
        for (h1, c1), (h2, c2) in zip(want, got):
            np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
            np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    # padding rows are zeros; over-tight pad_to raises
    np.testing.assert_array_equal(np.asarray(stacked[0][0][3:]),
                                  np.zeros((5, 16), np.float32))
    with pytest.raises(ValueError):
        stack_rnn_carries(singles, pad_to=2)


@given(st.integers(1, 3), st.integers(16, 64))
@settings(max_examples=10, deadline=None)
def test_blocked_attention_matches_reference(b, s):
    rng = np.random.default_rng(s)
    q = jnp.asarray(rng.standard_normal((b, s, 4, 32)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, s, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, s, 2, 32)).astype(np.float32))
    got = blocked_attention(q, k, v, causal=True, q_block=16, kv_block=16)
    want = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_rope_preserves_norm_and_relative():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 8, 2, 64)).astype(np.float32))
    pos = jnp.arange(8)[None]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # position 0 is the identity
    np.testing.assert_allclose(y[:, 0], x[:, 0], atol=1e-6)


def test_rms_norm_unit_scale():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (4, 64)).astype(np.float32))
    y = rms_norm(x, jnp.ones(64))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_pad_vocab():
    assert pad_vocab(50280) == 50432
    assert pad_vocab(256) == 256
    assert pad_vocab(1) == 256

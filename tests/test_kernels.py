"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps in
interpret mode (CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.ops import flash_attention
from repro.kernels.attention.ref import attention_ref
from repro.kernels.evl.ops import evl_loss_fused
from repro.kernels.evl.ref import evl_loss_ref
from repro.kernels.lstm.ops import lstm_cell_fused
from repro.kernels.lstm.ref import lstm_cell_ref
from repro.kernels.ssd.ops import ssd_scan_fused
from repro.models.ssm import ssd_chunked, ssd_reference

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------- EVL ----

@pytest.mark.parametrize("n", [1, 127, 128, 1000, 4096])
@pytest.mark.parametrize("beta0,beta1,gamma", [(0.9, 0.1, 2.0),
                                               (0.99, 0.01, 1.5)])
def test_evl_kernel_matches_ref(n, beta0, beta1, gamma):
    u = jnp.asarray(RNG.uniform(0.01, 0.99, n).astype(np.float32))
    v = jnp.asarray((RNG.uniform(size=n) < 0.2).astype(np.float32))
    got = evl_loss_fused(u, v, beta0, beta1, gamma, reduce="none")
    want = evl_loss_ref(u, v, beta0, beta1, gamma)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


def test_evl_kernel_reductions():
    u = jnp.asarray(RNG.uniform(0.01, 0.99, 300).astype(np.float32))
    v = jnp.zeros(300)
    m = float(evl_loss_fused(u, v, 0.9, 0.1, 2.0, reduce="mean"))
    s = float(evl_loss_fused(u, v, 0.9, 0.1, 2.0, reduce="sum"))
    np.testing.assert_allclose(s / 300, m, rtol=1e-6)


# --------------------------------------------------------------- LSTM ----

@pytest.mark.parametrize("batch,in_dim,hidden", [
    (1, 5, 64), (13, 5, 64), (32, 7, 32), (8, 16, 128),
    # non-multiple-of-8 shapes: odd batch, odd feature dim, batch=1
    # with a tiny feature dim, odd-everything — the wrapper's sublane
    # padding must keep all of them exact
    (3, 9, 24), (7, 3, 40), (1, 1, 8), (9, 11, 48), (5, 5, 16)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_lstm_kernel_matches_ref(batch, in_dim, hidden, dtype):
    x = jnp.asarray(RNG.standard_normal((batch, in_dim)).astype(dtype))
    h = jnp.asarray(RNG.standard_normal((batch, hidden)).astype(dtype))
    c = jnp.asarray(RNG.standard_normal((batch, hidden)).astype(dtype))
    wx = jnp.asarray((0.1 * RNG.standard_normal(
        (in_dim, 4 * hidden))).astype(dtype))
    wh = jnp.asarray((0.1 * RNG.standard_normal(
        (hidden, 4 * hidden))).astype(dtype))
    b = jnp.asarray((0.1 * RNG.standard_normal(4 * hidden)).astype(dtype))
    hn, cn = lstm_cell_fused(x, h, c, wx, wh, b)
    hr, cr = lstm_cell_ref(x, h, c, wx, wh, b)
    np.testing.assert_allclose(hn, hr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(cn, cr, rtol=1e-5, atol=1e-6)


def test_lstm_kernel_in_model():
    """The fused cell is a drop-in for the model's lstm_cell."""
    from repro.models.rnn import lstm_cell
    p = {"wx": jnp.asarray(0.1 * RNG.standard_normal((5, 256)),
                           jnp.float32),
         "wh": jnp.asarray(0.1 * RNG.standard_normal((64, 256)),
                           jnp.float32),
         "b": jnp.asarray(0.1 * RNG.standard_normal(256), jnp.float32)}
    x = jnp.asarray(RNG.standard_normal((3, 5)), jnp.float32)
    h = jnp.zeros((3, 64)); c = jnp.zeros((3, 64))
    h1, c1 = lstm_cell(p, x, h, c)
    h2, c2 = lstm_cell_fused(x, h, c, p["wx"], p["wh"], p["b"])
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-6)


def test_lstm_kernel_resolves_backend_at_trace_time(monkeypatch):
    """Regression: the ops wrappers used to snapshot
    ``jax.default_backend()`` at IMPORT time, so a backend configured
    after import served the wrong ``interpret`` flag forever. The flag
    is now resolved when the wrapper traces."""
    from repro.kernels.lstm import ops as lstm_ops

    captured = {}

    def fake_pallas(x, h, c, wx, wh, b, block_b=8, interpret=None):
        captured["interpret"] = interpret
        return h, c

    monkeypatch.setattr(lstm_ops, "lstm_cell_pallas", fake_pallas)
    monkeypatch.setattr(lstm_ops.jax, "default_backend", lambda: "tpu")
    lstm_ops.lstm_cell_fused.clear_cache()    # force a fresh trace
    try:
        x = jnp.zeros((2, 5), jnp.float32)
        h = c = jnp.zeros((2, 8), jnp.float32)
        wx = jnp.zeros((5, 32), jnp.float32)
        wh = jnp.zeros((8, 32), jnp.float32)
        b = jnp.zeros((32,), jnp.float32)
        lstm_ops.lstm_cell_fused(x, h, c, wx, wh, b)
        # the backend patched in AFTER import must win at trace time
        assert captured["interpret"] is False
    finally:
        # drop the traces built against the patched backend/kernel
        lstm_ops.lstm_cell_fused.clear_cache()


# ----------------------------------------------------------- dispatch ----

def test_dispatch_default_table_cpu_picks_xla():
    from repro.kernels import dispatch
    dispatch.reset_table()
    for batch, hidden in [(1, 8), (8, 64), (128, 256)]:
        assert dispatch.resolve("lstm_cell", batch=batch, hidden=hidden,
                                backend="cpu") == "xla"


def test_dispatch_default_table_tpu_thresholds():
    from repro.kernels import dispatch
    dispatch.reset_table()
    assert dispatch.resolve("lstm_cell", batch=8, hidden=64,
                            backend="tpu") == "pallas"
    assert dispatch.resolve("lstm_cell", batch=1, hidden=64,
                            backend="tpu") == "xla"      # below batch floor
    assert dispatch.resolve("lstm_cell", batch=8, hidden=4,
                            backend="tpu") == "xla"      # below hidden floor


def test_dispatch_unknown_op_and_backend_default_to_xla():
    from repro.kernels import dispatch
    dispatch.reset_table()
    assert dispatch.resolve("nope", batch=64, hidden=64,
                            backend="tpu") == "xla"
    assert dispatch.resolve("lstm_cell", batch=64, hidden=64,
                            backend="rocm") == "xla"     # "default" rules


def test_dispatch_force_overrides_everything(monkeypatch):
    from repro.kernels import dispatch
    dispatch.reset_table()
    with dispatch.force("pallas"):
        assert dispatch.resolve("lstm_cell", batch=1, hidden=8,
                                backend="cpu") == "pallas"
    assert dispatch.resolve("lstm_cell", batch=1, hidden=8,
                            backend="cpu") == "xla"      # restored
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "xla")
    assert dispatch.resolve("lstm_cell", batch=64, hidden=64,
                            backend="tpu") == "xla"
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "bogus")
    with pytest.raises(ValueError):
        dispatch.resolve("lstm_cell", batch=1, hidden=8)


def test_dispatch_resolves_backend_at_trace_time(monkeypatch):
    """Like the ops-wrapper regression: a backend configured after
    import must win when ``resolve`` runs (i.e. when tracing)."""
    from repro.kernels import dispatch
    dispatch.reset_table()
    monkeypatch.setattr(dispatch.jax, "default_backend", lambda: "tpu")
    assert dispatch.resolve("lstm_cell", batch=8, hidden=64) == "pallas"


def test_dispatch_table_save_load_roundtrip(tmp_path):
    from repro.kernels import dispatch
    dispatch.reset_table()
    dispatch.set_rules("lstm_cell", "cpu",
                       [{"min_batch": 4, "min_hidden": 0,
                         "impl": "pallas"}])
    path = str(tmp_path / "table.json")
    dispatch.save_table(path)
    dispatch.reset_table()
    assert dispatch.resolve("lstm_cell", batch=4, hidden=8,
                            backend="cpu") == "xla"
    dispatch.load_table(path)
    try:
        assert dispatch.resolve("lstm_cell", batch=4, hidden=8,
                                backend="cpu") == "pallas"
        assert dispatch.resolve("lstm_cell", batch=2, hidden=8,
                                backend="cpu") == "xla"
        # merged over defaults: untouched backends keep their rules
        assert dispatch.resolve("lstm_cell", batch=8, hidden=64,
                                backend="tpu") == "pallas"
    finally:
        dispatch.reset_table()


def test_dispatch_env_table_loads_lazily(tmp_path, monkeypatch):
    from repro.kernels import dispatch
    path = str(tmp_path / "env_table.json")
    dispatch.set_rules("lstm_cell", "cpu",
                       [{"min_batch": 1, "impl": "pallas"}])
    dispatch.save_table(path)
    dispatch.reset_table()
    monkeypatch.setenv("REPRO_DISPATCH_TABLE", path)
    try:
        assert dispatch.resolve("lstm_cell", batch=1, hidden=8,
                                backend="cpu") == "pallas"
    finally:
        dispatch.reset_table()


def test_dispatched_cell_matches_ref_both_impls():
    """The dispatch-routed cell is numerically the ref cell on the XLA
    path (identical expression) and allclose on the forced Pallas
    path — at a non-multiple-of-8 shape to exercise the padding."""
    from repro.kernels import dispatch
    dispatch.reset_table()
    B, I, H = 3, 5, 24
    x = jnp.asarray(RNG.standard_normal((B, I)).astype(np.float32))
    h = jnp.asarray(RNG.standard_normal((B, H)).astype(np.float32))
    c = jnp.asarray(RNG.standard_normal((B, H)).astype(np.float32))
    wx = jnp.asarray(0.1 * RNG.standard_normal((I, 4 * H)), jnp.float32)
    wh = jnp.asarray(0.1 * RNG.standard_normal((H, 4 * H)), jnp.float32)
    b = jnp.asarray(0.1 * RNG.standard_normal(4 * H), jnp.float32)
    want = lstm_cell_ref(x, h, c, wx, wh, b)
    got = dispatch.lstm_cell(x, h, c, wx, wh, b)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
    with dispatch.force("pallas"):
        got_p = dispatch.lstm_cell(x, h, c, wx, wh, b)
    np.testing.assert_allclose(got_p[0], want[0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_p[1], want[1], rtol=1e-5, atol=1e-6)


def test_model_cell_routes_through_dispatch(monkeypatch):
    """``models.rnn.lstm_cell`` consults the dispatch layer — forcing
    Pallas must reach the kernel wrapper."""
    from repro.kernels import dispatch
    from repro.models import rnn as rnn_mod

    called = {"n": 0}
    real = dispatch.lstm_cell_padded

    def spy(*args, **kw):
        called["n"] += 1
        return real(*args, **kw)

    monkeypatch.setattr(dispatch, "lstm_cell_padded", spy)
    p = {"wx": jnp.zeros((5, 64), jnp.float32),
         "wh": jnp.zeros((16, 64), jnp.float32),
         "b": jnp.zeros((64,), jnp.float32)}
    x = jnp.zeros((2, 5), jnp.float32)
    h = c = jnp.zeros((2, 16), jnp.float32)
    rnn_mod.lstm_cell(p, x, h, c)          # cpu -> xla, no kernel call
    assert called["n"] == 0
    with dispatch.force("pallas"):
        rnn_mod.lstm_cell(p, x, h, c)
    assert called["n"] == 1


# ---------------------------------------------------- flash attention ----

@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (1, 128, 4, 4, 64),     # MHA, aligned
    (2, 200, 4, 2, 64),     # GQA, ragged seq
    (1, 300, 8, 1, 32),     # MQA
    (2, 64, 6, 2, 128),     # tiny seq < block
])
@pytest.mark.parametrize("kwargs", [
    dict(causal=True), dict(causal=False), dict(causal=True, window=37)])
def test_flash_attention_matches_ref(B, S, Hq, Hkv, D, kwargs):
    q = jnp.asarray(RNG.standard_normal((B, S, Hq, D)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((B, S, Hkv, D)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((B, S, Hkv, D)).astype(np.float32))
    got = flash_attention(q, k, v, **kwargs)
    want = attention_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16():
    B, S, H, D = 1, 128, 2, 64
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True).astype(np.float32)
    want = attention_ref(q, k, v, causal=True).astype(np.float32)
    np.testing.assert_allclose(got, want, rtol=0.08, atol=0.08)


def test_blocked_attention_model_twin():
    """models.attention.blocked_attention (the pure-JAX twin used inside
    the transformer) agrees with the Pallas kernel."""
    from repro.models.attention import blocked_attention
    B, S, Hq, Hkv, D = 2, 160, 4, 2, 64
    q = jnp.asarray(RNG.standard_normal((B, S, Hq, D)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((B, S, Hkv, D)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((B, S, Hkv, D)).astype(np.float32))
    a = blocked_attention(q, k, v, causal=True, q_block=64, kv_block=64)
    b = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


# ------------------------------------------------------------- SSD -------

@pytest.mark.parametrize("B,L,H,P,N,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 96, 3, 16, 8, 32),
    (1, 100, 1, 32, 16, 32),   # ragged: L % chunk != 0
    (2, 128, 4, 64, 32, 128),  # full-size chunk
])
def test_ssd_kernel_matches_refs(B, L, H, P, N, chunk):
    xd = jnp.asarray((0.1 * RNG.standard_normal((B, L, H, P))).astype(np.float32))
    a = -jnp.asarray(RNG.uniform(0.01, 0.5, (B, L, H)).astype(np.float32))
    B_ = jnp.asarray((0.3 * RNG.standard_normal((B, L, N))).astype(np.float32))
    C_ = jnp.asarray((0.3 * RNG.standard_normal((B, L, N))).astype(np.float32))
    y1, s1 = ssd_scan_fused(xd, a, B_, C_, chunk=chunk)
    y2, s2 = ssd_chunked(xd, a, B_, C_, chunk=chunk)
    y3, s3 = ssd_reference(xd, a, B_, C_)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(y1, y3, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(s1, s3, rtol=1e-4, atol=1e-5)


def test_ssd_decode_matches_scan_tail():
    """Sequential decode steps reproduce the chunked scan's output."""
    from repro.models.ssm import ssd_decode_step
    B, L, H, P, N = 1, 32, 2, 8, 4
    xd = jnp.asarray((0.1 * RNG.standard_normal((B, L, H, P))).astype(np.float32))
    a = -jnp.asarray(RNG.uniform(0.01, 0.5, (B, L, H)).astype(np.float32))
    B_ = jnp.asarray((0.3 * RNG.standard_normal((B, L, N))).astype(np.float32))
    C_ = jnp.asarray((0.3 * RNG.standard_normal((B, L, N))).astype(np.float32))
    y_scan, _ = ssd_chunked(xd, a, B_, C_, chunk=8)
    state = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(L):
        y, state = ssd_decode_step(state, xd[:, t], a[:, t], B_[:, t],
                                   C_[:, t])
        ys.append(y)
    y_seq = jnp.stack(ys, 1)
    np.testing.assert_allclose(y_seq, y_scan, rtol=1e-4, atol=1e-5)

"""Synthetic heavy-tailed OHLCV generator.

The paper trains on S&P500 constituents (AAPL, AMZN, ...) 2012-2017 —
daily OHLCV. Offline here, so we synthesize a series with the stylized
facts that matter for the paper's questions:

- heavy-tailed daily returns (Student-t shocks, nu ~ 3-5): extreme events
  have non-negligible probability (paper §II.A);
- volatility clustering (GARCH(1,1)-style variance recursion): extremes
  arrive in bursts, stressing the imbalanced-sampling strategies;
- occasional jumps (compound-Poisson): the "stock market crash" events;
- a slow drift + regime trend so the LSTM has learnable structure.

Deterministic per (ticker, seed): the ticker string hashes into the seed,
so "AAPL" and "AMZN" give distinct but reproducible series.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticStockConfig:
    n_days: int = 1430            # ~ Jan 2012..Sep 2017 of trading days
    s0: float = 100.0             # initial price
    mu: float = 0.0004            # daily drift
    # GARCH(1,1) variance: sig2_{t+1} = w + alpha * r_t^2 + beta * sig2_t
    garch_omega: float = 2e-6
    garch_alpha: float = 0.10
    garch_beta: float = 0.87
    student_nu: float = 4.0       # heavy-tail dof for shocks
    jump_prob: float = 0.01       # daily jump (crash/rally) probability
    jump_scale: float = 0.04      # jump magnitude scale
    seed: int = 0


def _ticker_seed(ticker: str, seed: int) -> int:
    h = hashlib.sha256(f"{ticker}:{seed}".encode()).digest()
    return int.from_bytes(h[:8], "little") % (2**31)


def generate_ohlcv(ticker: str = "AAPL",
                   config: SyntheticStockConfig | None = None) -> np.ndarray:
    """Return float32 [n_days, 5] array: Open, High, Low, Close, Volume."""
    cfg = config or SyntheticStockConfig()
    rng = np.random.default_rng(_ticker_seed(ticker, cfg.seed))

    n = cfg.n_days
    sig2 = np.empty(n)
    ret = np.empty(n)
    sig2[0] = cfg.garch_omega / max(1e-9, (1 - cfg.garch_alpha - cfg.garch_beta))
    # Student-t shocks normalized to unit variance
    t_shocks = rng.standard_t(cfg.student_nu, size=n)
    t_shocks /= np.sqrt(cfg.student_nu / (cfg.student_nu - 2.0))
    jumps = (rng.random(n) < cfg.jump_prob) * rng.normal(
        0.0, cfg.jump_scale, size=n)
    for t in range(n):
        ret[t] = cfg.mu + np.sqrt(sig2[t]) * t_shocks[t] + jumps[t]
        if t + 1 < n:
            sig2[t + 1] = (cfg.garch_omega + cfg.garch_alpha * ret[t] ** 2
                           + cfg.garch_beta * sig2[t])

    close = cfg.s0 * np.exp(np.cumsum(ret))
    open_ = np.empty(n)
    open_[0] = cfg.s0
    open_[1:] = close[:-1] * np.exp(rng.normal(0, 0.002, size=n - 1))
    intra = np.abs(rng.normal(0, 0.5, size=n)) * np.sqrt(sig2) * close
    high = np.maximum(open_, close) + intra
    low = np.minimum(open_, close) - intra
    low = np.maximum(low, 1e-3)
    # volume spikes with |return| (well-documented stylized fact)
    volume = 1e6 * np.exp(rng.normal(0, 0.3, size=n)) * (
        1.0 + 25.0 * np.abs(ret))
    return np.stack([open_, high, low, close, volume], axis=1).astype(np.float32)


def log_returns(close: np.ndarray) -> np.ndarray:
    close = np.asarray(close, np.float64)
    return np.diff(np.log(close)).astype(np.float32)

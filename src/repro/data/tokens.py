"""Synthetic token / embedding streams for the LM architecture zoo.

Used by smoke tests and the e2e transformer example: deterministic
pseudo-random token ids with a Zipfian marginal (realistic softmax load)
and, for the audio/VLM frontends (stubbed per spec), precomputed frame or
patch embeddings of the right shape.
"""

from __future__ import annotations

import numpy as np


def synthetic_token_batch(batch: int, seq_len: int, vocab: int,
                          seed: int = 0) -> np.ndarray:
    """int32 [batch, seq_len] Zipf-distributed token ids in [0, vocab)."""
    rng = np.random.default_rng(seed)
    # Zipf via inverse-CDF on ranks; alpha ~ 1.1 typical of text
    ranks = rng.zipf(1.3, size=(batch, seq_len)).astype(np.int64)
    return np.asarray(np.minimum(ranks - 1, vocab - 1), np.int32)


def synthetic_embedding_batch(batch: int, n_frames: int, dim: int,
                              seed: int = 0) -> np.ndarray:
    """float32 [batch, n_frames, dim] unit-variance embeddings — stands in
    for the (stubbed) audio conv frontend or VLM vision encoder output."""
    rng = np.random.default_rng(seed)
    return rng.standard_normal((batch, n_frames, dim)).astype(np.float32)

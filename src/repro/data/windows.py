"""Sliding-window dataset construction (paper Table I: window = 20).

Windows are built over *normalized* features; the prediction target is the
next-step normalized close price (regression) plus the extreme-event
indicator of the next-step *return* (classification head for EVL).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.extreme.indicators import indicator_sequence


def normalize_windows(windows: np.ndarray) -> np.ndarray:
    """Per-window normalization w[i] -> w[i]/w[0] - 1 (the standard scheme
    of the paper's data-source repo): removes scale, keeps shape."""
    base = windows[:, :1, :]
    return (windows / np.maximum(np.abs(base), 1e-8) - 1.0).astype(np.float32)


@dataclasses.dataclass
class WindowDataset:
    x: np.ndarray          # [N, window, features]  normalized windows
    y: np.ndarray          # [N]                    next-step normalized close
    v: np.ndarray          # [N] int32              extreme indicator of next return
    returns: np.ndarray    # [N]                    raw next-step log return
    eps1: float
    eps2: float

    def __len__(self) -> int:
        return len(self.x)

    def batches(self, batch_size: int, rng: np.random.Generator | None = None,
                indices: np.ndarray | None = None, drop_last: bool = True):
        idx = np.arange(len(self.x)) if indices is None else np.asarray(indices)
        if rng is not None:
            idx = idx.copy()
            rng.shuffle(idx)
        end = (len(idx) // batch_size) * batch_size if drop_last else len(idx)
        for s in range(0, end, batch_size):
            b = idx[s:s + batch_size]
            yield self.x[b], self.y[b], self.v[b]


def make_windows(ohlcv: np.ndarray, window: int = 20,
                 quantile: float = 0.95,
                 eps: tuple[float, float] | None = None) -> WindowDataset:
    """Build the sliding-window dataset from [T, 5] OHLCV.

    Features: normalized OHLCV window. Target: next-day normalized close.
    Extreme labels: indicator of next-day log return vs (eps1, eps2)
    thresholds (defaults: 95% quantiles of |returns| — how [2] sets them).
    """
    close = ohlcv[:, 3]
    logret = np.diff(np.log(np.maximum(close, 1e-8))).astype(np.float32)
    n = len(ohlcv) - window  # windows [t, t+window) predicting index t+window
    if n <= 0:
        raise ValueError(f"series of length {len(ohlcv)} too short for "
                         f"window {window}")
    wins = np.stack([ohlcv[t:t + window] for t in range(n)], axis=0)
    xw = normalize_windows(wins)
    # target: next close normalized by window base
    base = np.maximum(np.abs(wins[:, 0, 3]), 1e-8)
    y = (close[window:window + n] / base - 1.0).astype(np.float32)
    next_ret = logret[window - 1:window - 1 + n]
    if eps is None:
        a = np.abs(logret)
        eps1 = float(np.quantile(a, quantile))
        eps2 = eps1
    else:
        eps1, eps2 = eps
    v = np.asarray(indicator_sequence(next_ret, eps1, eps2))
    return WindowDataset(x=xw, y=y, v=v, returns=next_ret,
                         eps1=eps1, eps2=eps2)

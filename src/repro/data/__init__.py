"""Data pipeline: synthetic heavy-tailed OHLCV stock data (S&P500-like),
sliding-window datasets, per-client splits (iid / heterogeneous), and
synthetic token/embedding streams for the LM architecture zoo.

The container is offline, so ``sp500.load_stock`` synthesizes a
deterministic, calibrated heavy-tailed series unless a real CSV is found
(DESIGN.md §7 — repro<=2 data gate, simulated).
"""

from repro.data.synthetic import SyntheticStockConfig, generate_ohlcv
from repro.data.sp500 import load_stock, train_test_split
from repro.data.windows import WindowDataset, make_windows, normalize_windows
from repro.data.sharding import client_splits
from repro.data.tokens import synthetic_token_batch

__all__ = [
    "SyntheticStockConfig",
    "WindowDataset",
    "client_splits",
    "generate_ohlcv",
    "load_stock",
    "make_windows",
    "normalize_windows",
    "synthetic_token_batch",
    "train_test_split",
]

"""Per-client data splits for the distributed setting.

The paper: "each compute node can have its own local data set ... or can
share the same data sets", and the theory [27] covers both iid and
heterogeneous data. We provide:

- ``iid``            — windows shuffled then striped round-robin;
- ``contiguous``     — each client gets a contiguous time span
                       (heterogeneous: regimes differ across clients);
- ``shared``         — every client sees the full data set (paper's
                       "share the same data sets" mode).
"""

from __future__ import annotations

import numpy as np


def client_splits(n_samples: int, n_clients: int, mode: str = "iid",
                  seed: int = 0) -> list[np.ndarray]:
    idx = np.arange(n_samples)
    if mode == "shared":
        return [idx.copy() for _ in range(n_clients)]
    if mode == "iid":
        rng = np.random.default_rng(seed)
        perm = rng.permutation(n_samples)
        return [np.sort(perm[c::n_clients]) for c in range(n_clients)]
    if mode == "contiguous":
        bounds = np.linspace(0, n_samples, n_clients + 1).astype(int)
        return [idx[bounds[c]:bounds[c + 1]] for c in range(n_clients)]
    raise ValueError(f"unknown split mode {mode!r}")

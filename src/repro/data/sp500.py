"""S&P500 loader with synthetic fallback.

The paper uses the CSV from jaungiers/LSTM-Neural-Network-for-Time-Series-
Prediction (OHLCV, Jan 2012 - Sep 2017), split 2012-2014 train / 2015-2016
test, tickers GOOGL, FB, AAPL, AMZN, IBM, NFLX, EBAY (results reported for
AAPL, AMZN). Offline container: if ``data/<ticker>.csv`` exists we parse
it; otherwise a calibrated synthetic series is generated (see synthetic.py
and DESIGN.md §7).
"""

from __future__ import annotations

import os

import numpy as np

from repro.data.synthetic import SyntheticStockConfig, generate_ohlcv

PAPER_TICKERS = ("GOOGL", "FB", "AAPL", "AMZN", "IBM", "NFLX", "EBAY")
_COLUMNS = ("Open", "High", "Low", "Close", "Volume")


def _parse_csv(path: str) -> np.ndarray:
    rows = []
    with open(path) as f:
        header = f.readline().strip().split(",")
        idx = []
        for col in _COLUMNS:
            for j, name in enumerate(header):
                if name.strip().lower() == col.lower():
                    idx.append(j)
                    break
        if len(idx) != 5:
            raise ValueError(f"{path}: could not find OHLCV columns in {header}")
        for line in f:
            parts = line.strip().split(",")
            if len(parts) <= max(idx):
                continue
            try:
                rows.append([float(parts[j]) for j in idx])
            except ValueError:
                continue
    if not rows:
        raise ValueError(f"{path}: no data rows parsed")
    return np.asarray(rows, np.float32)


def load_stock(ticker: str = "AAPL", data_dir: str = "data",
               n_days: int = 1430, seed: int = 0) -> np.ndarray:
    """[n_days, 5] OHLCV. Real CSV if present, else deterministic synthetic."""
    path = os.path.join(data_dir, f"{ticker}.csv")
    if os.path.exists(path):
        return _parse_csv(path)
    generic = os.path.join(data_dir, "sp500.csv")
    if os.path.exists(generic):
        return _parse_csv(generic)
    return generate_ohlcv(ticker, SyntheticStockConfig(n_days=n_days, seed=seed))


def train_test_split(series: np.ndarray,
                     train_fraction: float = 0.6) -> tuple[np.ndarray, np.ndarray]:
    """Chronological split — the paper uses 2012-2014 train (~60%) and
    2015-2016 test. Never shuffle before splitting a time series."""
    n = len(series)
    cut = int(n * train_fraction)
    return series[:cut], series[cut:]

"""Qwen2.5-32B [hf:Qwen/Qwen2.5-0.5B family scaling].

GQA (kv=8), QKV bias, gated SiLU MLP, RMSNorm, large vocab (152064).
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab=152064,
    qkv_bias=True,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=1e6,
    train_microbatches=16,
    source="hf:Qwen/Qwen2.5-0.5B",
))

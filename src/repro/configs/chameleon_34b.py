"""Chameleon-34B — early-fusion VLM decoder [arXiv:2405.09818].

Text + VQ image tokens share one vocabulary (65536 incl. 8192 image codes);
the transformer backbone is a llama-style decoder with qk-norm for
stability. The VQ image tokenizer is a STUB per the assignment —
``input_specs`` supplies token ids that include image-token spans.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    train_microbatches=16,
    source="arXiv:2405.09818",
))

"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family scaling].

94 layers, 128 experts top-8 (expert d_ff=1536), GQA kv=4 with qk-norm,
head_dim=128 (q_dim 8192 != d_model 4096). Largest assigned model:
235B total / ~22B active params; requires fully-sharded params+optimizer
(DESIGN.md §5).
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    n_experts=128,
    top_k=8,
    qk_norm=True,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=1e6,
    train_microbatches=16,
    adam_moment_dtype="bfloat16",
    source="hf:Qwen/Qwen3-30B-A3B",
))

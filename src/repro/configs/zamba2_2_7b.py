"""Zamba2-2.7B — hybrid: Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]. 54 Mamba2 layers (d_model=2560, ssm_state=64) with a
single *shared* full-attention+MLP block (tied weights, 32 MHA heads,
d_ff=10240) applied every 6 SSM layers.

Runs long_500k natively (SSM backbone); the shared attention block uses
the long-context sliding window for that shape.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    activation="gelu",
    gated_mlp=False,
    norm="rmsnorm",
    source="arXiv:2411.15242",
))

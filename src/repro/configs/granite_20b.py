"""Granite-20B (code) [arXiv:2405.04324].

GPT-BigCode-style deep-narrow decoder with multi-query attention
(n_kv_heads=1) and non-gated GELU MLP (d_ff = 4 * d_model).
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    activation="gelu",
    gated_mlp=False,
    norm="layernorm",
    train_microbatches=16,
    source="arXiv:2405.04324",
))

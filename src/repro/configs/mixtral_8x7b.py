"""Mixtral-8x7B — sparse MoE, 8 experts top-2, sliding-window attention
(window 4096) [arXiv:2401.04088]. GQA kv=8, gated SiLU experts.

Native SWA means long_500k runs with its own window (no variant needed).
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    n_experts=8,
    top_k=2,
    window=4096,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    train_microbatches=8,
    source="arXiv:2401.04088",
))

from repro.configs.base import (
    ArchConfig,
    ARCHS,
    get_config,
    list_archs,
    register,
)
from repro.configs.shapes import INPUT_SHAPES, InputShape

# importing the arch modules populates the registry
from repro.configs import (  # noqa: F401
    chameleon_34b,
    granite_20b,
    mamba2_370m,
    mixtral_8x7b,
    nemotron_4_15b,
    paper_lstm,
    qwen1_5_4b,
    qwen2_5_32b,
    qwen3_moe_235b_a22b,
    whisper_medium,
    zamba2_2_7b,
)

__all__ = [
    "ARCHS",
    "ArchConfig",
    "INPUT_SHAPES",
    "InputShape",
    "get_config",
    "list_archs",
    "register",
]

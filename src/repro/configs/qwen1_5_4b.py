"""Qwen1.5-4B [hf:Qwen/Qwen1.5-0.5B family scaling].

MHA (kv=20 == heads), QKV bias, gated SiLU MLP.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    train_microbatches=8,
    source="hf:Qwen/Qwen1.5-0.5B",
))

"""Whisper-medium — encoder-decoder ASR [arXiv:2212.04356].

24+24 layers, d_model=1024, 16 MHA heads, GELU, LayerNorm, learned
positions. The mel-spectrogram + conv frontend is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings
[batch, 1500, 1024]. Decode = text decoder with self-attn KV cache and
cross-attention to the encoder output.

long_500k is SKIPPED for this arch (enc-dec ASR decoder; 500k-token
autoregressive decode is not meaningful — DESIGN.md §4).
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    encoder_layers=24,
    n_frames=1500,
    qkv_bias=True,
    activation="gelu",
    gated_mlp=False,
    norm="layernorm",
    source="arXiv:2212.04356",
))

"""Nemotron-4-15B [arXiv:2402.16819].

GQA (kv=8), squared-ReLU non-gated MLP, 256k vocab.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=256000,
    activation="relu2",
    gated_mlp=False,
    norm="layernorm",
    train_microbatches=8,
    source="arXiv:2402.16819",
))

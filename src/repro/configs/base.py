"""Architecture config system: one frozen dataclass drives model
construction, sharding rules, dry-run input specs and roofline math.

``--arch <id>`` resolves through the registry (``get_config``); each
assigned architecture lives in its own module citing its source.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    """Pad vocab to a shardable multiple (logits over padding ids are
    never produced as labels). Kept import-free: configs must not import
    model code (model modules import configs)."""
    return ((vocab + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    qk_norm: bool = False
    activation: str = "silu"        # silu | gelu | relu2
    gated_mlp: bool = True
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    rope_theta: float = 1e4
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_group_size: int = 512
    moe_capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # hybrid (zamba2): one shared attention+MLP block applied every
    # ``attn_every`` SSM layers (tied weights)
    attn_every: int = 0
    # enc-dec (whisper)
    encoder_layers: int = 0
    n_frames: int = 1500            # encoder source positions (stub frontend)
    # attention
    window: Optional[int] = None    # sliding-window attention (SWA)
    long_context_window: int = 4096  # window used for long_500k dense variant
    decode_buffer: int = 256        # replicated decode write-buffer slots
    # numerics
    dtype: str = "bfloat16"
    remat: bool = True
    # gradient-accumulation microbatches for train_4k on the production
    # mesh — sized per arch so the remat-saved per-layer stacks fit
    # 16 GiB/chip (EXPERIMENTS.md §Dry-run)
    train_microbatches: int = 4
    # Adam moment storage dtype; "bfloat16" halves optimizer HBM (used by
    # qwen3-moe-235b to fit one pod — EXPERIMENTS.md §Perf HC2)
    adam_moment_dtype: str = "float32"
    # citation
    source: str = ""

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Archs running long_500k natively (sub-quadratic / O(1) state or
        native SWA); dense archs run it via the SWA variant."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    def param_count(self) -> int:
        """Approximate parameter count (used for roofline MODEL_FLOPS)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        emb = self.padded_vocab * d * 2  # embed + lm_head (untied)
        per_layer = 0
        if self.family == "ssm":
            per_layer = self._ssm_block_params()
        elif self.family == "hybrid":
            per_layer = self._ssm_block_params()
            n_shared = L // max(self.attn_every, 1)
            shared = (self._attn_params() + 3 * d * f + 2 * d)
            return emb + L * per_layer + shared + n_shared * 0 + 2 * d
        else:
            per_layer += self._attn_params()
            if self.n_experts:
                per_layer += d * self.n_experts  # router
                mult = 3 if self.gated_mlp else 2
                per_layer += self.n_experts * mult * d * f
            else:
                mult = 3 if self.gated_mlp else 2
                per_layer += mult * d * f
            per_layer += 2 * d  # norms
        total = emb + L * per_layer + d
        if self.encoder_layers:
            enc_layer = self._attn_params() + 2 * d * f + 2 * d
            total += self.encoder_layers * enc_layer
            total += L * (self._attn_params() + d)  # decoder cross-attn
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.n_experts:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        mult = 3 if self.gated_mlp else 2
        dense_like = self.param_count() - L * self.n_experts * mult * d * f
        return dense_like + L * self.top_k * mult * d * f

    def _attn_params(self) -> int:
        d = self.d_model
        return d * self.q_dim * 2 + d * self.kv_dim * 2

    def _ssm_block_params(self) -> int:
        d = self.d_model
        di = self.d_inner
        proj_in = d * (2 * di + 2 * self.ssm_state + self.ssm_heads)
        return proj_in + di * d + (di + 2 * self.ssm_state) * self.ssm_conv + 3 * self.ssm_heads + di + 2 * d


ARCHS: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    ARCHS[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> list[str]:
    return sorted(ARCHS)


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Reduced variant of the same family for CPU smoke tests
    (2 layers, d_model <= 512, <= 4 experts)."""
    small: dict = dict(
        n_layers=2,
        d_model=min(cfg.d_model, 256),
        n_heads=min(cfg.n_heads, 4),
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=64,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab=min(cfg.vocab, 1024),
        name=cfg.name + "-smoke",
    )
    if cfg.n_experts:
        small["n_experts"] = min(cfg.n_experts, 4)
        small["top_k"] = min(cfg.top_k, 2)
        small["moe_group_size"] = 64
        # capacity = group: no token ever dropped, so prefill/decode are
        # bitwise-consistent with the full forward in the smoke tests
        small["moe_capacity_factor"] = (small["n_experts"]
                                        / max(small["top_k"], 1))
    if cfg.ssm_state:
        small["ssm_state"] = min(cfg.ssm_state, 32)
        small["ssm_head_dim"] = 32
        small["ssm_chunk"] = 16
    if cfg.attn_every:
        small["attn_every"] = 1
        small["n_kv_heads"] = small["n_heads"]
    if cfg.encoder_layers:
        small["encoder_layers"] = 2
        small["n_frames"] = 16
    if cfg.window is not None:
        small["window"] = 64
    small["decode_buffer"] = 8      # exercise flush_recent in smoke tests
    if cfg.n_kv_heads == cfg.n_heads:  # MHA archs stay MHA
        small["n_kv_heads"] = small["n_heads"]
    small["dtype"] = "float32"
    small.update(overrides)
    return dataclasses.replace(cfg, **small)

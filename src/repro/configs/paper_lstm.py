"""The paper's own model: 2-layer LSTM + 3 FC layers, window 20, OHLCV
features (Table I + footnote ¶). Not part of the assigned-architecture
pool; used by the faithful reproduction experiments.
"""

from repro.models.rnn import RNNConfig

CONFIG = RNNConfig(input_dim=5, hidden=64, num_layers=2, fc_dims=(32, 16),
                   window=20, evl_head=True)

"""Mamba2-370M — attention-free SSM with state-space duality
[arXiv:2405.21060]. 48 layers, d_model=1024, expand=2 (d_inner=2048),
head_dim=64 (32 SSM heads), ssm_state=128, depthwise conv width 4.

Runs long_500k natively: decode state is O(1) in sequence length.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    norm="rmsnorm",
    source="arXiv:2405.21060",
))

"""The paper's model: Input -> 2 x LSTM -> 3 x FC (Table I footnote ¶),
sliding window 20, for stock prediction, plus an extreme-event indicator
head (sigmoid) for the EVL experiments.

Functional LSTM built on ``jax.lax.scan``. The per-step cell routes
through ``repro.kernels.dispatch``, which picks the fused Pallas kernel
(``repro.kernels.lstm``) or the plain XLA lowering per (backend, batch,
hidden) at trace time — train-time ``rnn_features`` and the serving
``step``/``replay`` paths therefore resolve identically.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.models.layers import dense_init

PyTree = Any


@dataclasses.dataclass(frozen=True)
class RNNConfig:
    input_dim: int = 5          # OHLCV
    hidden: int = 64
    num_layers: int = 2         # paper: 2 LSTM layers
    fc_dims: tuple = (32, 16)   # paper: 3 FC layers (2 hidden + output)
    window: int = 20            # paper Table I
    evl_head: bool = True       # extreme-event indicator head
    dtype: Any = jnp.float32


def init_lstm_layer(key, in_dim: int, hidden: int, dtype):
    k1, k2 = jax.random.split(key)
    # gates packed [i, f, g, o] along the last dim
    return {
        "wx": dense_init(k1, (in_dim, 4 * hidden), dtype),
        "wh": dense_init(k2, (hidden, 4 * hidden), dtype),
        # forget-gate bias 1.0 (standard trick for gradient flow)
        "b": jnp.concatenate([
            jnp.zeros((hidden,), dtype), jnp.ones((hidden,), dtype),
            jnp.zeros((2 * hidden,), dtype)]),
    }


def lstm_cell(p, x_t, h, c):
    """Fused LSTM cell: x_t [B, I]; h, c [B, H] -> (h', c'). Dispatch-
    routed: the kernel table picks Pallas or XLA for this (backend,
    batch, hidden) while the surrounding program traces."""
    return dispatch.lstm_cell(x_t, h, c, p["wx"], p["wh"], p["b"])


def lstm_layer_apply(p, xs):
    """xs [B, T, I] -> hs [B, T, H] via scan over time."""
    B = xs.shape[0]
    H = p["wh"].shape[0]
    h0 = jnp.zeros((B, H), xs.dtype)
    c0 = jnp.zeros((B, H), xs.dtype)

    def step(carry, x_t):
        h, c = carry
        h, c = lstm_cell(p, x_t, h, c)
        return (h, c), h

    (_, _), hs = jax.lax.scan(step, (h0, c0), xs.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2)


def init_rnn(key, cfg: RNNConfig) -> PyTree:
    keys = jax.random.split(key, cfg.num_layers + len(cfg.fc_dims) + 2)
    params: dict = {"lstm": [], "fc": []}
    in_dim = cfg.input_dim
    for i in range(cfg.num_layers):
        params["lstm"].append(init_lstm_layer(keys[i], in_dim, cfg.hidden,
                                              cfg.dtype))
        in_dim = cfg.hidden
    dims = (cfg.hidden,) + tuple(cfg.fc_dims)
    for j in range(len(cfg.fc_dims)):
        k = keys[cfg.num_layers + j]
        params["fc"].append({
            "w": dense_init(k, (dims[j], dims[j + 1]), cfg.dtype),
            "b": jnp.zeros((dims[j + 1],), cfg.dtype)})
    k_out = keys[-2]
    params["out"] = {"w": dense_init(k_out, (dims[-1], 1), cfg.dtype),
                     "b": jnp.zeros((1,), cfg.dtype)}
    if cfg.evl_head:
        k_evl = keys[-1]
        params["evl"] = {"w": dense_init(k_evl, (dims[-1], 1), cfg.dtype),
                         "b": jnp.zeros((1,), cfg.dtype)}
    return params


def rnn_features(params: PyTree, x):
    """x [B, T, input_dim] -> last-layer hidden sequence [B, T, H]."""
    h = x
    for lp in params["lstm"]:
        h = lstm_layer_apply(lp, h)
    return h


def rnn_head(params: PyTree, h, cfg: RNNConfig):
    """FC stack + output/EVL heads on a hidden state h [B, H]."""
    for fp in params["fc"]:
        h = jnp.tanh(h @ fp["w"] + fp["b"])
    y = (h @ params["out"]["w"] + params["out"]["b"])[:, 0]
    u = None
    if cfg.evl_head and "evl" in params:
        u = jax.nn.sigmoid((h @ params["evl"]["w"] + params["evl"]["b"]))[:, 0]
    return y, u


def rnn_apply(params: PyTree, x, cfg: RNNConfig):
    """x [B, window, input_dim] -> (y_pred [B], u_extreme [B] or None)."""
    h = rnn_features(params, x)[:, -1, :]     # last time step
    return rnn_head(params, h, cfg)


def rnn_apply_padded(params: PyTree, x, lengths, cfg: RNNConfig):
    """Length-bucketed apply: x [B, T, input_dim] right-padded to a bucket
    length T, lengths [B] int32 giving each example's true length.

    The LSTM stack is causal, so the hidden state at position len-1 depends
    only on x[:len] — gathering there yields exactly the unpadded result,
    which is what lets the serving batcher mix lengths in one bucket.
    """
    hs = rnn_features(params, x)
    idx = (jnp.asarray(lengths, jnp.int32) - 1)[:, None, None]
    h = jnp.take_along_axis(hs, jnp.broadcast_to(
        idx, (hs.shape[0], 1, hs.shape[2])), axis=1)[:, 0, :]
    return rnn_head(params, h, cfg)


def init_rnn_carry(params: PyTree, batch: int, dtype=jnp.float32):
    """Zero (h, c) carries for each LSTM layer — the per-session state
    kept resident by the serving session cache."""
    return tuple(
        (jnp.zeros((batch, lp["wh"].shape[0]), dtype),
         jnp.zeros((batch, lp["wh"].shape[0]), dtype))
        for lp in params["lstm"])


def stack_rnn_carries(carries, pad_to: int | None = None):
    """Stack per-session carries (each ``init_rnn_carry(params, 1)``
    shaped) into one batched carry: N x ([1, H], [1, H]) per layer ->
    ([N, H], [N, H]) per layer. ``pad_to`` right-pads the batch dim with
    zero rows (the decode lane's fixed width) in the same concatenate —
    one op per tensor, and the stacked buffer is freshly allocated, so
    the caller owns it (donation-safe)."""
    n = len(carries)
    pad = (pad_to - n) if pad_to is not None else 0
    if pad < 0:
        raise ValueError(f"cannot pad {n} carries to width {pad_to}")
    out = []
    for layer in range(len(carries[0])):
        parts_h = [c[layer][0] for c in carries]
        parts_c = [c[layer][1] for c in carries]
        if pad:
            z = jnp.zeros((pad,) + tuple(parts_h[0].shape[1:]),
                          parts_h[0].dtype)
            parts_h = parts_h + [z]
            parts_c = parts_c + [z]
        out.append((jnp.concatenate(parts_h, axis=0),
                    jnp.concatenate(parts_c, axis=0)))
    return tuple(out)


def split_rnn_carry(carry, n: int | None = None):
    """Inverse of ``stack_rnn_carries``: a batched carry -> list of
    batch-1 per-session carries (first ``n`` rows; padding rows beyond
    ``n`` are dropped)."""
    batch = carry[0][0].shape[0]
    n = batch if n is None else n
    return [tuple((h[i:i + 1], c[i:i + 1]) for h, c in carry)
            for i in range(n)]


def rnn_step(params: PyTree, x_t, carries, cfg: RNNConfig):
    """One time step: x_t [B, input_dim], carries from ``init_rnn_carry``.

    Returns (y [B], u [B] or None, new_carries). Feeding a window one step
    at a time from zero carries reproduces ``rnn_apply`` on that window —
    O(1) per step for streaming clients instead of O(window) recompute.
    """
    new_carries = []
    h = x_t
    for lp, (hc, cc) in zip(params["lstm"], carries):
        hc, cc = lstm_cell(lp, h, hc, cc)
        new_carries.append((hc, cc))
        h = hc
    y, u = rnn_head(params, h, cfg)
    return y, u, tuple(new_carries)

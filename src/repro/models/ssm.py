"""Mamba2 (state-space duality / SSD) block — arXiv:2405.21060.

Training path: chunked SSD — within a chunk the quadratic "dual" form runs
on the MXU; across chunks a sequential ``lax.scan`` carries the SSM state
(O(L) total). Decode path: the O(1) recurrence

    state <- exp(dt*A) * state + (dt*x) outer B
    y     <- C . state + D * x

This module is the pure-JAX reference; ``repro.kernels.ssd`` implements the
chunk kernel in Pallas with the same block decomposition.

Shapes (single SSM group, as in mamba2-370m / zamba2):
    x  [B, L, H, P]   (H heads, P = head_dim)
    dt [B, L, H]      (positive, after softplus + bias)
    A  [H]            (negative; A = -exp(A_log))
    B_, C_ [B, L, N]  (N = ssm_state)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import silu, rms_norm


def segsum(a):
    """[..., K] -> [..., K, K] lower-triangular segment sums:
    out[..., q, k] = sum_{i in (k, q]} a[..., i] for q >= k, else -inf."""
    K = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    d = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((K, K), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(xd, a, B_, C_, chunk: int = 128, initial_state=None):
    """Chunked SSD scan.

    Args:
        xd: [B, L, H, P] — dt-scaled inputs (dt * x).
        a:  [B, L, H]    — per-step log decay (dt * A, negative).
        B_, C_: [B, L, N].
        chunk: chunk length (L padded to a multiple).
        initial_state: optional [B, H, P, N].

    Returns (y [B, L, H, P], final_state [B, H, P, N]).
    """
    Bsz, L, H, P = xd.shape
    N = B_.shape[-1]
    pad = (-L) % chunk
    if pad:
        xd = jnp.pad(xd, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk

    # [nc, B, K, ...]
    xc = xd.reshape(Bsz, nc, chunk, H, P).transpose(1, 0, 2, 3, 4)
    ac = a.reshape(Bsz, nc, chunk, H).transpose(1, 0, 2, 3)
    Bc = B_.reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3)
    Cc = C_.reshape(Bsz, nc, chunk, N).transpose(1, 0, 2, 3)

    if initial_state is None:
        initial_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def chunk_step(state, inp):
        x_k, a_k, B_k, C_k = inp       # [B,K,H,P], [B,K,H], [B,K,N], [B,K,N]
        x32 = x_k.astype(jnp.float32)
        a32 = a_k.astype(jnp.float32)
        B32 = B_k.astype(jnp.float32)
        C32 = C_k.astype(jnp.float32)

        a_hk = a32.transpose(0, 2, 1)                 # [B,H,K]
        cum = jnp.cumsum(a_hk, axis=-1)               # [B,H,K]
        Lmat = jnp.exp(segsum(a_hk))                  # [B,H,K,K] lower-tri

        # intra-chunk (dual / attention-like form)
        scores = jnp.einsum("bqn,bkn->bqk", C32, B32)  # [B,K,K]
        Y = jnp.einsum("bqk,bhqk,bkhp->bqhp", scores, Lmat, x32)

        # contribution of the carried state
        decay_q = jnp.exp(cum).transpose(0, 2, 1)      # [B,K,H]
        Y = Y + jnp.einsum("bqn,bqh,bhpn->bqhp", C32, decay_q, state)

        # state update
        total = cum[..., -1]                           # [B,H]
        decay_k = jnp.exp(total[..., None] - cum)      # [B,H,K]
        new_state = state * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bkn,bhk,bkhp->bhpn", B32, decay_k, x32)
        return new_state, Y.astype(xd.dtype)

    final_state, Yc = jax.lax.scan(chunk_step, initial_state,
                                   (xc, ac, Bc, Cc))
    y = Yc.transpose(1, 0, 2, 3, 4).reshape(Bsz, Lp, H, P)
    return y[:, :L], final_state


def ssd_reference(xd, a, B_, C_, initial_state=None):
    """O(L) sequential oracle (tests only)."""
    Bsz, L, H, P = xd.shape
    N = B_.shape[-1]
    state = (jnp.zeros((Bsz, H, P, N), jnp.float32)
             if initial_state is None else initial_state)
    ys = []
    for t in range(L):
        state = (state * jnp.exp(a[:, t]).astype(jnp.float32)[..., None, None]
                 + jnp.einsum("bn,bhp->bhpn", B_[:, t].astype(jnp.float32),
                              xd[:, t].astype(jnp.float32)))
        ys.append(jnp.einsum("bn,bhpn->bhp", C_[:, t].astype(jnp.float32),
                             state))
    return jnp.stack(ys, axis=1).astype(xd.dtype), state


def ssd_decode_step(state, xd_t, a_t, B_t, C_t):
    """One decode step. state [B,H,P,N]; xd_t [B,H,P]; a_t [B,H];
    B_t, C_t [B,N]. Returns (y_t [B,H,P], new_state)."""
    decay = jnp.exp(a_t.astype(jnp.float32))[..., None, None]
    state = state * decay + jnp.einsum(
        "bn,bhp->bhpn", B_t.astype(jnp.float32), xd_t.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", C_t.astype(jnp.float32), state)
    return y.astype(xd_t.dtype), state


# -------------------------------------------------------------------------
# Full Mamba2 block (in_proj -> causal conv -> SSD -> gated norm -> out)
# -------------------------------------------------------------------------

def _split_proj(zxbcdt, d_inner, n_state, n_heads):
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:2 * d_inner + 2 * n_state]
    dt = zxbcdt[..., 2 * d_inner + 2 * n_state:]
    assert dt.shape[-1] == n_heads
    return z, xBC, dt


def causal_conv1d(x, w, b):
    """Depthwise causal conv. x [B, L, Cdim]; w [Cdim, K]; b [Cdim]."""
    K = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # windows: y[t] = sum_k x[t-K+1+k] * w[k]
    out = sum(xp[:, k:k + x.shape[1], :] * w[None, None, :, k]
              for k in range(K))
    return out + b[None, None, :]


def conv_decode_step(conv_state, x_t, w, b):
    """conv_state [B, K-1, Cdim] holds the last K-1 inputs; x_t [B, Cdim]."""
    K = w.shape[-1]
    full = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # [B,K,C]
    y = jnp.einsum("bkc,ck->bc", full, w) + b[None, :]
    return y, full[:, 1:, :] if K > 1 else conv_state


def mamba2_apply(p, x, *, head_dim: int, ssm_state: int, chunk: int = 128,
                 dt_limit=(1e-4, 1e2)):
    """Full block forward. x [B, L, D] -> [B, L, D]."""
    Bsz, L, D = x.shape
    d_inner = p["out_proj"].shape[0]
    H = d_inner // head_dim
    N = ssm_state

    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split_proj(zxbcdt, d_inner, N, H)
    xBC = silu(causal_conv1d(xBC, p["conv_w"], p["conv_b"]))
    xs = xBC[..., :d_inner].reshape(Bsz, L, H, head_dim)
    B_ = xBC[..., d_inner:d_inner + N]
    C_ = xBC[..., d_inner + N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt = jnp.clip(dt, *dt_limit)                                # [B, L, H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # [H]
    a = dt * A[None, None, :]
    xd = xs * dt[..., None].astype(xs.dtype)

    y, _ = ssd_chunked(xd, a, B_, C_, chunk=chunk)
    y = y + xs * p["D"][None, None, :, None]
    y = y.reshape(Bsz, L, d_inner)
    y = rms_norm(y * silu(z), p["norm_w"])
    return y @ p["out_proj"]


def mamba2_decode(p, x_t, conv_state, ssm_state_arr, *, head_dim: int,
                  ssm_state: int, dt_limit=(1e-4, 1e2)):
    """One-token decode. x_t [B, D]. Returns (y [B, D], conv_state, state)."""
    Bsz, D = x_t.shape
    d_inner = p["out_proj"].shape[0]
    H = d_inner // head_dim
    N = ssm_state

    zxbcdt = x_t @ p["in_proj"]
    z, xBC, dt = _split_proj(zxbcdt, d_inner, N, H)
    xBC, conv_state = conv_decode_step(conv_state, xBC, p["conv_w"],
                                       p["conv_b"])
    xBC = silu(xBC)
    xs = xBC[..., :d_inner].reshape(Bsz, H, head_dim)
    B_ = xBC[..., d_inner:d_inner + N]
    C_ = xBC[..., d_inner + N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt = jnp.clip(dt, *dt_limit)                                # [B, H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a_t = dt * A[None, :]
    xd_t = xs * dt[..., None].astype(xs.dtype)

    y, ssm_state_arr = ssd_decode_step(ssm_state_arr, xd_t, a_t, B_, C_)
    y = y + xs * p["D"][None, :, None]
    y = y.reshape(Bsz, d_inner)
    y = rms_norm(y * silu(z), p["norm_w"])
    return y @ p["out_proj"], conv_state, ssm_state_arr

"""Dense MLP (gated / non-gated) and Mixture-of-Experts with GShard-style
capacity-grouped einsum dispatch (expert-parallel friendly).

MoE baseline design (see DESIGN.md §5): tokens are reshaped into groups of
``group_size``; per group each token picks top-k experts; one-hot dispatch
and combine tensors of shape [G, s, E, C] route tokens through the stacked
expert FFNs via einsums. Expert dim shards on the ``model`` mesh axis when
divisible, groups shard on ``data``; XLA's sharding propagation inserts
the all-to-alls. Small ``group_size`` keeps the dispatch-einsum FLOPs at
a few percent of expert FLOPs (dispatch cost ~ tokens*s*topk*cf*d_model).

Dropped tokens (over capacity) pass through on the residual path, the
standard Switch/GShard behaviour. A load-balance auxiliary loss
(Switch-style) is returned for the optimizer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ACTIVATIONS
from repro.models.pshard import constrain


def mlp_apply(p, x, activation: str, gated: bool):
    act = ACTIVATIONS[activation]
    h = x @ p["w1"]
    if "b1" in p:
        h = h + p["b1"]
    h = act(h)
    if gated:
        g = x @ p["w3"]
        h = h * g
    out = h @ p["w2"]
    if "b2" in p:
        out = out + p["b2"]
    return out


def moe_apply(p, x, *, top_k: int, activation: str, gated: bool,
              group_size: int = 512, capacity_factor: float = 1.25):
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar).

    p: router [D, E], w1/w3 [E, D, F], w2 [E, F, D].
    """
    B, S, D = x.shape
    E = p["router"].shape[1]
    act = ACTIVATIONS[activation]

    tokens = x.reshape(B * S, D)
    n = tokens.shape[0]
    s = min(group_size, n)
    # pad token count to a multiple of the group size
    pad = (-n) % s
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    g = tokens.shape[0] // s
    xt = constrain(tokens.reshape(g, s, D), "batch", None, None)

    logits = (xt.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))          # [g, s, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)      # [g, s, k]
    # renormalize the chosen gates (mixtral convention)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    capacity = max(1, int(s * top_k * capacity_factor / E))

    # position of each (token, k) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)   # [g, s, k, E]
    flat = onehot.reshape(g, s * top_k, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) * flat - 1     # [g, s*k, E]
    pos_in_expert = pos_in_expert.reshape(g, s, top_k, E)
    keep = (pos_in_expert >= 0) & (pos_in_expert < capacity)

    pos_clip = jnp.clip(pos_in_expert, 0, capacity - 1)
    # NOTE (§Perf HC2, refuted hypothesis): building dispatch/combine in
    # bf16 was tried and made both the memory term and peak WORSE
    # (qwen3 train: 120.7->130.9 s, 17.8->26.3 GiB peak) — the bf16
    # one-hot product chain materializes the [g,s,k,E,C] intermediate
    # that XLA folds away in the f32 formulation. Kept in f32.
    cap_onehot = jax.nn.one_hot(pos_clip, capacity, dtype=jnp.float32)
    # dispatch [g, s, E, C]: 1 where token s routes to expert e slot c
    dispatch = jnp.sum(onehot.astype(jnp.float32)[..., None] * cap_onehot
                       * keep[..., None], axis=2)
    combine = jnp.sum(gate_vals[..., None, None]
                      * onehot.astype(jnp.float32)[..., None] * cap_onehot
                      * keep[..., None], axis=2)            # [g, s, E, C]

    dtype = x.dtype
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch.astype(dtype), xt)
    # expert-parallel layout: experts on 'model', groups on batch axes —
    # the reshard from token-major to expert-major is the MoE all-to-all
    expert_in = constrain(expert_in, "model", "batch", None, None)
    h = jnp.einsum("egcd,edf->egcf", expert_in, p["w1"])
    h = act(h)
    if gated:
        h = h * jnp.einsum("egcd,edf->egcf", expert_in, p["w3"])
    expert_out = constrain(jnp.einsum("egcf,efd->egcd", h, p["w2"]),
                           "model", "batch", None, None)
    out = constrain(jnp.einsum("egcd,gsec->gsd", expert_out,
                               combine.astype(dtype)), "batch", None, None)

    out = out.reshape(-1, D)
    if pad:
        out = out[:n]
    out = out.reshape(B, S, D)

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    frac_tokens = jnp.mean(onehot[..., 0, :] * 0.0 + jnp.sum(
        onehot.astype(jnp.float32), axis=2), axis=(0, 1)) / top_k  # [E]
    mean_prob = jnp.mean(probs, axis=(0, 1))                       # [E]
    aux = E * jnp.sum(frac_tokens * mean_prob)
    return out, aux

"""Transformer zoo: decoder LMs (dense / MoE / VLM), SSM stacks, hybrid
(Mamba2 + shared attention), and encoder-decoder (Whisper backbone).

Functional design:
    params = init_lm(cfg, key)                  (or jax.eval_shape for dry-run)
    logits, aux = lm_forward(cfg, params, batch)            # train
    logits, cache = lm_prefill(cfg, params, batch)          # prefill
    logits, cache = lm_decode_step(cfg, params, tok, cache) # decode

Layers are stacked on a leading [L, ...] dim and driven by ``jax.lax.scan``
(one compiled block body per block type — keeps 94-layer models cheap to
compile) with optional rematerialization.

Whisper deviation (see configs/whisper_medium.py): the decoder uses RoPE
instead of learned positions so parameter shapes stay independent of the
dry-run sequence length; the encoder keeps a learned [n_frames, d] table.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.attention import blocked_attention, decode_attention
from repro.models.layers import (
    apply_norm,
    apply_rope,
    dense_init,
    embed_init,
    norm_param,
    rms_norm,
)
from repro.models.mlp import mlp_apply, moe_apply
from repro.models.pshard import constrain
from repro.models import ssm as ssm_mod

PyTree = Any


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ==========================================================================
# Parameter construction
# ==========================================================================

def _init_attn(key, cfg: ArchConfig, dt, n_heads=None, n_kv=None, head_dim=None):
    H = n_heads or cfg.n_heads
    Hkv = n_kv or cfg.n_kv_heads
    hd = head_dim or cfg.head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dt),
        "wk": dense_init(ks[1], (d, Hkv * hd), dt),
        "wv": dense_init(ks[2], (d, Hkv * hd), dt),
        "wo": dense_init(ks[3], (H * hd, d), dt, scale=1.0),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((Hkv * hd,), dt)
        p["bv"] = jnp.zeros((Hkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _init_mlp(key, cfg: ArchConfig, dt):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], (d, f), dt),
         "w2": dense_init(ks[1], (f, d), dt)}
    if cfg.gated_mlp:
        p["w3"] = dense_init(ks[2], (d, f), dt)
    return p


def _init_moe(key, cfg: ArchConfig, dt):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {"router": dense_init(ks[0], (d, E), jnp.float32),
         "w1": dense_init(ks[1], (E, d, f), dt),
         "w2": dense_init(ks[2], (E, f, d), dt)}
    if cfg.gated_mlp:
        p["w3"] = dense_init(ks[3], (E, d, f), dt)
    return p


def _init_ssm_block(key, cfg: ArchConfig, dt):
    d = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    conv_dim = di + 2 * N
    ks = jax.random.split(key, 3)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * N + H), dt),
        "conv_w": dense_init(ks[1], (conv_dim, cfg.ssm_conv), dt, scale=1.0),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),      # A = -1 at init
        "D": jnp.ones((H,), dt),
        "norm_w": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[2], (di, d), dt, scale=1.0),
    }


def _stack(fn, key, n: int):
    """Init ``n`` copies of a param subtree and stack leaves on axis 0."""
    keys = jax.random.split(key, n)
    trees = [fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *trees)


def _init_decoder_layer(key, cfg: ArchConfig, dt, cross: bool = False):
    ks = jax.random.split(key, 6)
    p = {"norm1": norm_param(cfg.norm, cfg.d_model, dt),
         "attn": _init_attn(ks[0], cfg, dt),
         "norm2": norm_param(cfg.norm, cfg.d_model, dt)}
    if cfg.n_experts:
        p["moe"] = _init_moe(ks[1], cfg, dt)
    else:
        p["mlp"] = _init_mlp(ks[1], cfg, dt)
    if cross:
        p["norm_x"] = norm_param(cfg.norm, cfg.d_model, dt)
        p["xattn"] = _init_attn(ks[2], cfg, dt)
    return p


def init_lm(cfg: ArchConfig, key) -> PyTree:
    """Build the parameter pytree for any assigned architecture."""
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    V, d = cfg.padded_vocab, cfg.d_model
    params: dict = {
        "embed": embed_init(ks[0], (V, d), dt),
        "final_norm": norm_param(cfg.norm, d, dt),
        "lm_head": dense_init(ks[1], (d, V), dt),
    }
    if cfg.family == "ssm":
        params["layers"] = _stack(
            lambda k: {"norm1": norm_param(cfg.norm, d, dt),
                       "ssm": _init_ssm_block(k, cfg, dt)},
            ks[2], cfg.n_layers)
    elif cfg.family == "hybrid":
        params["layers"] = _stack(
            lambda k: {"norm1": norm_param(cfg.norm, d, dt),
                       "ssm": _init_ssm_block(k, cfg, dt)},
            ks[2], cfg.n_layers)
        # one SHARED attention+MLP block (tied weights, applied per stage)
        params["shared"] = _init_decoder_layer(ks[3], cfg, dt)
    elif cfg.family == "audio":
        params["enc_pos"] = embed_init(ks[4], (cfg.n_frames, d), dt)
        params["enc_layers"] = _stack(
            lambda k: _init_decoder_layer(k, cfg, dt), ks[5],
            cfg.encoder_layers)
        params["layers"] = _stack(
            lambda k: _init_decoder_layer(k, cfg, dt, cross=True), ks[2],
            cfg.n_layers)
    else:  # dense / moe / vlm
        params["layers"] = _stack(
            lambda k: _init_decoder_layer(k, cfg, dt), ks[2], cfg.n_layers)
    return params


# ==========================================================================
# Attention block (training / prefill path)
# ==========================================================================

def _project_qkv(cfg: ArchConfig, p, x, positions):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, -1, hd)
    k = k.reshape(B, S, -1, hd)
    v = v.reshape(B, S, -1, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if positions is not None:  # rope (None => learned/absolute upstream)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_block(cfg: ArchConfig, p, x, positions, *, causal=True,
                window=None, return_kv=False):
    q, k, v = _project_qkv(cfg, p, x, positions)
    out = blocked_attention(q, k, v, causal=causal, window=window)
    out = out.reshape(x.shape[0], x.shape[1], -1) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def _cross_attn_block(cfg: ArchConfig, p, x, kv):
    """Cross attention: q from x, (k, v) precomputed from encoder output."""
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, -1, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
    k, v = kv
    out = blocked_attention(q, k, v, causal=False)
    return out.reshape(B, S, -1) @ p["wo"]


def _encode_cross_kv(cfg: ArchConfig, p, enc_out):
    """Per-decoder-layer k/v projections of the encoder output."""
    B, F, _ = enc_out.shape
    hd = cfg.head_dim
    k = enc_out @ p["wk"]
    v = enc_out @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(B, F, -1, hd)
    v = v.reshape(B, F, -1, hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"])
    return k, v


# ==========================================================================
# Layer bodies (train / prefill)
# ==========================================================================

def _ffn(cfg: ArchConfig, lp, h):
    if cfg.n_experts:
        out, aux = moe_apply(lp["moe"], h, top_k=cfg.top_k,
                             activation=cfg.activation, gated=cfg.gated_mlp,
                             group_size=cfg.moe_group_size,
                             capacity_factor=cfg.moe_capacity_factor)
        return out, aux
    return mlp_apply(lp["mlp"], h, cfg.activation, cfg.gated_mlp), 0.0


def _decoder_block(cfg: ArchConfig, lp, x, positions, window, cross_kv=None):
    x = constrain(x, "batch", None, None)
    h = apply_norm(x, lp["norm1"], cfg.norm)
    x = x + _attn_block(cfg, lp["attn"], h, positions, window=window)
    if cross_kv is not None:
        h = apply_norm(x, lp["norm_x"], cfg.norm)
        x = x + _cross_attn_block(cfg, lp["xattn"], h, cross_kv)
    h = apply_norm(x, lp["norm2"], cfg.norm)
    out, aux = _ffn(cfg, lp, h)
    return constrain(x + out, "batch", None, None), aux


def _ssm_block(cfg: ArchConfig, lp, x):
    x = constrain(x, "batch", None, None)
    h = apply_norm(x, lp["norm1"], cfg.norm)
    y = x + ssm_mod.mamba2_apply(
        lp["ssm"], h, head_dim=cfg.ssm_head_dim, ssm_state=cfg.ssm_state,
        chunk=cfg.ssm_chunk)
    return constrain(y, "batch", None, None)


def _effective_window(cfg: ArchConfig, seq_len: int):
    """SWA window for this forward: native window if the arch has one,
    else the long-context variant window when seq_len is huge (DESIGN §4)."""
    if cfg.window is not None:
        return cfg.window
    if seq_len > 131072 and cfg.family not in ("ssm",):
        return cfg.long_context_window
    return None


def _maybe_remat(cfg: ArchConfig, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


# ==========================================================================
# Forward (training) — returns logits and MoE aux loss
# ==========================================================================

def _embed(cfg: ArchConfig, params, tokens):
    return constrain(params["embed"][tokens], "batch", None, None)


def _run_encoder(cfg: ArchConfig, params, frames):
    """frames: [B, n_frames, d] (stub frontend output) -> [B, n_frames, d]."""
    x = frames.astype(_dtype(cfg)) + params["enc_pos"][None, :frames.shape[1]]

    # bidirectional: the encoder calls the attention block with causal=False.
    def enc_block(x, lp):
        h = apply_norm(x, lp["norm1"], cfg.norm)
        x = x + _attn_block(cfg, lp["attn"], h, None, causal=False)
        h = apply_norm(x, lp["norm2"], cfg.norm)
        out, _ = _ffn(cfg, lp, h)
        return x + out, None

    x, _ = jax.lax.scan(_maybe_remat(cfg, enc_block), x,
                        params["enc_layers"])
    return x


def lm_forward(cfg: ArchConfig, params: PyTree, tokens, frames=None):
    """Training/prefill forward.

    tokens: int32 [B, S]. frames: [B, n_frames, d] for audio archs.
    Returns (logits [B, S, padded_vocab], aux_loss scalar).
    """
    B, S = tokens.shape
    x = _embed(cfg, params, tokens)
    positions = jnp.arange(S)[None, :].repeat(B, 0)
    window = _effective_window(cfg, S)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family == "ssm":
        def body(x, lp):
            return _ssm_block(cfg, lp, x), None
        x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, params["layers"])

    elif cfg.family == "hybrid":
        n_stages = cfg.n_layers // cfg.attn_every
        stacked = jax.tree.map(
            lambda a: a.reshape((n_stages, cfg.attn_every) + a.shape[1:]),
            params["layers"])
        shared = params["shared"]

        def stage(x, stage_params):
            def inner(x, lp):
                return _ssm_block(cfg, lp, x), None
            x, _ = jax.lax.scan(inner, x, stage_params)
            x, _ = _decoder_block(cfg, shared, x, positions, window)
            return x, None

        x, _ = jax.lax.scan(_maybe_remat(cfg, stage), x, stacked)

    elif cfg.family == "audio":
        if frames is None:
            raise ValueError("audio arch requires frame embeddings")
        enc_out = _run_encoder(cfg, params, frames)

        def body(carry, lp):
            x = carry
            kv = _encode_cross_kv(cfg, lp["xattn"], enc_out)
            x, _ = _decoder_block(cfg, lp, x, positions, window, cross_kv=kv)
            return x, None

        x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, params["layers"])

    else:  # dense / moe / vlm
        def body(carry, lp):
            x, aux = carry
            x, a = _decoder_block(cfg, lp, x, positions, window)
            return (x, aux + a), None

        (x, aux_total), _ = jax.lax.scan(
            _maybe_remat(cfg, body), (x, aux_total), params["layers"])

    x = apply_norm(x, params["final_norm"], cfg.norm)
    logits = constrain(x @ params["lm_head"], "batch", None, "model")
    return logits, aux_total


def lm_loss(cfg: ArchConfig, params: PyTree, tokens, frames=None,
            aux_weight: float = 0.01):
    """Next-token cross entropy (+ MoE load-balance aux)."""
    logits, aux = lm_forward(cfg, params, tokens, frames)
    logits = logits[:, :-1].astype(jnp.float32)
    labels = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + aux_weight * aux


# ==========================================================================
# KV / state caches and decode
# ==========================================================================

def _attn_cache_mode(cfg: ArchConfig, max_len: int) -> tuple[str, int]:
    """('ring', W) for sliding-window archs (cache = W slots, slot =
    pos % W), else ('full', max_len) with a main+recent split."""
    W = _effective_window(cfg, max_len)
    if W is not None and W < max_len:
        return "ring", W
    return "full", max_len


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> PyTree:
    dt = _dtype(cfg)
    hd, Hkv = cfg.head_dim, cfg.n_kv_heads
    cache: dict = {"len": jnp.zeros((), jnp.int32)}

    def attn_bufs(n_stacked: int) -> dict:
        # The replicated decode write buffer ("recent" tokens,
        # cfg.decode_buffer slots): new-token k/v land here via a clean
        # DUS; the big "main" cache is read-only inside a decode step and
        # is folded in by flush_recent — a simplified paged-KV layout
        # that keeps main shardable on any axis (DESIGN.md §5).
        mode, size = _attn_cache_mode(cfg, max_len)
        R = cfg.decode_buffer
        bufs = {
            "k": jnp.zeros((n_stacked, batch, size, Hkv, hd), dt),
            "v": jnp.zeros((n_stacked, batch, size, Hkv, hd), dt),
        }
        if mode == "full":
            bufs["kr"] = jnp.zeros((n_stacked, batch, R, Hkv, hd), dt)
            bufs["vr"] = jnp.zeros((n_stacked, batch, R, Hkv, hd), dt)
            bufs["flushed"] = jnp.zeros((), jnp.int32)
        return bufs

    if cfg.family == "ssm":
        cache.update(_ssm_cache(cfg, batch, cfg.n_layers, dt))
    elif cfg.family == "hybrid":
        n_stages = cfg.n_layers // cfg.attn_every
        cache.update(_ssm_cache(cfg, batch, cfg.n_layers, dt))
        cache.update(attn_bufs(n_stages))
    elif cfg.family == "audio":
        cache.update(attn_bufs(cfg.n_layers))
        cache["xk"] = jnp.zeros((cfg.n_layers, batch, cfg.n_frames, Hkv, hd), dt)
        cache["xv"] = jnp.zeros((cfg.n_layers, batch, cfg.n_frames, Hkv, hd), dt)
    else:
        cache.update(attn_bufs(cfg.n_layers))
    return cache


def _ssm_cache(cfg: ArchConfig, batch: int, n_layers: int, dt):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, conv_dim), dt),
        "ssm": jnp.zeros((n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), jnp.float32),
    }


def _decode_attn(cfg: ArchConfig, p, x, bufs, pos, flushed):
    """x: [B, 1, d]; bufs = (k, v) ring or (k, v, kr, vr) full split.
    pos: scalar int32 (token index being decoded); flushed: int32 count
    of tokens already flushed into the main cache (full mode).
    Returns (out [B, 1, d], new_bufs) — main k/v pass through untouched."""
    positions = pos[None, None].repeat(x.shape[0], 0)
    q, k, v = _project_qkv(cfg, p, x, positions)
    if len(bufs) == 2:                      # ring (sliding window)
        kc, vc = bufs
        W = kc.shape[1]
        slot = pos % W
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        out = decode_attention(q, [(kc, vc, jnp.minimum(pos + 1, W))])
        new_bufs = (kc, vc)
    else:                                   # full: read-only main + recent
        km, vm, kr, vr = bufs
        slot = pos - flushed
        kr = jax.lax.dynamic_update_slice_in_dim(kr, k, slot, axis=1)
        vr = jax.lax.dynamic_update_slice_in_dim(vr, v, slot, axis=1)
        out = decode_attention(
            q, [(km, vm, flushed), (kr, vr, pos - flushed + 1)])
        new_bufs = (km, vm, kr, vr)
    out = out.reshape(x.shape[0], 1, -1) @ p["wo"]
    return out, new_bufs


def _decode_ssm_block(cfg: ArchConfig, lp, x, conv_state, ssm_state):
    h = apply_norm(x, lp["norm1"], cfg.norm)
    y, conv_state, ssm_state = ssm_mod.mamba2_decode(
        lp["ssm"], h[:, 0], conv_state, ssm_state,
        head_dim=cfg.ssm_head_dim, ssm_state=cfg.ssm_state)
    return x + y[:, None], conv_state, ssm_state


def lm_decode_step(cfg: ArchConfig, params: PyTree, token, cache: PyTree):
    """One decode step. token: int32 [B]. Returns (logits [B, V], cache).

    Attention caches: ring mode writes in place (slot = pos % W); full
    mode writes only the replicated recent buffer — the sharded main
    cache passes through untouched (flushed by ``flush_recent``)."""
    pos = cache["len"]
    full = "kr" in cache
    flushed = cache.get("flushed", jnp.zeros((), jnp.int32))
    x = _embed(cfg, params, token[:, None])
    x = constrain(x, "batch", None, None)
    new_cache = dict(cache)

    def attn_xs(extra=()):
        bufs = (cache["k"], cache["v"]) + (
            (cache["kr"], cache["vr"]) if full else ())
        return bufs + tuple(extra)

    def split_bufs(inp):
        if full:
            return inp[:4], inp[4:]
        return inp[:2], inp[2:]

    def updated(new_bufs):
        """Scan outputs: only the written buffers (main is read-only)."""
        if full:
            return new_bufs[2:]             # (kr, vr)
        return new_bufs                     # (k, v)

    def store(out_bufs):
        if full:
            new_cache.update(kr=out_bufs[0], vr=out_bufs[1])
        else:
            new_cache.update(k=out_bufs[0], v=out_bufs[1])

    if cfg.family == "ssm":
        def body(x, inp):
            lp, conv, st = inp
            x, conv, st = _decode_ssm_block(cfg, lp, x, conv, st)
            return x, (conv, st)
        x, (conv, st) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["ssm"]))
        new_cache.update(conv=conv, ssm=st)

    elif cfg.family == "hybrid":
        n_stages = cfg.n_layers // cfg.attn_every
        re_stage = lambda a: a.reshape((n_stages, cfg.attn_every) + a.shape[1:])
        re_flat = lambda a: a.reshape((cfg.n_layers,) + a.shape[2:])
        stacked = jax.tree.map(re_stage, params["layers"])
        conv_s = re_stage(cache["conv"])
        ssm_s = re_stage(cache["ssm"])
        shared = params["shared"]

        def stage(x, inp):
            sp, conv, st = inp[0], inp[1], inp[2]
            bufs, _ = split_bufs(inp[3:])
            def inner(x, i):
                lp, c, s = i
                x, c, s = _decode_ssm_block(cfg, lp, x, c, s)
                return x, (c, s)
            x, (conv, st) = jax.lax.scan(inner, x, (sp, conv, st))
            h = apply_norm(x, shared["norm1"], cfg.norm)
            a, new_bufs = _decode_attn(cfg, shared["attn"], h, bufs, pos,
                                       flushed)
            x = x + a
            h = apply_norm(x, shared["norm2"], cfg.norm)
            out, _ = _ffn(cfg, shared, h)
            return x + out, (conv, st) + updated(new_bufs)

        x, outs = jax.lax.scan(
            stage, x, (stacked, conv_s, ssm_s) + attn_xs())
        new_cache.update(conv=re_flat(outs[0]), ssm=re_flat(outs[1]))
        store(outs[2:])

    elif cfg.family == "audio":
        def body(x, inp):
            lp = inp[0]
            bufs, rest = split_bufs(inp[1:])
            xk, xv = rest
            h = apply_norm(x, lp["norm1"], cfg.norm)
            a, new_bufs = _decode_attn(cfg, lp["attn"], h, bufs, pos,
                                       flushed)
            x = x + a
            h = apply_norm(x, lp["norm_x"], cfg.norm)
            x = x + _cross_attn_block(cfg, lp["xattn"], h, (xk, xv))
            h = apply_norm(x, lp["norm2"], cfg.norm)
            out, _ = _ffn(cfg, lp, h)
            return x + out, updated(new_bufs)
        x, outs = jax.lax.scan(
            body, x, (params["layers"],) + attn_xs((cache["xk"],
                                                    cache["xv"])))
        store(outs)

    else:  # dense / moe / vlm
        def body(x, inp):
            lp = inp[0]
            bufs, _ = split_bufs(inp[1:])
            h = apply_norm(x, lp["norm1"], cfg.norm)
            a, new_bufs = _decode_attn(cfg, lp["attn"], h, bufs, pos,
                                       flushed)
            x = x + a
            h = apply_norm(x, lp["norm2"], cfg.norm)
            out, _ = _ffn(cfg, lp, h)
            return x + out, updated(new_bufs)
        x, outs = jax.lax.scan(body, x, (params["layers"],) + attn_xs())
        store(outs)

    x = apply_norm(x, params["final_norm"], cfg.norm)
    logits = constrain((x @ params["lm_head"])[:, 0], "batch", "model")
    new_cache["len"] = pos + 1
    return logits, new_cache


def flush_recent(cfg: ArchConfig, cache: PyTree) -> PyTree:
    """Fold the full recent buffer into the main cache (full mode only).
    Called by the serving loop every DECODE_BUFFER tokens; this is the
    only op that writes the (possibly model-axis-sharded) main cache, so
    any resharding cost is amortized over DECODE_BUFFER decode steps."""
    if "kr" not in cache:
        return cache
    flushed = cache["flushed"]
    n_new = cache["len"] - flushed
    out = dict(cache)
    out["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], cache["kr"], flushed, axis=2)
    out["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], cache["vr"], flushed, axis=2)
    out["flushed"] = flushed + n_new
    return out


def _pack_prefill_attn(cfg: ArchConfig, k, v, S: int) -> dict:
    """Convert prefill-computed stacked k/v [Lc, B, S, H, hd] into the
    decode cache layout (ring-rolled for SWA archs; main+empty-recent for
    full attention)."""
    mode, size = _attn_cache_mode(cfg, S)
    if mode == "ring":
        W = size
        k = k[:, :, S - W:]
        v = v[:, :, S - W:]
        if S % W:
            # place absolute position p at slot p % W
            k = jnp.roll(k, S % W, axis=2)
            v = jnp.roll(v, S % W, axis=2)
        return {"k": k, "v": v}
    Lc, B = k.shape[0], k.shape[1]
    R = cfg.decode_buffer
    empty = jnp.zeros((Lc, B, R) + k.shape[3:], k.dtype)
    return {"k": k, "v": v, "kr": empty, "vr": empty,
            "flushed": jnp.asarray(S, jnp.int32)}


def lm_prefill(cfg: ArchConfig, params: PyTree, tokens, frames=None):
    """Prefill: forward over the prompt, building the decode cache.
    Returns (last-token logits [B, V], cache)."""
    B, S = tokens.shape
    x = _embed(cfg, params, tokens)
    positions = jnp.arange(S)[None, :].repeat(B, 0)
    window = _effective_window(cfg, S)
    cache: dict = {"len": jnp.asarray(S, jnp.int32)}

    if cfg.family == "ssm":
        def body(x, lp):
            x = constrain(x, "batch", None, None)
            h = apply_norm(x, lp["norm1"], cfg.norm)
            y, conv, st = _ssm_prefill_block(cfg, lp["ssm"], h)
            return x + y, (conv, st)
        x, (conv, st) = jax.lax.scan(body, x, params["layers"])
        cache.update(conv=conv, ssm=st)

    elif cfg.family == "hybrid":
        n_stages = cfg.n_layers // cfg.attn_every
        stacked = jax.tree.map(
            lambda a: a.reshape((n_stages, cfg.attn_every) + a.shape[1:]),
            params["layers"])
        shared = params["shared"]

        def stage(x, sp):
            def inner(x, lp):
                x = constrain(x, "batch", None, None)
                h = apply_norm(x, lp["norm1"], cfg.norm)
                y, conv, st = _ssm_prefill_block(cfg, lp["ssm"], h)
                return x + y, (conv, st)
            x, (conv, st) = jax.lax.scan(inner, x, sp)
            h = apply_norm(x, shared["norm1"], cfg.norm)
            a, (k, v) = _attn_block(cfg, shared["attn"], h, positions,
                                    window=window, return_kv=True)
            x = x + a
            h = apply_norm(x, shared["norm2"], cfg.norm)
            out, _ = _ffn(cfg, shared, h)
            return x + out, (conv, st, k, v)

        x, (conv, st, k, v) = jax.lax.scan(stage, x, stacked)
        cache.update(
            conv=jax.tree.map(lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), conv),
            ssm=jax.tree.map(lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), st))
        cache.update(_pack_prefill_attn(cfg, k, v, S))

    elif cfg.family == "audio":
        enc_out = _run_encoder(cfg, params, frames)

        def body(x, lp):
            x = constrain(x, "batch", None, None)
            kv = _encode_cross_kv(cfg, lp["xattn"], enc_out)
            h = apply_norm(x, lp["norm1"], cfg.norm)
            a, (k, v) = _attn_block(cfg, lp["attn"], h, positions,
                                    window=window, return_kv=True)
            x = x + a
            h = apply_norm(x, lp["norm_x"], cfg.norm)
            x = x + _cross_attn_block(cfg, lp["xattn"], h, kv)
            h = apply_norm(x, lp["norm2"], cfg.norm)
            out, _ = _ffn(cfg, lp, h)
            return x + out, (k, v, kv[0], kv[1])

        x, (k, v, xk, xv) = jax.lax.scan(body, x, params["layers"])
        cache.update(_pack_prefill_attn(cfg, k, v, S))
        cache.update(xk=xk, xv=xv)

    else:
        def body(x, lp):
            x = constrain(x, "batch", None, None)
            h = apply_norm(x, lp["norm1"], cfg.norm)
            a, (k, v) = _attn_block(cfg, lp["attn"], h, positions,
                                    window=window, return_kv=True)
            x = x + a
            h = apply_norm(x, lp["norm2"], cfg.norm)
            out, _ = _ffn(cfg, lp, h)
            return x + out, (k, v)
        x, (k, v) = jax.lax.scan(body, x, params["layers"])
        cache.update(_pack_prefill_attn(cfg, k, v, S))

    x = apply_norm(x[:, -1:], params["final_norm"], cfg.norm)
    logits = constrain((x @ params["lm_head"])[:, 0], "batch", "model")
    return logits, cache


def _ssm_prefill_block(cfg: ArchConfig, p, x):
    """Like mamba2_apply but also returns (conv_state, ssm_state)."""
    Bsz, L, D = x.shape
    d_inner = cfg.d_inner
    H, N = cfg.ssm_heads, cfg.ssm_state

    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N:]
    conv_state = xBC[:, -(cfg.ssm_conv - 1):, :]
    xBC = ssm_mod.silu(ssm_mod.causal_conv1d(xBC, p["conv_w"], p["conv_b"]))
    xs = xBC[..., :d_inner].reshape(Bsz, L, H, cfg.ssm_head_dim)
    B_ = xBC[..., d_inner:d_inner + N]
    C_ = xBC[..., d_inner + N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt = jnp.clip(dt, 1e-4, 1e2)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = dt * A[None, None, :]
    xd = xs * dt[..., None].astype(xs.dtype)
    y, final_state = ssm_mod.ssd_chunked(xd, a, B_, C_, chunk=cfg.ssm_chunk)
    y = y + xs * p["D"][None, None, :, None]
    y = y.reshape(Bsz, L, d_inner)
    y = rms_norm(y * ssm_mod.silu(z), p["norm_w"])
    return y @ p["out_proj"], conv_state, final_state

"""Activation sharding constraints (logical-axis layer).

Model code calls ``constrain(x, "batch", None, "model")`` with *logical*
axes; the launch layer installs a context mapping logical -> mesh axes
before tracing. Without a context (CPU smoke tests, single-device
examples) it is a no-op, so model code is mesh-agnostic.

This is required because sharding propagation alone picks degenerate
layouts here: the embedding table is (vocab='model', d_model='data')
sharded, and the gather output's d_model sharding beats the batch
sharding of the token operand — everything downstream ends up
batch-replicated. Constraining the block inputs/outputs pins the
batch axis (observed: 57 GiB -> ~2 GiB temp per chip on mamba2 train).
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX = threading.local()


def set_context(mesh, batch_axes) -> None:
    _CTX.mesh = mesh
    _CTX.batch = batch_axes


def clear_context() -> None:
    _CTX.mesh = None
    _CTX.batch = None


@contextlib.contextmanager
def sharding_context(mesh, batch_axes):
    set_context(mesh, batch_axes)
    try:
        yield
    finally:
        clear_context()


def _resolve(axis, mesh_axes):
    if axis == "batch":
        return getattr(_CTX, "batch", None)
    if axis is None:
        return None
    # plain mesh axis name; drop if the mesh lacks it
    return axis if axis in mesh_axes else None


def constrain(x, *axes):
    """x with a with_sharding_constraint if a context is installed."""
    mesh = getattr(_CTX, "mesh", None)
    if mesh is None:
        return x
    if x.ndim != len(axes):
        raise ValueError(f"rank mismatch: {x.shape} vs axes {axes}")
    names = set(mesh.axis_names)
    spec = P(*[_resolve(a, names) for a in axes])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

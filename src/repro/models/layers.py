"""Shared building blocks: norms, rotary embeddings, activations, inits."""

from __future__ import annotations

import jax
import jax.numpy as jnp


# -------------------------------------------------------------------------
# Initializers (truncated-normal-free, deterministic, split-by-path)
# -------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    """LeCun-normal-ish init: std = scale / sqrt(fan_in)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = (scale if scale is not None else 1.0) / (fan_in ** 0.5)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype, std: float = 0.02):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# -------------------------------------------------------------------------
# Norms
# -------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * weight.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dtype)


def apply_norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rms_norm(x, p["w"])
    return layer_norm(x, p["w"], p.get("b"))


def norm_param(kind: str, dim: int, dtype):
    if kind == "rmsnorm":
        return {"w": jnp.ones((dim,), dtype)}
    return {"w": jnp.ones((dim,), dtype), "b": jnp.zeros((dim,), dtype)}


# -------------------------------------------------------------------------
# Activations
# -------------------------------------------------------------------------

def silu(x):
    return x * jax.nn.sigmoid(x)


def relu2(x):
    """Squared ReLU (nemotron-4)."""
    r = jnp.maximum(x, 0.0)
    return r * r


ACTIVATIONS = {
    "silu": silu,
    "gelu": jax.nn.gelu,
    "relu2": relu2,
}


# -------------------------------------------------------------------------
# Rotary position embeddings
# -------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 1e4):
    """[head_dim // 2] inverse frequencies."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x, positions, theta: float = 1e4):
    """x: [..., seq, heads, head_dim]; positions: [..., seq] int32.

    Rotates pairs (x[2i], x[2i+1]) — the interleaved convention.
    """
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    """Pad vocab to a shardable multiple (standard practice; logits over
    padding ids are masked at the loss)."""
    return ((vocab + multiple - 1) // multiple) * multiple

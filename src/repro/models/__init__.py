"""Model zoo: the paper's LSTM predictor plus the assigned transformer
architectures (dense GQA, MoE, Mamba2 SSM, hybrid, enc-dec, early-fusion
VLM), all functional (params as pytrees) and scan-over-layers for
compile-time control.
"""

from repro.models.rnn import RNNConfig, init_rnn, rnn_apply
from repro.models.model_zoo import build_model

__all__ = ["RNNConfig", "build_model", "init_rnn", "rnn_apply"]

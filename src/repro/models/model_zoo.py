"""build_model(cfg): uniform functional handle over every architecture."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.configs.base import ArchConfig
from repro.models import transformer as tfm

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable            # (key) -> params
    forward: Callable         # (params, tokens, frames=None) -> (logits, aux)
    loss: Callable            # (params, tokens, frames=None) -> scalar
    prefill: Callable         # (params, tokens, frames=None) -> (logits, cache)
    decode_step: Callable     # (params, token, cache) -> (logits, cache)
    init_cache: Callable      # (batch, max_len) -> cache


def build_model(cfg: ArchConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: tfm.init_lm(cfg, key),
        forward=lambda p, t, frames=None: tfm.lm_forward(cfg, p, t, frames),
        loss=lambda p, t, frames=None: tfm.lm_loss(cfg, p, t, frames),
        prefill=lambda p, t, frames=None: tfm.lm_prefill(cfg, p, t, frames),
        decode_step=lambda p, tok, cache: tfm.lm_decode_step(cfg, p, tok, cache),
        init_cache=lambda batch, max_len: tfm.init_cache(cfg, batch, max_len),
    )

"""GQA attention: blocked (flash-style, memory O(S·block)) training path,
single-step decode against a KV cache, sliding-window masking, and
cross-attention (enc-dec).

The blocked path is the pure-JAX twin of ``repro.kernels.attention``
(Pallas); both share the online-softmax algorithm so the Pallas kernel can
be validated against this implementation, and dry-run memory analysis
never sees an S x S score tensor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pad_to(x, axis: int, multiple: int):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def blocked_attention(q, k, v, *, causal: bool = True,
                      window: int | None = None,
                      q_block: int = 512, kv_block: int = 1024,
                      q_offset: int = 0):
    """Online-softmax attention.

    Args:
        q: [B, Sq, Hq, D]
        k, v: [B, Skv, Hkv, D] — Hq % Hkv == 0 (GQA).
        causal: apply causal mask (query position = q_offset + index).
        window: sliding-window size (keys within [pos-window+1, pos]).
        q_offset: absolute position of q[0] (for decode/chunked prefill).

    Returns [B, Sq, Hq, D] in q.dtype.
    """
    orig_dtype = q.dtype
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5

    q, Sq0 = _pad_to(q, 1, q_block)
    k, Skv0 = _pad_to(k, 1, kv_block)
    v, _ = _pad_to(v, 1, kv_block)
    Sq_p, Skv_p = q.shape[1], k.shape[1]
    nq, nk = Sq_p // q_block, Skv_p // kv_block

    # [nq, B, qb, Hkv, G, D]
    qb = q.reshape(B, nq, q_block, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, kv_block, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kv_block, Hkv, D).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(Sq_p).reshape(nq, q_block)
    k_pos = jnp.arange(Skv_p).reshape(nk, kv_block)

    def q_step(_, qi):
        q_i, qpos_i = qi

        def kv_step(carry, ki):
            m, l, acc = carry
            k_j, v_j, kpos_j = ki
            # scores: [B, qb, Hkv, G, kvb]
            s = jnp.einsum("bqhgd,bkhd->bqhgk", q_i.astype(jnp.float32),
                           k_j.astype(jnp.float32)) * scale
            mask = kpos_j[None, :] <= qpos_i[:, None] if causal else \
                jnp.ones((q_block, kv_block), bool)
            if window is not None:
                mask = mask & (kpos_j[None, :] > qpos_i[:, None] - window)
            # mask out kv padding
            mask = mask & (kpos_j < Skv0)[None, :]
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            # §Perf: the probability tile is the largest attention tensor
            # (B*H*S^2); storing it in the compute dtype (bf16) halves its
            # HBM traffic while the accumulator stays f32 on the MXU.
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, q_block, Hkv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_block, Hkv, G), jnp.float32)
        a0 = jnp.zeros((B, q_block, Hkv, G, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kb, vb, k_pos))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(orig_dtype)

    _, out = jax.lax.scan(q_step, None, (qb, q_pos))
    # [nq, B, qb, Hkv, G, D] -> [B, Sq, Hq, D]
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq_p, Hq, D)
    return out[:, :Sq0]


def decode_attention(q, sources):
    """Single-token decode attention over one or more KV sources.

    Serving design (DESIGN.md §5): the big prompt cache ("main") is
    READ-ONLY and can be sharded any way (seq or heads on the model axis)
    because decode never writes it; new tokens land in a small replicated
    ring/"recent" buffer via a clean dynamic-update-slice. Attention
    merges the sources with a shared softmax (single max/denominator),
    which never concatenates differently-sharded buffers.

    Args:
        q: [B, 1, Hq, D] (RoPE already applied).
        sources: list of (k, v, valid_len) with k, v [B, Sk, Hkv, D] and
            valid_len an int32 scalar (entries [0, valid_len) attend).

    Returns [B, 1, Hq, D].
    """
    B, _, Hq, D = q.shape
    Hkv = sources[0][0].shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    # §Perf: keep k/v in their storage dtype and let the MXU accumulate in
    # f32 (preferred_element_type); a wholesale .astype(f32) on the cache
    # makes XLA hoist an f32 copy of the ENTIRE stacked cache out of the
    # layer scan (observed: +13 GiB on qwen1.5 decode_32k).
    kdt = sources[0][0].dtype
    qh = q[:, 0].reshape(B, Hkv, G, D).astype(kdt)

    scores = []
    for k, v, valid_len in sources:
        s = jnp.einsum("bhgd,bkhd->bhgk", qh, k,
                       preferred_element_type=jnp.float32) * scale
        valid = jnp.arange(k.shape[1]) < valid_len
        scores.append(jnp.where(valid[None, None, None, :], s, NEG_INF))

    m = scores[0].max(axis=-1)
    for s in scores[1:]:
        m = jnp.maximum(m, s.max(axis=-1))
    denom = jnp.zeros_like(m)
    out = jnp.zeros((B, Hkv, G, D), jnp.float32)
    for s, (k, v, _) in zip(scores, sources):
        p = jnp.exp(s - m[..., None])
        denom = denom + p.sum(axis=-1)
        out = out + jnp.einsum("bhgk,bkhd->bhgd", p.astype(kdt), v,
                               preferred_element_type=jnp.float32)
    out = out / jnp.maximum(denom[..., None], 1e-30)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def reference_attention(q, k, v, *, causal: bool = True,
                        window: int | None = None, q_offset: int = 0):
    """Naive O(S^2) oracle — tests only."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    Skv = k.shape[1]
    qh = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qh.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)

"""SGD(+momentum), Adam, gradient clipping — pure pytree transforms.

Built from scratch (the container ships no optax). Conventions:
- ``update`` returns the *step to subtract*: new_params = params - updates.
- ``lr`` is passed at update time so the paper's diminishing step-size
  schedule (eta_i = eta0 / (1 + beta sqrt(t))) can be driven externally,
  per communication round, without rebuilding optimizer state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple[PyTree, PyTree]]
    name: str = "optimizer"


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.asarray(0.0)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p - u.astype(p.dtype)), params, updates)


# --------------------------------------------------------------------------
# SGD (+ momentum, + weight decay) — the paper's base optimizer.
# --------------------------------------------------------------------------

class SGDState(NamedTuple):
    momentum: PyTree


def sgd(momentum: float = 0.0, weight_decay: float = 0.0,
        clip_norm: float | None = None) -> Optimizer:
    def init(params: PyTree) -> SGDState:
        if momentum == 0.0:
            return SGDState(momentum=None)
        return SGDState(momentum=jax.tree.map(jnp.zeros_like, params))

    def update(grads: PyTree, state: SGDState, params: PyTree,
               lr) -> tuple[PyTree, SGDState]:
        if clip_norm is not None:
            grads = clip_by_global_norm(grads, clip_norm)
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p.astype(g.dtype),
                                 grads, params)
        if momentum == 0.0:
            updates = jax.tree.map(lambda g: lr * g, grads)
            return updates, state
        new_m = jax.tree.map(lambda m, g: momentum * m + g, state.momentum, grads)
        updates = jax.tree.map(lambda m: lr * m, new_m)
        return updates, SGDState(momentum=new_m)

    return Optimizer(init=init, update=update, name="sgd")


# --------------------------------------------------------------------------
# Adam — used for the transformer-zoo training paths.
# --------------------------------------------------------------------------

class AdamState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, clip_norm: float | None = None,
         moment_dtype=jnp.float32) -> Optimizer:
    """moment_dtype: storage dtype for mu/nu. bf16 moments halve optimizer
    HBM (the lever that fits qwen3-moe-235b's 2.35 TB state on one pod);
    the update math still runs in f32."""
    def init(params: PyTree) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=jax.tree.map(zeros, params),
                         nu=jax.tree.map(zeros, params))

    def update(grads: PyTree, state: AdamState, params: PyTree,
               lr) -> tuple[PyTree, AdamState]:
        if clip_norm is not None:
            grads = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32)
                          + (1 - b1) * g).astype(moment_dtype),
            state.mu, g32)
        nu = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32)
                          + (1 - b2) * jnp.square(g)).astype(moment_dtype),
            state.nu, g32)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        def _upd(m, v, p):
            mhat = m.astype(jnp.float32) / bc1
            vhat = v.astype(jnp.float32) / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return lr * u
        updates = jax.tree.map(_upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update, name="adam")

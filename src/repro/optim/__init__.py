"""Optimizers as pure pytree transforms (no optax in this container).

Each optimizer is a pair of pure functions:
    init(params) -> opt_state
    update(grads, opt_state, params, lr) -> (updates, opt_state)
apply with ``apply_updates(params, updates)`` (updates are *subtracted*).
"""

from repro.optim.optimizers import (
    Optimizer,
    adam,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    sgd,
)

__all__ = [
    "Optimizer",
    "adam",
    "apply_updates",
    "clip_by_global_norm",
    "global_norm",
    "sgd",
]

"""Public wrapper: [B, S, H, D] GQA flash attention with padding."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.attention.kernel import flash_attention_pallas


def _on_tpu() -> bool:
    # trace-time, not import-time: see repro.kernels.lstm.ops._on_tpu
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "q_offset"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 128, block_k: int = 128,
                    q_offset: int = 0):
    """q [B, Sq, Hq, D]; k, v [B, Skv, Hkv, D] -> [B, Sq, Hq, D]."""
    B, Sq, Hq, D = q.shape
    Hkv, Skv = k.shape[2], k.shape[1]
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    qf = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kf = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vf = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    # fold to [B*H, S, D]
    qf = qf.transpose(0, 2, 1, 3).reshape(B * Hq, Sq + pad_q, D)
    kf = kf.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv + pad_k, D)
    vf = vf.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv + pad_k, D)
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, window=window, block_q=block_q,
        block_k=block_k, q_offset=q_offset, kv_valid=Skv,
        interpret=not _on_tpu())
    out = out.reshape(B, Hq, Sq + pad_q, D).transpose(0, 2, 1, 3)
    return out[:, :Sq]

from repro.kernels.attention.ops import flash_attention

__all__ = ["flash_attention"]

"""Flash-style blocked attention kernel (TPU).

Design (DESIGN.md §6): grid = (batch*q_heads, num_q_blocks, num_kv_blocks)
with the kv dimension innermost and marked "arbitrary" (sequential) —
running max / denominator / accumulator live in VMEM scratch across kv
steps, so the S x S score matrix never exists: per step only a
[block_q, block_k] tile is materialized, MXU-shaped (multiples of 128
for paper-scale head dims).

GQA without materializing repeated K/V: the kv BlockSpec index_map folds
the query-head -> kv-head mapping (h_kv = h_q // group), so K/V stream
from HBM once per kv head group.

Causal + sliding-window masking is positional; fully-masked kv blocks are
skipped with ``pl.when`` (the compiler elides the DMA for untouched
blocks on the skipped steps' compute).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30
_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window, block_q: int, block_k: int,
                  sm_scale: float, q_offset: int, kv_valid: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_offset + iq * block_q
    k_start = ik * block_k
    # block-level skip: entirely above the diagonal / outside the window
    relevant = jnp.asarray(True)
    if causal:
        relevant &= k_start <= q_start + block_q - 1
    if window is not None:
        relevant &= k_start + block_k - 1 > q_start - window

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # [bq, D]
        k = k_ref[0].astype(jnp.float32)            # [bk, D]
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = k_pos < kv_valid
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[:, 0] = l_scr[:, 0] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_scr[:, 0] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, 0], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window=None,
                           block_q: int = 128, block_k: int = 128,
                           q_offset: int = 0, kv_valid=None,
                           interpret: bool = True):
    """q [BH, Sq, D] (batch*q_heads folded); k, v [BKV, Skv, D] with
    BKV = batch*kv_heads; group = BH // BKV. Sq % block_q == 0,
    Skv % block_k == 0 (wrapper pads). Returns [BH, Sq, D]."""
    BH, Sq, D = q.shape
    BKV, Skv, _ = k.shape
    group = BH // BKV
    if kv_valid is None:
        kv_valid = Skv
    grid = (BH, Sq // block_q, Skv // block_k)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, sm_scale=D ** -0.5, q_offset=q_offset,
        kv_valid=kv_valid)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            # GQA fold: query-head b maps to kv row b // group
            pl.BlockSpec((1, block_k, D),
                         lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, D), jnp.float32),   # accumulator
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)

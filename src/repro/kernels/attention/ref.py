"""Naive O(S^2) attention oracle (float32 accumulation), with GQA,
causal and sliding-window masking — the allclose target for the flash
kernel."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window=None,
                  q_offset: int = 0):
    """q [B, Sq, Hq, D]; k, v [B, Skv, Hkv, D]."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    Skv = k.shape[1]
    qh = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qh.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)

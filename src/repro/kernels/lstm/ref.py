"""Pure-jnp oracle for the fused LSTM cell — identical math to
``repro.models.rnn.lstm_cell`` (gates packed [i, f, g, o])."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lstm_cell_ref(x, h, c, wx, wh, b):
    gates = x @ wx + h @ wh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    g = jnp.tanh(g)
    o = jax.nn.sigmoid(o)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new

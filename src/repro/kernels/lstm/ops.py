"""Public wrapper: padding + jit around the fused LSTM cell kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.lstm.kernel import lstm_cell_pallas


def _on_tpu() -> bool:
    # resolved at TRACE time, not import time: the backend may be
    # configured (jax.config / env) after this module is imported, and a
    # stale import-time snapshot would run the kernel in interpret mode
    # on a real TPU (or worse, compiled mode off one)
    return jax.default_backend() == "tpu"


def lstm_cell_padded(x, h, c, wx, wh, b):
    """Drop-in fused version of ``repro.models.rnn.lstm_cell`` signature:
    (params dict unpacked) -> (h', c'). Pads batch to a sublane multiple
    and the input feature dim to 8. Un-jitted so the dispatch layer can
    inline it into larger programs; ``lstm_cell_fused`` below is the
    jitted standalone entry."""
    B, I = x.shape
    H = h.shape[-1]
    block_b = 8
    pad_b = (-B) % block_b
    pad_i = (-I) % 8
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0)))
        h = jnp.pad(h, ((0, pad_b), (0, 0)))
        c = jnp.pad(c, ((0, pad_b), (0, 0)))
    if pad_i:
        x = jnp.pad(x, ((0, 0), (0, pad_i)))
        wx = jnp.pad(wx, ((0, pad_i), (0, 0)))
    h_new, c_new = lstm_cell_pallas(x, h, c, wx, wh, b[None, :],
                                    block_b=block_b,
                                    interpret=not _on_tpu())
    return h_new[:B], c_new[:B]


lstm_cell_fused = jax.jit(lstm_cell_padded)

"""Fused LSTM cell kernel.

The paper's model is a 2-layer LSTM; per time step a naive implementation
issues two matmuls plus ~8 elementwise HBM round trips for the gate math.
This kernel keeps the [block_b, 4H] gate tile resident in VMEM: both gate
matmuls hit the MXU back-to-back and all gate nonlinearities + state
update fuse before a single store of (h', c').

Tiling: grid over batch blocks; weights [I, 4H] / [H, 4H] are loaded whole
per block (paper-scale H=64 → 4H=256 lanes, well inside VMEM; the wrapper
pads I and B to sublane multiples).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref,
                 h_out_ref, c_out_ref):
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    gates = (jnp.dot(x, wx_ref[...], preferred_element_type=jnp.float32)
             + jnp.dot(h, wh_ref[...], preferred_element_type=jnp.float32)
             + b_ref[...])
    H = h.shape[-1]
    i = jax.nn.sigmoid(gates[:, :H])
    f = jax.nn.sigmoid(gates[:, H:2 * H])
    g = jnp.tanh(gates[:, 2 * H:3 * H])
    o = jax.nn.sigmoid(gates[:, 3 * H:])
    c_new = f * c + i * g
    h_out_ref[...] = (o * jnp.tanh(c_new)).astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)


def lstm_cell_pallas(x, h, c, wx, wh, b2d, *, block_b: int = 8,
                     interpret: bool = True):
    """x [B, I]; h, c [B, H]; wx [I, 4H]; wh [H, 4H]; b2d [1, 4H].
    B % block_b == 0. Returns (h', c')."""
    B, I = x.shape
    H = h.shape[-1]
    assert B % block_b == 0
    grid = (B // block_b,)
    return pl.pallas_call(
        _lstm_kernel,
        out_shape=(jax.ShapeDtypeStruct((B, H), h.dtype),
                   jax.ShapeDtypeStruct((B, H), c.dtype)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, I), lambda i: (i, 0)),
            pl.BlockSpec((block_b, H), lambda i: (i, 0)),
            pl.BlockSpec((block_b, H), lambda i: (i, 0)),
            pl.BlockSpec((I, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((1, 4 * H), lambda i: (0, 0)),
        ],
        out_specs=(pl.BlockSpec((block_b, H), lambda i: (i, 0)),
                   pl.BlockSpec((block_b, H), lambda i: (i, 0))),
        interpret=interpret,
    )(x, h, c, wx, wh, b2d)

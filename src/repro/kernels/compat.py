"""Pallas API compatibility: jax renamed ``pltpu.TPUCompilerParams`` to
``pltpu.CompilerParams`` (jax >= 0.5); resolve whichever this jax has so
the kernels run on both sides of the rename."""

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

"""Benchmark-backed kernel dispatch: Pallas kernel vs XLA lowering,
resolved per (backend, op, shape) at TRACE time.

The repo ships two implementations of each hot op — a Pallas kernel
(``kernels/<op>/kernel.py``, compiled on TPU, interpret-mode elsewhere)
and the pure-jnp math XLA lowers itself. Which one is faster depends on
the backend and the shape: on CPU the Pallas path only exists in
interpret mode (orders of magnitude slower — it stays available as an
explicitly forced fallback for kernel debugging), while on TPU the
fused kernel wins once the batch fills a sublane tile. This module owns
that decision so every caller — train-time ``rnn_features``, serve-time
``step``/``replay``/``predict`` — resolves it the same way:

- ``resolve(op, batch=..., hidden=...)`` consults a rule table keyed by
  backend. The default table encodes what ``benchmarks/bench_kernels``
  measures (its ``dispatch`` phase re-measures both impls and
  ``--tune-out`` writes a fresh table).
- The table can be replaced wholesale: ``load_table(path)`` /
  ``save_table(path)`` round-trip JSON, and the ``REPRO_DISPATCH_TABLE``
  env var points at a tuned table to load lazily on first resolve.
- ``REPRO_KERNEL_IMPL=pallas|xla`` (or ``force(impl)``) overrides every
  rule — the kill switch when a tuned table turns out wrong in prod.

Resolution happens while tracing (shapes are static there, and
``jax.default_backend()`` reflects any backend configured after
import — same lesson as the trace-time ``_on_tpu`` fix in the op
wrappers), so a compiled program bakes in one implementation and the
choice costs nothing at run time.
"""

from __future__ import annotations

import json
import os
import threading

import jax

from repro.kernels.lstm.ops import lstm_cell_padded
from repro.kernels.lstm.ref import lstm_cell_ref

# rule table: op -> backend -> list of {min_batch, min_hidden, impl}
# rules, first match wins, no match -> "xla". Backends not listed fall
# back to the "default" entry. Floors (not ranges) keep the table tiny
# and monotone: bigger shapes only ever move TOWARD the fused kernel.
DEFAULT_TABLE: dict = {
    "lstm_cell": {
        # CPU: XLA everywhere. At micro shapes the interpret-mode kernel
        # can LOOK competitive (dispatch overhead dominates both — see
        # bench_kernels' dispatch phase), but it interprets the grid
        # python-side, so it falls off a cliff as shapes grow and is
        # never the right default off-TPU.
        "cpu": [],
        # TPU: one sublane tile (8 rows) amortizes the kernel's weight
        # loads; below that the XLA fusion is at parity or better
        "tpu": [{"min_batch": 8, "min_hidden": 8, "impl": "pallas"}],
        "default": [],
    },
}

# reentrant: set_rules resolves the active table while holding it
_lock = threading.RLock()
_table: dict | None = None          # lazy: env table loads on first use


def _active_table() -> dict:
    global _table
    if _table is None:
        with _lock:
            if _table is None:
                path = os.environ.get("REPRO_DISPATCH_TABLE")
                _table = _load(path) if path else _copy(DEFAULT_TABLE)
    return _table


def _copy(table: dict) -> dict:
    return {op: {bk: [dict(r) for r in rules]
                 for bk, rules in per_op.items()}
            for op, per_op in table.items()}


def _load(path: str) -> dict:
    with open(path) as f:
        loaded = json.load(f)
    table = _copy(DEFAULT_TABLE)
    for op, per_op in loaded.items():
        table.setdefault(op, {}).update(
            {bk: [dict(r) for r in rules] for bk, rules in per_op.items()})
    return table


def load_table(path: str) -> dict:
    """Replace the active table with ``path``'s JSON (merged over the
    defaults, so a tuned table may override just one backend)."""
    global _table
    with _lock:
        _table = _load(path)
    return _table


def save_table(path: str, table: dict | None = None) -> None:
    """Persist ``table`` (default: the active one) as JSON — the output
    of a ``bench_kernels --tune-out`` run."""
    with open(path, "w") as f:
        json.dump(table if table is not None else _active_table(), f,
                  indent=2, sort_keys=True)


def set_rules(op: str, backend: str, rules: list[dict]) -> None:
    """Install dispatch rules for (op, backend) — the programmatic
    re-tune hook (``bench_kernels`` uses it before ``save_table``)."""
    with _lock:
        _active_table().setdefault(op, {})[backend] = \
            [dict(r) for r in rules]


def reset_table() -> None:
    """Back to the built-in defaults (drops env/file/set_rules state)."""
    global _table
    with _lock:
        _table = None


def resolve(op: str, *, batch: int, hidden: int,
            backend: str | None = None) -> str:
    """Pick ``"pallas"`` or ``"xla"`` for ``op`` at this shape. Call
    while tracing: ``batch``/``hidden`` are static shapes there and the
    backend is read when the surrounding program traces, not at import.
    """
    forced = os.environ.get("REPRO_KERNEL_IMPL")
    if forced:
        if forced not in ("pallas", "xla"):
            raise ValueError(
                f"REPRO_KERNEL_IMPL={forced!r}: must be 'pallas' or 'xla'")
        return forced
    per_op = _active_table().get(op, {})
    if backend is None:
        backend = jax.default_backend()
    rules = per_op.get(backend, per_op.get("default", []))
    for rule in rules:
        if batch >= rule.get("min_batch", 0) \
                and hidden >= rule.get("min_hidden", 0):
            return rule["impl"]
    return "xla"


class force:
    """Context manager pinning every resolve to one impl (tests and
    kernel debugging): ``with dispatch.force("pallas"): ...``."""

    def __init__(self, impl: str):
        if impl not in ("pallas", "xla"):
            raise ValueError(f"impl must be 'pallas' or 'xla', got {impl!r}")
        self.impl = impl
        self._saved: str | None = None

    def __enter__(self) -> "force":
        self._saved = os.environ.get("REPRO_KERNEL_IMPL")
        os.environ["REPRO_KERNEL_IMPL"] = self.impl
        return self

    def __exit__(self, *exc) -> None:
        if self._saved is None:
            os.environ.pop("REPRO_KERNEL_IMPL", None)
        else:
            os.environ["REPRO_KERNEL_IMPL"] = self._saved


# -- dispatch accounting ----------------------------------------------------
#
# resolve() fires at TRACE time (once per compilation), so the dispatch
# COUNT has to be recorded where the compiled function is invoked — the
# forecaster calls record() right before each jitted-fn call. Counting
# is opt-in: with no collector installed, record() is a truthiness
# check and an immediate return.

_collectors: list["DispatchCounts"] = []


class DispatchCounts:
    """Per-(backend, op, impl, shape) invocation counts, collected while
    installed via ``counting()``. ``shape`` is the (batch, hidden) the
    caller dispatched at — the padded shape, i.e. what actually ran."""

    def __init__(self):
        self.counts: dict[tuple, int] = {}

    def add(self, key: tuple, n: int = 1) -> None:
        self.counts[key] = self.counts.get(key, 0) + n

    def total(self, op: str | None = None) -> int:
        return sum(n for (bk, o, impl, shape), n in self.counts.items()
                   if op is None or o == op)

    def by_op(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for (bk, op, impl, shape), n in self.counts.items():
            out[op] = out.get(op, 0) + n
        return out

    def __getitem__(self, op: str) -> int:
        return self.total(op)

    def __repr__(self) -> str:
        return f"DispatchCounts({self.counts!r})"


def record(op: str, *, batch: int, hidden: int, impl: str | None = None,
           kernel_op: str | None = None, n: int = 1) -> None:
    """Count one dispatch of a compiled ``op`` at (batch, hidden).
    ``impl`` defaults to what ``resolve`` picks for ``kernel_op`` (or
    ``op``) at this shape — resolved only when a collector is installed,
    so the inactive path stays a single truthiness check."""
    if not _collectors:
        return
    if impl is None:
        impl = resolve(kernel_op or op, batch=batch, hidden=hidden)
    key = (jax.default_backend(), op, impl, (batch, hidden))
    with _lock:
        for c in _collectors:
            c.add(key, n)


class counting:
    """Collect dispatch counts inside a ``with`` block::

        with dispatch.counting() as counts:
            engine.submit_step(...)          # ... flush ...
        assert counts["slots_generate"] == 1   # one fused dispatch
        assert counts["decode_many"] == 0      # no host gather/scatter

    This is how tier-1 proves the steady-state decode contract: each
    step flush is exactly one ``slots_generate`` dispatch over the
    device-resident slot state (``decode_many`` — the cache
    gather/scatter path — and per-session ``decode_step`` both stay
    zero; ``slots_insert`` fires only when a session enters a lane).
    Collectors nest (each sees every dispatch while installed)."""

    def __enter__(self) -> DispatchCounts:
        self._counts = DispatchCounts()
        with _lock:
            _collectors.append(self._counts)
        return self._counts

    def __exit__(self, *exc) -> None:
        with _lock:
            _collectors.remove(self._counts)


# -- dispatched ops ---------------------------------------------------------

def lstm_cell(x, h, c, wx, wh, b):
    """The dispatch-routed LSTM cell: x [B, I]; h, c [B, H]; gates
    packed [i, f, g, o]. Resolves Pallas-vs-XLA from the table at trace
    time; the XLA path is the exact expression ``repro.models.rnn``
    always used, so a "xla" resolution changes nothing numerically. The
    Pallas path shares ``ops.lstm_cell_padded`` (un-jitted, so it
    inlines into whatever program is tracing)."""
    if resolve("lstm_cell", batch=x.shape[0],
               hidden=h.shape[-1]) == "pallas":
        return lstm_cell_padded(x, h, c, wx, wh, b)
    return lstm_cell_ref(x, h, c, wx, wh, b)

from repro.kernels.evl.ops import evl_loss_fused

__all__ = ["evl_loss_fused"]

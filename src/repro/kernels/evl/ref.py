"""Pure-jnp oracle for the fused EVL kernel — paper eq. (6)."""

from __future__ import annotations

import jax.numpy as jnp


def evl_loss_ref(u, v, beta0: float, beta1: float, gamma: float = 2.0,
                 eps: float = 1e-7):
    """Elementwise EVL (no reduction). u, v: same shape, float32."""
    u = jnp.clip(u.astype(jnp.float32), eps, 1.0 - eps)
    v = v.astype(jnp.float32)
    w_pos = beta0 * jnp.power(jnp.maximum(1.0 - u / gamma, 1e-12), gamma)
    w_neg = beta1 * jnp.power(jnp.maximum(1.0 - (1.0 - u) / gamma, 1e-12),
                              gamma)
    return -w_pos * v * jnp.log(u) - w_neg * (1.0 - v) * jnp.log(1.0 - u)

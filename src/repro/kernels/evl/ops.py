"""Public wrapper: arbitrary-shape EVL via the Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.evl.kernel import LANES, evl_pallas


def _on_tpu() -> bool:
    # trace-time, not import-time: see repro.kernels.lstm.ops._on_tpu
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("beta0", "beta1", "gamma",
                                             "reduce"))
def evl_loss_fused(u, v, beta0: float, beta1: float, gamma: float = 2.0,
                   reduce: str = "mean"):
    """Drop-in fused version of ``repro.extreme.evl.evl_loss``."""
    shape = u.shape
    n = u.size
    rows = -(-n // LANES)                      # ceil
    pad_rows = (-rows) % 8
    total = (rows + pad_rows) * LANES
    u2 = jnp.zeros((total,), jnp.float32).at[:n].set(
        u.reshape(-1).astype(jnp.float32)).reshape(-1, LANES)
    # pad u with 0.5 so log() terms stay finite in the dead lanes
    u2 = u2.at[:].set(jnp.where(
        (jnp.arange(total) < n).reshape(-1, LANES), u2, 0.5))
    v2 = jnp.zeros((total,), jnp.float32).at[:n].set(
        v.reshape(-1).astype(jnp.float32)).reshape(-1, LANES)
    out = evl_pallas(u2, v2, beta0=beta0, beta1=beta1, gamma=gamma,
                     interpret=not _on_tpu())
    flat = out.reshape(-1)[:n]
    mask = jnp.ones((n,), jnp.float32)
    if reduce == "mean":
        return jnp.sum(flat * mask) / n
    if reduce == "sum":
        return jnp.sum(flat * mask)
    return flat.reshape(shape)

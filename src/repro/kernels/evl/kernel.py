"""Fused Extreme Value Loss kernel (paper eq. 6).

One VMEM-resident elementwise pass: clip + GEV penalty weights + weighted
BCE, fused so u never round-trips to HBM between the four stages. Tiles
are [block_rows, 128] (lane-aligned); the wrapper reshapes/pads flat
inputs into this layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _evl_kernel(u_ref, v_ref, o_ref, *, beta0: float, beta1: float,
                gamma: float, eps: float):
    u = jnp.clip(u_ref[...], eps, 1.0 - eps)
    v = v_ref[...]
    w_pos = beta0 * jnp.power(jnp.maximum(1.0 - u / gamma, 1e-12), gamma)
    w_neg = beta1 * jnp.power(jnp.maximum(1.0 - (1.0 - u) / gamma, 1e-12),
                              gamma)
    o_ref[...] = (-w_pos * v * jnp.log(u)
                  - w_neg * (1.0 - v) * jnp.log(1.0 - u))


def evl_pallas(u2d, v2d, *, beta0: float, beta1: float, gamma: float,
               eps: float = 1e-7, block_rows: int = 8,
               interpret: bool = True):
    """u2d, v2d: [R, 128] float32 with R % block_rows == 0."""
    R, L = u2d.shape
    assert L == LANES and R % block_rows == 0
    kernel = functools.partial(_evl_kernel, beta0=beta0, beta1=beta1,
                               gamma=gamma, eps=eps)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((R, L), jnp.float32),
        grid=(R // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, L), lambda i: (i, 0))] * 2,
        out_specs=pl.BlockSpec((block_rows, L), lambda i: (i, 0)),
        interpret=interpret,
    )(u2d, v2d)

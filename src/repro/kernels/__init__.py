"""Pallas TPU kernels for the workload's compute hot spots (DESIGN.md §6).

Each kernel package ships:
    kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
    ops.py    — jit'd public wrapper (interpret=True fallback on CPU)
    ref.py    — pure-jnp oracle used by the allclose test sweeps

``dispatch.py`` owns the Pallas-vs-XLA decision per (backend, op,
shape): a benchmark-backed rule table resolved at trace time, re-tunable
via ``bench_kernels --tune-out`` / ``REPRO_DISPATCH_TABLE`` and
overridable with ``REPRO_KERNEL_IMPL``. Model code calls the dispatched
ops (e.g. ``dispatch.lstm_cell``) so train and serve resolve alike.

Kernels:
    evl       — fused Extreme Value Loss (paper eq. 6)
    lstm      — fused LSTM cell (paper's 2-layer LSTM hot loop)
    attention — flash-style blocked attention w/ causal + sliding window
    ssd       — Mamba2 SSD chunk kernel (intra-chunk dual form)
"""

from repro.kernels.ssd.ops import ssd_chunk_fused, ssd_scan_fused

__all__ = ["ssd_chunk_fused", "ssd_scan_fused"]

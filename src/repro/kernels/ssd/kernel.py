"""Mamba2 SSD chunk kernel (TPU adaptation of the GPU SSD algorithm).

TPU rethink (DESIGN.md §6): the GPU implementation leans on warp-level
shuffles for the intra-chunk scan; on TPU we use the *dual* (quadratic-
in-chunk) form so the intra-chunk work is two MXU matmuls —
[K,N]x[N,K] score matrix and [K,K]x[K,P] mix — plus a VMEM-resident
decay mask built from a cumulative sum. The inter-chunk recurrence is a
sequential grid dimension carrying the [P, N] state in VMEM scratch.

Grid = (batch, heads, chunks); chunks is "arbitrary" (sequential), so the
state never round-trips to HBM between chunks — it is written out once at
the last chunk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _ssd_kernel(xd_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_scr, *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xd = xd_ref[0, :, 0, :].astype(jnp.float32)       # [K, P]
    a = a_ref[0, :, 0].astype(jnp.float32)            # [K]
    B_ = b_ref[0].astype(jnp.float32)                 # [K, N]
    C_ = c_ref[0].astype(jnp.float32)                 # [K, N]
    state = state_scr[...]                            # [P, N]

    cum = jnp.cumsum(a)                               # [K]
    d = cum[:, None] - cum[None, :]
    K = chunk
    mask = jax.lax.broadcasted_iota(jnp.int32, (K, K), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (K, K), 1)
    L = jnp.where(mask, jnp.exp(d), 0.0)

    scores = jnp.dot(C_, B_.T, preferred_element_type=jnp.float32)
    y = jnp.dot(scores * L, xd, preferred_element_type=jnp.float32)
    y = y + jnp.dot(C_, state.T,
                    preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]

    total = cum[-1]
    decay_k = jnp.exp(total - cum)
    new_state = state * jnp.exp(total) + jnp.dot(
        xd.T, B_ * decay_k[:, None], preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    state_scr[...] = new_state

    @pl.when(ic == nc - 1)
    def _done():
        state_out_ref[0, 0] = new_state.astype(state_out_ref.dtype)


def ssd_pallas(xd, a, B_, C_, *, chunk: int = 128, interpret: bool = True):
    """Full SSD scan via the chunk kernel.

    xd [B, L, H, P]; a [B, L, H]; B_, C_ [B, L, N]; L % chunk == 0.
    Returns (y [B, L, H, P], final_state [B, H, P, N]) — float32 state.
    """
    Bsz, L, H, P = xd.shape
    N = B_.shape[-1]
    assert L % chunk == 0
    nc = L // chunk
    grid = (Bsz, H, nc)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, state = pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((Bsz, L, H, P), xd.dtype),
                   jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xd, a, B_, C_)
    return y, state

"""Public wrappers for the SSD kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_chunk_ref


def _on_tpu() -> bool:
    # trace-time, not import-time: see repro.kernels.lstm.ops._on_tpu
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan_fused(xd, a, B_, C_, chunk: int = 128):
    """Drop-in fused version of ``repro.models.ssm.ssd_chunked`` (no
    initial state). Pads L to a chunk multiple."""
    Bsz, L, H, P = xd.shape
    pad = (-L) % chunk
    if pad:
        xd = jnp.pad(xd, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # pad a with 0 decay-log => exp(0)=1, but with zero x it is inert
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_pallas(xd, a, B_, C_, chunk=chunk,
                          interpret=not _on_tpu())
    return y[:, :L], state


def ssd_chunk_fused(xd, a, B_, C_, state):
    """Single-chunk single-(batch,head) entry point (tests)."""
    y, new_state = ssd_pallas(
        xd[None, :, None, :], a[None, :, None], B_[None], C_[None],
        chunk=xd.shape[0], interpret=not _on_tpu())
    # ssd_pallas starts from zero state; fold the provided state like the
    # reference does: y += C @ state^T * exp(cumsum a); state' folds decay.
    cum = jnp.cumsum(a)
    y0 = y[0, :, 0, :] + (C_ @ state.T) * jnp.exp(cum)[:, None]
    st = new_state[0, 0] + state * jnp.exp(cum[-1])
    return y0, st


ssd_chunk_ref = ssd_chunk_ref  # re-export for the test sweep

"""Pure-jnp oracle for the SSD chunk kernel: one chunk of the Mamba2
state-space-duality recurrence (same math as repro.models.ssm)."""

from __future__ import annotations

import jax.numpy as jnp


def ssd_chunk_ref(xd, a, B_, C_, state):
    """One chunk, single (batch, head) slice.

    xd [K, P] (dt-scaled inputs); a [K] (dt*A, negative); B_, C_ [K, N];
    state [P, N]. Returns (y [K, P], new_state [P, N]). All float32.
    """
    K = xd.shape[0]
    cum = jnp.cumsum(a)                                 # [K]
    d = cum[:, None] - cum[None, :]
    mask = jnp.tril(jnp.ones((K, K), bool))
    L = jnp.where(mask, jnp.exp(d), 0.0)                # [K, K]

    scores = C_ @ B_.T                                  # [K, K]
    y = (scores * L) @ xd                               # intra-chunk
    y = y + (C_ @ state.T) * jnp.exp(cum)[:, None]      # carried state

    total = cum[-1]
    decay_k = jnp.exp(total - cum)                      # [K]
    new_state = state * jnp.exp(total) + xd.T @ (B_ * decay_k[:, None])
    return y, new_state

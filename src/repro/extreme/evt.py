"""Extreme Value Theory — paper eqs. (2)-(4).

Generalized Extreme Value distribution (eq. 3):

    G(y) = exp(-(1 - y/gamma)^gamma)   gamma != 0, 1 - y/gamma > 0
    G(y) = exp(-exp(-y))               gamma == 0   (Gumbel)

Tail modeling (eq. 4):

    1 - F(y) ~ (1 - F(xi)) * [1 - log G((y - xi) / f(xi))],  y > xi
"""

from __future__ import annotations

import jax.numpy as jnp


def gev_log_cdf(y, gamma: float):
    """log G(y) for the GEV parameterization of eq. (3)."""
    y = jnp.asarray(y, jnp.float32)
    if gamma == 0.0:
        return -jnp.exp(-y)
    base = 1.0 - y / gamma
    # outside the support (base <= 0) the cdf saturates; clamp for safety.
    base = jnp.maximum(base, 1e-12)
    return -(base ** gamma)


def gev_cdf(y, gamma: float):
    return jnp.exp(gev_log_cdf(y, gamma))


def tail_probability(y, xi: float, scale: float, tail_at_xi: float,
                     gamma: float):
    """eq. (4): P(Y > y) for y > xi, using the GEV tail approximation.

    Args:
        y: query points (> xi for the approximation to be meaningful).
        xi: sufficiently large threshold.
        scale: the positive scale function value f(xi).
        tail_at_xi: empirical 1 - F(xi).
        gamma: extreme value index.
    """
    z = (jnp.asarray(y, jnp.float32) - xi) / scale
    return tail_at_xi * (1.0 - gev_log_cdf(z, gamma))


def fit_tail(y, q: float = 0.95) -> dict[str, float]:
    """Moment-style tail fit: pick xi at the q-quantile, scale as the mean
    excess over xi (exponential/Pareto-style estimator). Returns the
    parameters consumed by ``tail_probability``."""
    y = jnp.asarray(y, jnp.float32)
    xi = jnp.quantile(y, q)
    excess = jnp.where(y > xi, y - xi, 0.0)
    n_tail = jnp.maximum(jnp.sum(y > xi), 1)
    scale = jnp.sum(excess) / n_tail
    return {
        "xi": float(xi),
        "scale": float(jnp.maximum(scale, 1e-8)),
        "tail_at_xi": float(n_tail / y.size),
    }

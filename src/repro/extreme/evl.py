"""Extreme Value Loss — paper eq. (6).

    EVL(u_t) = -beta0 * [1 - u_t/gamma]^gamma       * v_t     * log(u_t)
               -beta1 * [1 - (1-u_t)/gamma]^gamma   * (1-v_t) * log(1-u_t)

where u_t in (0,1) is the predicted extreme-event indicator, v_t in {0,1}
is the binary ground-truth indicator for (right) extreme events, beta0 is
the proportion of normal events, beta1 the proportion of extreme events,
and gamma the extreme value index hyper-parameter.

Interpretation: beta0 >> beta1 in imbalanced data, so misclassifying an
extreme event as normal (v=1, u small) is weighted by the *large* beta0,
and the GEV-derived factor [1 - u/gamma]^gamma further amplifies
low-confidence extreme detections — the tail-distribution-aware reweighted
binary cross entropy.

A fused Pallas kernel of this loss lives in ``repro.kernels.evl``; this
module is the reference implementation used by default on CPU.
"""

from __future__ import annotations

import jax.numpy as jnp


def evl_weights(u, v, beta0: float, beta1: float, gamma: float = 2.0):
    """The two GEV penalty weights of eq. (6) (before the log terms)."""
    u = jnp.asarray(u, jnp.float32)
    w_pos = beta0 * jnp.power(jnp.maximum(1.0 - u / gamma, 1e-12), gamma)
    w_neg = beta1 * jnp.power(jnp.maximum(1.0 - (1.0 - u) / gamma, 1e-12), gamma)
    return w_pos, w_neg


def evl_loss(u, v, beta0: float, beta1: float, gamma: float = 2.0,
             eps: float = 1e-7, reduce: str = "mean"):
    """eq. (6). ``u``: predicted probability in (0,1); ``v``: {0,1} labels.

    Note the sign convention follows [2]: beta0 (normal-event proportion,
    the large number) multiplies the positive-class term so that missed
    extremes are heavily penalized.
    """
    u = jnp.clip(jnp.asarray(u, jnp.float32), eps, 1.0 - eps)
    v = jnp.asarray(v, jnp.float32)
    w_pos, w_neg = evl_weights(u, v, beta0, beta1, gamma)
    loss = -w_pos * v * jnp.log(u) - w_neg * (1.0 - v) * jnp.log(1.0 - u)
    if reduce == "mean":
        return jnp.mean(loss)
    if reduce == "sum":
        return jnp.sum(loss)
    return loss


def bce_loss(u, v, eps: float = 1e-7, reduce: str = "mean"):
    """Plain binary cross entropy — the unweighted ablation of EVL."""
    u = jnp.clip(jnp.asarray(u, jnp.float32), eps, 1.0 - eps)
    v = jnp.asarray(v, jnp.float32)
    loss = -v * jnp.log(u) - (1.0 - v) * jnp.log(1.0 - u)
    if reduce == "mean":
        return jnp.mean(loss)
    if reduce == "sum":
        return jnp.sum(loss)
    return loss

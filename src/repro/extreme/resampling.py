"""Imbalanced-data handling strategies — the paper's sensitivity study
(§I, §IV.C task 1): which extreme-event modeling method works best under
distributed training?

Three strategies over sliding-window samples:

1. ``plain_windows``      — standard sliding-window sampling (risk:
                            underfitting on extremes; they are rare).
2. ``oversample_extreme`` — duplicate windows whose target is an extreme
                            event until they reach a target fraction
                            (the paper's "duplicate the extreme events to
                            break the imbalanced barrier"; risk: overfit).
3. ``evl_sample_weights`` — keep the sample distribution, reweight the
                            loss per-sample via EVL-style class weights.

All are deterministic given a seed (numpy RNG; data pipeline is host-side).
"""

from __future__ import annotations

import numpy as np

from repro.extreme.indicators import indicator_sequence


def plain_windows(n_windows: int, rng: np.random.Generator | None = None):
    """Identity sampling: every window once, order shuffled if rng given."""
    idx = np.arange(n_windows)
    if rng is not None:
        rng.shuffle(idx)
    return idx


def oversample_extreme_windows(targets: np.ndarray, eps1: float, eps2: float,
                               target_fraction: float = 0.3,
                               rng: np.random.Generator | None = None):
    """Return window indices with extreme-target windows duplicated until
    they make up ``target_fraction`` of the epoch (or all windows if the
    data has no extremes)."""
    v = np.asarray(indicator_sequence(targets, eps1, eps2))
    extreme = np.nonzero(v != 0)[0]
    normal = np.nonzero(v == 0)[0]
    if extreme.size == 0 or normal.size == 0:
        return plain_windows(len(targets), rng)
    # solve for duplication count d: d*E / (d*E + N) >= f
    f = target_fraction
    dup = max(1, int(np.ceil(f * normal.size / ((1 - f) * extreme.size))))
    idx = np.concatenate([normal] + [extreme] * dup)
    if rng is not None:
        rng.shuffle(idx)
    return idx


def evl_sample_weights(targets: np.ndarray, eps1: float, eps2: float,
                       gamma: float = 2.0) -> np.ndarray:
    """Per-window loss weights derived from event-class proportions:
    normal windows get beta1 (small), extreme windows beta0 (large) —
    the sampling-free counterpart of the EVL reweighting."""
    v = np.asarray(indicator_sequence(targets, eps1, eps2))
    beta0 = float(np.mean(v == 0))
    beta1 = float(np.mean(v != 0))
    beta1 = max(beta1, 1e-6)
    w = np.where(v != 0, beta0, beta1).astype(np.float32)
    # normalize to mean 1 so learning rates stay comparable across methods
    return w / max(w.mean(), 1e-12)


RESAMPLERS = {
    "plain": plain_windows,
    "oversample": oversample_extreme_windows,
    "evl": evl_sample_weights,
}

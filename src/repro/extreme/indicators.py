"""Auxiliary indicator sequence — paper eq. (1).

    v_t =  1   if y_t >  eps1          (right extreme event)
    v_t =  0   if y_t in [-eps2, eps1] (normal event)
    v_t = -1   if y_t < -eps2          (left extreme event)
"""

from __future__ import annotations

import jax.numpy as jnp


def indicator_sequence(y, eps1: float, eps2: float):
    """v_t per eq. (1). Thresholds must be positive."""
    if eps1 <= 0 or eps2 <= 0:
        raise ValueError("thresholds eps1, eps2 must be > 0")
    y = jnp.asarray(y)
    return jnp.where(y > eps1, 1, jnp.where(y < -eps2, -1, 0)).astype(jnp.int32)


def extreme_fractions(v) -> dict[str, float]:
    """beta_0 = P(v=0) (normal), P(v=1) (right), P(v=-1) (left) — the
    event-class proportions that weight the EVL (eq. 6)."""
    v = jnp.asarray(v)
    n = v.size
    return {
        "normal": float(jnp.sum(v == 0) / n),
        "right": float(jnp.sum(v == 1) / n),
        "left": float(jnp.sum(v == -1) / n),
    }


def quantile_thresholds(y, q: float = 0.95) -> tuple[float, float]:
    """Pick (eps1, eps2) from empirical tail quantiles — how the paper's
    reference [2] sets thresholds in practice."""
    y = jnp.asarray(y)
    eps1 = float(jnp.quantile(y, q))
    eps2 = float(-jnp.quantile(y, 1.0 - q))
    # Guard: thresholds must be positive (eq. 1 requires large positive
    # constants); degenerate data falls back to a small epsilon.
    return max(eps1, 1e-6), max(eps2, 1e-6)

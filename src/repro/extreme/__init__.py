"""Extreme-event modeling (paper §II.A, eqs. 1-6).

- ``indicators`` — auxiliary indicator sequence v_t (eq. 1).
- ``evt`` — Generalized Extreme Value distribution and tail modeling
  (eqs. 3-4).
- ``evl`` — Extreme Value Loss (eq. 6).
- ``resampling`` — imbalanced-data handling strategies compared in the
  paper's sensitivity study (plain sliding window, extreme oversampling,
  EVL loss weighting).
"""

from repro.extreme.indicators import extreme_fractions, indicator_sequence
from repro.extreme.evt import gev_cdf, gev_log_cdf, tail_probability
from repro.extreme.evl import evl_loss, evl_weights
from repro.extreme.resampling import (
    RESAMPLERS,
    evl_sample_weights,
    oversample_extreme_windows,
    plain_windows,
)

__all__ = [
    "RESAMPLERS",
    "evl_loss",
    "evl_sample_weights",
    "evl_weights",
    "extreme_fractions",
    "gev_cdf",
    "gev_log_cdf",
    "indicator_sequence",
    "oversample_extreme_windows",
    "plain_windows",
    "tail_probability",
]

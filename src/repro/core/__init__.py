"""The paper's primary contribution: asynchronous local SGD with linearly
increasing sample sequences and model-exchange aggregation.

Public API:
- ``SampleSchedule`` / ``ConstantSchedule`` / ``StepSizeSchedule`` — Table I.
- ``ConstantDelay`` / ``SqrtLogDelay`` / ``NetworkDelay`` — tau(t) models.
- ``AsyncLocalSGD`` (shard_map SPMD rounds) — production path.
- ``AsyncSimulator`` — event-driven faithful simulation of n async clients.
- ``sync_step`` — synchronous minibatch SGD baseline.
"""

from repro.core.schedules import (
    ConstantSchedule,
    SampleSchedule,
    StepSizeSchedule,
    communication_rounds_constant,
    round_step_sizes,
)
from repro.core.delay import ConstantDelay, NetworkDelay, SqrtLogDelay
from repro.core.async_local_sgd import (
    AsyncLocalSGD,
    LocalSGDConfig,
    local_sgd_round,
    sync_step,
)
from repro.core.simulator import AsyncSimulator, SimConfig

__all__ = [
    "AsyncLocalSGD",
    "AsyncSimulator",
    "ConstantDelay",
    "ConstantSchedule",
    "LocalSGDConfig",
    "NetworkDelay",
    "SampleSchedule",
    "SimConfig",
    "SqrtLogDelay",
    "StepSizeSchedule",
    "communication_rounds_constant",
    "local_sgd_round",
    "round_step_sizes",
    "sync_step",
]

"""Schedules from the paper (Table I / Remark 1).

- Sample-size sequence  s_i = a * i^p + b   (paper: a=10, p=1, b=0)
  s_i is the number of local SGD recursions executed *globally* in
  communication round i; each of n nodes runs ceil(s_i / n).
- Diminishing step size  eta_i = eta0 / (1 + beta * sqrt(t))
  where t is the cumulative number of SGD iterations before round i.

The key property (Remark 1): for K total gradient computations the number
of communication rounds T satisfies K = sum_{j<=T} s_j, so with linear s_i
T ~ sqrt(2K/a) instead of T ~ K/s for a constant schedule — the paper's
communication-cost reduction.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator


@dataclasses.dataclass(frozen=True)
class SampleSchedule:
    """s_i = a * i^p + b, with i the 1-based communication round index."""

    a: float = 10.0
    p: float = 1.0
    b: float = 0.0
    minimum: int = 1

    def round_size(self, i: int) -> int:
        if i < 1:
            raise ValueError(f"round index must be >= 1, got {i}")
        return max(self.minimum, int(self.a * (i ** self.p) + self.b))

    def cumulative(self, i: int) -> int:
        """Total SGD iterations completed after round i."""
        return sum(self.round_size(j) for j in range(1, i + 1))

    def rounds_for_budget(self, k: int) -> int:
        """Smallest T with cumulative(T) >= k (number of communication
        rounds needed for K gradient computations)."""
        total, i = 0, 0
        while total < k:
            i += 1
            total += self.round_size(i)
        return i

    def sizes_for_budget(self, k: int) -> list[int]:
        """Round sizes covering exactly k iterations (last round clipped)."""
        sizes: list[int] = []
        total, i = 0, 0
        while total < k:
            i += 1
            s = min(self.round_size(i), k - total)
            sizes.append(s)
            total += s
        return sizes


@dataclasses.dataclass(frozen=True)
class ConstantSchedule(SampleSchedule):
    """Constant-size local SGD (the classical local-SGD baseline [15])."""

    size: int = 10

    def round_size(self, i: int) -> int:  # noqa: D102
        if i < 1:
            raise ValueError(f"round index must be >= 1, got {i}")
        return max(self.minimum, int(self.size))


@dataclasses.dataclass(frozen=True)
class StepSizeSchedule:
    """eta(t) = eta0 / (1 + beta * sqrt(t)) — paper Table I."""

    eta0: float = 0.01
    beta: float = 0.01

    def __call__(self, t) -> float:
        # Works for python ints and jax arrays alike.
        return self.eta0 / (1.0 + self.beta * (t ** 0.5))


def round_step_sizes(schedule: SampleSchedule, stepsize: StepSizeSchedule,
                     num_rounds: int) -> Iterator[tuple[int, float]]:
    """Yield (s_i, eta_i) pairs; eta_i is evaluated at the cumulative
    iteration count at the *start* of round i (paper's bar-eta_i)."""
    t = 0
    for i in range(1, num_rounds + 1):
        s = schedule.round_size(i)
        yield s, stepsize(t)
        t += s


def communication_rounds_constant(k: int, s: int) -> int:
    """Rounds for constant schedule: ceil(K / s)."""
    return math.ceil(k / s)

"""Asynchronous local SGD — the paper's technique as a first-class
distributed-training feature (DESIGN.md §2).

Production mapping (SPMD, multi-pod): a *worker* ("compute node" in the
paper) is one pod (or any data-parallel group). Params carry a leading
worker dim [W, ...]; within a worker, gradients sync over the ``data``
mesh axis every step (standard data parallel), while **across workers no
collective runs during a round** — workers drift apart for H local steps,
then *models* (not gradients) are averaged, exactly the paper's exchange
scheme. With the linearly-increasing sample schedule (s_i = a·i^p + b)
the number of cross-worker communications for K total iterations drops
from O(K) to O(sqrt(K)) (Remark 1).

Staleness (Definition 1): with ``tau >= 1`` the round-r average is applied
at round r+tau ("delayed parameter averaging") — the worker keeps its
local delta accumulated since round r:

    w_w  <-  avg(w^{(r)}) + (w_w - w_w^{(r)})        at end of round r+tau

so the consumed model contains every global update up to round r = current
- tau, satisfying Definition 1 with tau(t) = tau. Inside a ``lax.scan``
over rounds the all-reduce result is consumed tau iterations later, which
lets XLA overlap the collective with local compute — the TPU-native form
of the paper's "asynchrony by design".

Exchange modes (paper §VI.(iii) + footnote **):
    "model"    — local updates, average models at round end (the paper's).
    "gradient" — average gradients every step (classic sync SGD); H is
                 forced to 1. Implemented for the paper's model-vs-gradient
                 comparison.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.schedules import SampleSchedule, StepSizeSchedule
from repro.optim.optimizers import Optimizer, apply_updates

PyTree = Any


@dataclasses.dataclass(frozen=True)
class LocalSGDConfig:
    n_workers: int = 2
    tau: int = 0                 # staleness (rounds); 0 = synchronous averaging
    exchange: str = "model"      # "model" | "gradient"
    schedule: SampleSchedule = SampleSchedule()   # s_i (global iterations)
    stepsize: StepSizeSchedule = StepSizeSchedule()

    def __post_init__(self):
        if self.exchange not in ("model", "gradient"):
            raise ValueError(f"unknown exchange mode {self.exchange!r}")
        if self.exchange == "gradient" and self.tau != 0:
            raise ValueError(
                "gradient exchange is synchronous SGD: every step is a "
                "collective, so delayed averaging (tau > 0) does not apply")


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def worker_mean(tree: PyTree) -> PyTree:
    """Average over the leading worker dim — the model exchange. Under
    pjit with the worker dim sharded on the 'pod' axis this lowers to one
    cross-pod all-reduce of the model."""
    return jax.tree.map(lambda a: jnp.mean(a, axis=0), tree)


def broadcast_to_workers(avg: PyTree, like: PyTree) -> PyTree:
    return jax.tree.map(
        lambda m, a: jnp.broadcast_to(m[None], a.shape).astype(a.dtype),
        avg, like)


def local_sgd_round(loss_fn: Callable, optimizer: Optimizer,
                    stacked_params: PyTree, stacked_opt: PyTree,
                    batches: PyTree, lr) -> tuple[PyTree, PyTree, jax.Array]:
    """One round: every worker runs H local steps, then models average.

    Args:
        loss_fn: (params, batch) -> scalar loss.
        stacked_params / stacked_opt: leading worker dim [W, ...].
        batches: pytree with leaves [W, H, ...] — worker-major microbatches.
        lr: scalar step size (bar-eta_i, constant within the round).

    Returns (new_stacked_params, new_stacked_opt, losses [W, H]).
    (The caller applies the averaging policy — sync or stale.)
    """
    def worker(params, opt_state, wbatches):
        def one_step(carry, batch):
            params, opt_state = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            updates, opt_state = optimizer.update(grads, opt_state, params, lr)
            params = apply_updates(params, updates)
            return (params, opt_state), loss

        (params, opt_state), losses = jax.lax.scan(
            one_step, (params, opt_state), wbatches)
        return params, opt_state, losses

    return jax.vmap(worker)(stacked_params, stacked_opt, batches)


def sync_step(loss_fn: Callable, optimizer: Optimizer,
              stacked_params: PyTree, stacked_opt: PyTree,
              batches: PyTree, lr, exchange: str = "gradient"):
    """Baseline synchronous step across workers.

    exchange="gradient": average worker gradients, then update the (shared)
    model — classic distributed SGD. exchange="model": update locally then
    average models (equivalent for plain SGD; differs under clipping /
    Adam, which is the paper's footnote-** comparison at H=1).
    """
    if exchange == "gradient":
        def worker_grad(params, batch):
            return jax.value_and_grad(loss_fn)(params, batch)
        losses, grads = jax.vmap(worker_grad)(stacked_params, batches)
        gavg = worker_mean(grads)
        params0 = jax.tree.map(lambda a: a[0], stacked_params)
        opt0 = jax.tree.map(lambda a: a[0], stacked_opt)
        updates, opt0 = optimizer.update(gavg, opt0, params0, lr)
        params0 = apply_updates(params0, updates)
        W = losses.shape[0]
        stacked_params = jax.tree.map(
            lambda m: jnp.broadcast_to(m[None], (W,) + m.shape), params0)
        stacked_opt = jax.tree.map(
            lambda m: jnp.broadcast_to(m[None], (W,) + m.shape), opt0)
        return stacked_params, stacked_opt, losses

    # model exchange at H=1
    batches1 = jax.tree.map(lambda b: b[:, None], batches)
    p, o, losses = local_sgd_round(loss_fn, optimizer, stacked_params,
                                   stacked_opt, batches1, lr)
    avg = worker_mean(p)
    return broadcast_to_workers(avg, p), o, losses[:, 0]


# --------------------------------------------------------------------------
# High-level trainer
# --------------------------------------------------------------------------

class AsyncLocalSGD:
    """Host-side round loop implementing the full technique: linearly
    increasing rounds, diminishing step size, model exchange, optional
    delayed (stale) averaging, and communication accounting."""

    def __init__(self, loss_fn: Callable, optimizer: Optimizer,
                 config: LocalSGDConfig):
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.cfg = config
        self._round = jax.jit(
            lambda p, o, b, lr: local_sgd_round(
                loss_fn, optimizer, p, o, b, lr))
        self._sync = jax.jit(
            lambda p, o, b, lr: sync_step(
                loss_fn, optimizer, p, o, b, lr, exchange="gradient"))
        # (avg, snapshot, round index the average was computed at)
        self._avg_queue: list[tuple[PyTree, PyTree, int]] = []
        # accounting
        self.rounds_done = 0
        self.iterations_done = 0
        self.communications = 0
        self.loss_history: list[float] = []
        # Definition 1 audit trail: (round applied at, round averaged at),
        # i.e. each entry asserts "round r consumed the round r - tau avg"
        self.consumed_rounds: list[tuple[int, int]] = []

    def init(self, params: PyTree) -> tuple[PyTree, PyTree]:
        W = self.cfg.n_workers
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (W,) + a.shape), params)
        opt = jax.vmap(self.optimizer.init)(stacked)
        return stacked, opt

    def local_steps_for_round(self, i: int) -> int:
        if self.cfg.exchange == "gradient":
            return 1             # paper footnote **: gradient exchange
            # communicates every iteration, so a "round" is one step
        s_i = self.cfg.schedule.round_size(i)
        return max(1, s_i // self.cfg.n_workers)

    def lr_for_round(self) -> float:
        return float(self.cfg.stepsize(self.iterations_done))

    def run_round(self, stacked_params: PyTree, stacked_opt: PyTree,
                  batches: PyTree) -> tuple[PyTree, PyTree, float]:
        """batches leaves: [W, H, ...] with H = local_steps_for_round(r+1)."""
        lr = self.lr_for_round()
        H = int(jax.tree_util.tree_leaves(batches)[0].shape[1])
        if self.cfg.exchange == "gradient":
            if H != 1:
                raise ValueError(
                    f"exchange='gradient' forces H == 1 (communicate every "
                    f"iteration); got a round of H = {H} local steps")
            batches1 = jax.tree.map(lambda b: b[:, 0], batches)
            p, o, losses = self._sync(stacked_params, stacked_opt,
                                      batches1, lr)
            self.iterations_done += self.cfg.n_workers
            self.rounds_done += 1
            self.communications += 1
            mean_loss = float(jnp.mean(losses))
            self.loss_history.append(mean_loss)
            return p, o, mean_loss
        p, o, losses = self._round(stacked_params, stacked_opt, batches, lr)
        self.iterations_done += H * self.cfg.n_workers
        self.rounds_done += 1
        self.communications += 1

        if self.cfg.tau == 0:
            avg = worker_mean(p)
            p = broadcast_to_workers(avg, p)
        else:
            # dispatch this round's average; apply the one from tau ago
            avg_now = worker_mean(p)
            snapshot = p
            self._avg_queue.append((avg_now, snapshot, self.rounds_done))
            if len(self._avg_queue) > self.cfg.tau:
                avg_old, snap_old, round_old = self._avg_queue.pop(0)
                p = jax.tree.map(
                    lambda a, w, s: (a[None] + (w - s)).astype(w.dtype),
                    avg_old, p, snap_old)
                self.consumed_rounds.append((self.rounds_done, round_old))
        mean_loss = float(jnp.mean(losses))
        self.loss_history.append(mean_loss)
        return p, o, mean_loss

    def model_bytes(self, params: PyTree) -> int:
        one = jax.tree.map(lambda a: a[0], params)
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(one))

    def communication_bytes(self, params: PyTree) -> int:
        """Total bytes exchanged so far (model up + model down per worker
        per round — the paper's communication-cost metric)."""
        return self.communications * 2 * self.cfg.n_workers * \
            self.model_bytes(params)

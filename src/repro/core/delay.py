"""Delay (staleness) models tau(t) — Definition 1 of the paper.

The paper's insight (via [25, 32]): asynchronous SGD tolerates delays up
to tau(t) ~ sqrt(t / ln t) for strongly convex problems, which is far
larger than network-induced delay — so extra asynchrony can be introduced
*by design* (e.g. overlapping the model exchange with further local
compute).

These models are used by the event-driven simulator (true per-client
staleness) and by the SPMD stale-averaging pipeline (constant tau).
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ConstantDelay:
    """tau(t) = tau — bounded staleness, the SPMD pipeline case."""

    tau: int = 1

    def __call__(self, t: int) -> int:
        return self.tau


@dataclasses.dataclass(frozen=True)
class SqrtLogDelay:
    """tau(t) = floor(c * sqrt(t / ln t)) — the theoretical tolerance
    envelope from [25, 32]; used to *cap* simulated staleness."""

    c: float = 1.0

    def __call__(self, t: int) -> int:
        if t < 3:
            return 1
        return max(1, int(self.c * math.sqrt(t / math.log(t))))


@dataclasses.dataclass(frozen=True)
class NetworkDelay:
    """Deterministic pseudo-random per-event delay in [lo, hi], modeling
    heterogeneous client/network latency in the simulator."""

    lo: int = 0
    hi: int = 2
    seed: int = 0

    def __call__(self, t: int) -> int:
        # splitmix64-style hash for determinism without global RNG state.
        z = (t + self.seed * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        z = z ^ (z >> 31)
        return self.lo + z % (self.hi - self.lo + 1)


def check_consistent(applied_round: int, current_round: int, tau: int) -> bool:
    """Definition 1: the model used at round r must include all updates up
    to round r - tau(r)."""
    return applied_round >= current_round - tau

"""Event-driven asynchronous distributed-learning simulator — the faithful
reproduction of the paper's experiment (§IV): n clients with heterogeneous
speeds run local SGD against a central server, exchanging *models*
asynchronously, with linearly increasing round sizes and diminishing step
sizes. Deterministic given seeds.

Server aggregation follows [27] (van Dijk et al., Algorithm 4): when a
client's round-r model arrives (possibly late), the server folds the
client's *delta* into the global model:

    w_global <- w_global + (w_client_end - w_client_start) / n

The client then pulls the current global model — which may already contain
other clients' newer contributions (bounded staleness; Definition 1 is
enforced by capping how far a client may run ahead, ``max_ahead``).

Virtual time: client c takes (iterations / speed_c) time units per round
plus network delays for upload/download; the server takes ``server_cost``
per aggregation (this produces the paper's speedup *saturation*,
Table II). Speedup = serial time of K iterations / parallel makespan.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.delay import NetworkDelay
from repro.core.schedules import SampleSchedule, StepSizeSchedule
from repro.optim.optimizers import Optimizer, apply_updates

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_clients: int = 2
    total_iterations: int = 2000          # K
    schedule: SampleSchedule = SampleSchedule()      # s_i
    stepsize: StepSizeSchedule = StepSizeSchedule()  # eta_i
    batch_size: int = 32
    heterogeneous_speeds: bool = True     # speeds in [0.5, 1.5]
    net_delay: tuple[float, float] = (0.01, 0.05)    # upload/download time
    server_cost: float = 0.05             # aggregation cost per arrival
    max_ahead: int = 2                    # staleness cap (Def. 1 bound)
    eval_every_rounds: int = 5
    seed: int = 0


@dataclasses.dataclass
class _Client:
    cid: int
    params: PyTree
    opt_state: PyTree
    pulled_params: PyTree     # snapshot at pull time (for delta aggregation)
    speed: float
    round_idx: int = 0        # global round counter at pull time
    iters_done: int = 0
    time: float = 0.0


class AsyncSimulator:
    """Runs the full async protocol in virtual time on real JAX steps."""

    def __init__(self, loss_fn: Callable, optimizer: Optimizer,
                 init_params: PyTree, data_per_client: list,
                 cfg: SimConfig, eval_fn: Callable | None = None):
        """data_per_client[c] -> callable (rng, n, batch) yielding stacked
        batches pytree with leaves [n, batch, ...]."""
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.cfg = cfg
        self.eval_fn = eval_fn
        self.global_params = init_params
        self.data_per_client = data_per_client
        self.rng = np.random.default_rng(cfg.seed)
        self.net = NetworkDelay(lo=0, hi=1, seed=cfg.seed)

        self._steps_cache: dict[int, Callable] = {}
        speeds = (np.linspace(0.5, 1.5, cfg.n_clients)
                  if cfg.heterogeneous_speeds and cfg.n_clients > 1
                  else np.ones(cfg.n_clients))
        self.clients = [
            _Client(cid=c, params=init_params, opt_state=optimizer.init(init_params),
                    pulled_params=init_params, speed=float(speeds[c]))
            for c in range(cfg.n_clients)]

        # accounting
        self.server_round = 0          # completed aggregations
        self.iterations = 0
        self.communications = 0
        self.makespan = 0.0
        self.staleness_log: list[int] = []
        self.eval_log: list[tuple[int, float]] = []   # (iterations, metric)

    # -- jitted multi-step local SGD (compiled once per distinct H) --------
    def _local_steps(self, h: int) -> Callable:
        if h not in self._steps_cache:
            loss_fn, opt = self.loss_fn, self.optimizer

            def run(params, opt_state, batches, lr):
                def one(carry, batch):
                    params, opt_state = carry
                    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
                    upd, opt_state = opt.update(grads, opt_state, params, lr)
                    return (apply_updates(params, upd), opt_state), loss
                (params, opt_state), losses = jax.lax.scan(
                    one, (params, opt_state), batches)
                return params, opt_state, jnp.mean(losses)

            self._steps_cache[h] = jax.jit(run)
        return self._steps_cache[h]

    def _round_size(self, i: int) -> int:
        s_i = self.cfg.schedule.round_size(i)
        return max(1, s_i // self.cfg.n_clients)

    def run(self) -> dict:
        cfg = self.cfg
        # event queue: (time, seq, client_id); seq breaks ties deterministically
        events = [(0.0, c, c) for c in range(cfg.n_clients)]
        heapq.heapify(events)
        seq = cfg.n_clients
        rounds_started = 0

        while events and self.iterations < cfg.total_iterations:
            t, _, cid = heapq.heappop(events)
            cl = self.clients[cid]

            # staleness guard (Definition 1 / bounded delay): a client may
            # not run more than max_ahead rounds past the slowest client.
            min_round = min(c.round_idx for c in self.clients)
            if cl.round_idx - min_round > cfg.max_ahead:
                # requeue after a small wait (client idles — this models
                # the bounded-delay constraint tau)
                heapq.heappush(events, (t + 0.1, seq, cid)); seq += 1
                continue

            rounds_started += 1
            round_i = rounds_started
            h = self._round_size(round_i)
            lr = float(cfg.stepsize(self.iterations))

            # local compute
            batches = self.data_per_client[cid](self.rng, h, cfg.batch_size)
            step = self._local_steps(h)
            new_params, new_opt, loss = step(cl.params, cl.opt_state,
                                             batches, lr)
            compute_time = h / cl.speed
            up = cfg.net_delay[0] + (cfg.net_delay[1] - cfg.net_delay[0]) * \
                (self.net(seq) / 1.0)
            arrive = t + compute_time + up

            # server aggregation (delta rule of [27])
            n = cfg.n_clients
            self.global_params = jax.tree.map(
                lambda g, e, s: g + (e - s) / n,
                self.global_params, new_params, cl.pulled_params)
            self.server_round += 1
            self.communications += 1
            self.iterations += h
            self.staleness_log.append(cl.round_idx - min_round)

            # client pulls the fresh global model, continues
            down = cfg.net_delay[0]
            finish = arrive + cfg.server_cost + down
            cl.params = self.global_params
            cl.pulled_params = self.global_params
            cl.opt_state = new_opt
            cl.round_idx += 1
            cl.iters_done += h
            cl.time = finish
            self.makespan = max(self.makespan, finish)

            if (self.eval_fn is not None
                    and self.server_round % cfg.eval_every_rounds == 0):
                self.eval_log.append(
                    (self.iterations, float(self.eval_fn(self.global_params))))

            heapq.heappush(events, (finish, seq, cid)); seq += 1

        if self.eval_fn is not None:
            self.eval_log.append(
                (self.iterations, float(self.eval_fn(self.global_params))))
        return self.summary()

    def summary(self) -> dict:
        cfg = self.cfg
        serial_time = cfg.total_iterations / 1.0   # unit-speed single node
        return {
            "n_clients": cfg.n_clients,
            "iterations": self.iterations,
            "communications": self.communications,
            "makespan": self.makespan,
            "speedup": serial_time / max(self.makespan, 1e-9),
            "mean_staleness": (float(np.mean(self.staleness_log))
                               if self.staleness_log else 0.0),
            "max_staleness": (int(np.max(self.staleness_log))
                              if self.staleness_log else 0),
            "eval_log": self.eval_log,
        }

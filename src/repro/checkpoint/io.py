"""Checkpointing: npz tensor store + msgpack metadata (no orbax offline).

Pytrees are flattened with '/'-joined key paths; arbitrary (non-array)
metadata rides along in a msgpack blob. Saves are crash-atomic: the
bytes are written to a tmp file, fsync'd, renamed over the target, and
the directory entry fsync'd — a crash mid-save can tear the tmp file
but never the checkpoint a later ``load_checkpoint`` trusts. Loads
raise ``CheckpointCorruptError`` (naming the path) on torn/truncated
files instead of leaking numpy zip internals.
"""

from __future__ import annotations

import os
import zipfile
from typing import Any

import jax
import msgpack
import numpy as np

PyTree = Any
_META_KEY = "__repro_meta__"
_DTYPES_KEY = "__dtypes__"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint file failed to parse — torn write, truncation, or
    not a checkpoint at all."""

# numpy's savez cannot serialize ml_dtypes (bfloat16 etc.); store them as
# a same-width unsigned view and record the true dtype in the metadata.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _flatten(tree: PyTree) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    dtypes: dict[str, str] = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name in _VIEW_AS:
            dtypes[key] = arr.dtype.name
            arr = arr.view(_VIEW_AS[arr.dtype.name])
        out[key] = arr
    return out, dtypes


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def _pack(tree: PyTree, metadata: dict | None) -> dict[str, np.ndarray]:
    flat, dtypes = _flatten(tree)
    blob = {_DTYPES_KEY: dtypes}
    if metadata is not None:
        blob["user"] = metadata
    flat[_META_KEY] = np.frombuffer(
        msgpack.packb(blob, use_bin_type=True), dtype=np.uint8)
    return flat


def _unpack(flat: dict[str, np.ndarray]
            ) -> tuple[dict[str, np.ndarray], dict | None]:
    import ml_dtypes

    meta = None
    dtypes: dict[str, str] = {}
    if _META_KEY in flat:
        blob = msgpack.unpackb(flat.pop(_META_KEY).tobytes(), raw=False)
        dtypes = blob.get(_DTYPES_KEY, {})
        meta = blob.get("user")
    for key, name in dtypes.items():
        if key in flat:
            flat[key] = flat[key].view(np.dtype(getattr(ml_dtypes, name)))
    return flat, meta


def save_checkpoint(path: str, tree: PyTree,
                    metadata: dict | None = None) -> None:
    flat = _pack(tree, metadata)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    np.savez(tmp, **flat)
    # np.savez appends .npz to the filename it is given
    tmp = tmp + ".npz" if not tmp.endswith(".npz") else tmp
    # fsync BEFORE the rename: os.replace is atomic in the namespace,
    # but renaming a file whose bytes are still in the page cache can
    # surface as a zero-length/torn target after a power cut — exactly
    # the torn-checkpoint a later load would otherwise trust
    fd = os.open(tmp, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)
    os.replace(tmp, path)
    dfd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
    try:
        os.fsync(dfd)          # persist the rename itself
    finally:
        os.close(dfd)


def dump_checkpoint_bytes(tree: PyTree,
                          metadata: dict | None = None) -> bytes:
    """The checkpoint as in-memory npz bytes — same format ``save_checkpoint``
    writes, for transports that move weights between processes instead
    of through the filesystem."""
    import io

    buf = io.BytesIO()
    np.savez(buf, **_pack(tree, metadata))
    return buf.getvalue()


def load_checkpoint(path: str, like: PyTree | None = None
                    ) -> tuple[PyTree | dict[str, np.ndarray], dict | None]:
    """Load a checkpoint. With ``like`` (a pytree of the target structure)
    the arrays are re-assembled into that structure; otherwise the flat
    {path: array} dict is returned. Returns (tree_or_flat, metadata).
    Raises ``CheckpointCorruptError`` on a torn/truncated file."""
    try:
        with np.load(path, allow_pickle=False) as z:
            flat = {k: z[k] for k in z.files}
    except (ValueError, OSError, EOFError, zipfile.BadZipFile) as e:
        raise CheckpointCorruptError(
            f"checkpoint {path!r} is corrupt or truncated "
            f"({type(e).__name__}: {e})") from e
    flat, meta = _unpack(flat)
    if like is None:
        return flat, meta
    return assemble(flat, like), meta


def load_checkpoint_bytes(data: bytes, like: PyTree | None = None
                          ) -> tuple[PyTree | dict[str, np.ndarray],
                                     dict | None]:
    """``load_checkpoint`` for in-memory npz bytes (the output of
    ``dump_checkpoint_bytes``). Raises ``CheckpointCorruptError`` on
    torn/truncated bytes."""
    import io

    try:
        with np.load(io.BytesIO(data), allow_pickle=False) as z:
            flat = {k: z[k] for k in z.files}
    except (ValueError, OSError, EOFError, zipfile.BadZipFile) as e:
        raise CheckpointCorruptError(
            f"checkpoint bytes ({len(data)}B) are corrupt or truncated "
            f"({type(e).__name__}: {e})") from e
    flat, meta = _unpack(flat)
    if like is None:
        return flat, meta
    return assemble(flat, like), meta


def assemble(flat: dict[str, np.ndarray], like: PyTree) -> PyTree:
    """Re-assemble a flat {path: array} dict (as returned by
    ``load_checkpoint`` without ``like``) into the structure of ``like`` —
    lets one file read serve both metadata inspection and tree loading."""
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths_and_leaves:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint {path!r} missing key {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {np.shape(leaf)}")
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)

from repro.checkpoint.io import (CheckpointCorruptError, load_checkpoint,
                                 save_checkpoint)

__all__ = ["CheckpointCorruptError", "load_checkpoint", "save_checkpoint"]

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) pair on
the production mesh and report memory / cost / collective analysis.

MUST be run as a module main (the XLA_FLAGS line above executes before any
jax import): ``PYTHONPATH=src python -m repro.launch.dryrun --arch
mixtral-8x7b --shape train_4k --mesh single``.

Flags:
    --arch       arch id or "all"
    --shape      shape id or "all"
    --mesh       single | multi | both
    --technique  also lower the paper's local-SGD round (multi-pod; H
                 local steps + cross-pod model exchange) with this H
    --out        append JSON-lines results to this path
"""

import argparse
import functools
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, INPUT_SHAPES, get_config
from repro.launch import specs as S
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import (make_production_mesh, mesh_axis_sizes,
                               n_chips)
from repro.launch.roofline import roofline_terms
from repro.launch.shardings import as_shardings, batch_axes
from repro.models.pshard import sharding_context
from jax.sharding import PartitionSpec as P


def skip_reason(cfg, shape) -> str | None:
    if shape.name == "long_500k":
        if cfg.family == "audio":
            return ("enc-dec ASR decoder: 500k-token autoregressive decode "
                    "not meaningful (DESIGN.md §4)")
    return None


def _analyses(lowered, compiled, pod_boundary=None, donated=False) -> dict:
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    # XLA's cost_analysis counts while bodies ONCE (verified); analyze_hlo
    # re-walks the partitioned HLO with trip-count multiplication.
    from repro.launch.hlo_analysis import HloCostModel
    hlo = HloCostModel(compiled.as_text(), pod_boundary=pod_boundary).totals()
    return {
        "flops_per_chip": float(hlo["flops"]),
        "bytes_per_chip": float(hlo["bytes"]),
        "collectives": hlo["collectives"],
        "collective_bytes_per_chip": float(sum(hlo["collectives"].values())),
        "cross_pod_collectives": hlo.get("cross_pod", {}),
        "cross_pod_bytes_per_chip": float(sum(hlo.get("cross_pod",
                                                      {}).values())),
        "xla_cost_analysis_raw": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": _memory_record(mem, donated),
    }


def _memory_record(mem, donated: bool) -> dict:
    """Live-bytes peak estimate. Without donation, arguments, temps and
    outputs coexist at step end; with donation the outputs alias the
    donated arguments AND XLA books them under temp, so adding args+temp
    would double-count (verified: temp grows by exactly output_bytes when
    donate_argnums is set)."""
    args = int(getattr(mem, "argument_size_in_bytes", 0))
    out = int(getattr(mem, "output_size_in_bytes", 0))
    temp = int(getattr(mem, "temp_size_in_bytes", 0))
    peak = (temp + max(args - out, 0)) if donated else (args + temp + out)
    return {"argument_bytes": args, "output_bytes": out,
            "temp_bytes": temp, "donated": donated, "peak_bytes": peak}


def dryrun_pair(arch: str, shape_name: str, mesh, *, technique_steps: int = 0,
                microbatches: int = 0, top: int = 0,
                verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ms = mesh_axis_sizes(mesh)
    chips = n_chips(mesh)
    reason = skip_reason(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "kind": shape.kind, "status": "skip" if reason else "pending"}
    if reason:
        rec["skip_reason"] = reason
        return rec

    t0 = time.time()
    ishapes = S.input_specs(cfg, shape)
    b_axes = batch_axes(ms, shape.global_batch)

    if shape.kind == "train" and technique_steps:
        W = ms.get("pod", 1)
        if W < 2:
            rec.update(status="skip",
                       skip_reason="technique round needs the pod axis")
            return rec
        round_fn, opt = S.make_local_round(cfg, W, technique_steps)
        sh = S.build_shardings(cfg, shape, mesh, stacked_workers=W)
        opt_shape = jax.eval_shape(
            lambda ps: jax.vmap(opt.init)(ps), sh["params_shape"])
        opt_spec = S.shd.opt_state_specs(sh["params"], opt_shape,
                                         sh["params_shape"])
        B, Sq = shape.global_batch, shape.seq_len
        bspec = {"tokens": jax.ShapeDtypeStruct(
            (W, technique_steps, B // W, Sq), jnp.int32)}
        bshard = {"tokens": P("pod", None, "data", None)}
        if cfg.family == "audio":
            bspec["frames"] = jax.ShapeDtypeStruct(
                (W, technique_steps, B // W, cfg.n_frames, cfg.d_model),
                jnp.bfloat16)
            bshard["frames"] = P("pod", None, "data", None, None)
        jitted = jax.jit(
            round_fn,
            in_shardings=as_shardings(mesh, (sh["params"], opt_spec, bshard)),
            out_shardings=as_shardings(mesh, (sh["params"], opt_spec, P())),
            donate_argnums=(0, 1))
        largs = (sh["params_shape"], opt_shape, bspec)

    elif shape.kind == "train":
        # per-arch gradient accumulation depth (ArchConfig.train_microbatches)
        mb = microbatches or cfg.train_microbatches
        step, opt = S.make_train_step(cfg, microbatches=mb)
        sh = S.build_shardings(cfg, shape, mesh)
        opt_shape = jax.eval_shape(opt.init, sh["params_shape"])
        opt_spec = S.shd.opt_state_specs(sh["params"], opt_shape,
                                         sh["params_shape"])
        in_sh = [sh["params"], opt_spec, sh["tokens"]]
        args = [sh["params_shape"], opt_shape, ishapes["tokens"]]
        if cfg.family == "audio":
            in_sh.append(sh["frames"])
            args.append(ishapes["frames"])
        jitted = jax.jit(
            step, in_shardings=as_shardings(mesh, tuple(in_sh)),
            out_shardings=as_shardings(
                mesh, (sh["params"], opt_spec, P())),
            donate_argnums=(0, 1))
        largs = tuple(args)

    elif shape.kind == "prefill":
        step = S.make_prefill_step(cfg)
        sh = S.build_shardings(cfg, shape, mesh)
        # prefill output cache shardings: same rules as decode cache
        if cfg.family == "audio":
            cache_shape = jax.eval_shape(
                lambda p, tok, fr: step(p, tok, fr)[1],
                sh["params_shape"], ishapes["tokens"], ishapes["frames"])
        else:
            cache_shape = jax.eval_shape(
                lambda p, tok: step(p, tok)[1],
                sh["params_shape"], ishapes["tokens"])
        cache_spec = S.shd.cache_specs(cfg, cache_shape, ms,
                                       shape.global_batch)
        in_sh = [sh["params"], sh["tokens"]]
        args = [sh["params_shape"], ishapes["tokens"]]
        if cfg.family == "audio":
            in_sh.append(sh["frames"])
            args.append(ishapes["frames"])
        jitted = jax.jit(
            step, in_shardings=as_shardings(mesh, tuple(in_sh)),
            out_shardings=as_shardings(mesh, (sh["logits"], cache_spec)))
        largs = tuple(args)

    else:  # decode
        step = S.make_decode_step(cfg)
        sh = S.build_shardings(cfg, shape, mesh)
        jitted = jax.jit(
            step,
            in_shardings=as_shardings(
                mesh, (sh["params"], sh["token1"], sh["cache"])),
            out_shardings=as_shardings(mesh, (sh["logits"], sh["cache"])),
            donate_argnums=(2,))
        largs = (sh["params_shape"], ishapes["token"], ishapes["cache"])

    if shape.kind == "train" and technique_steps:
        b_axes = "data"   # worker batches shard within their own pod
    with sharding_context(mesh, b_axes):
        lowered = jitted.lower(*largs)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    pod_boundary = 256 if "pod" in ms else None
    donated = shape.kind in ("train", "decode")
    rec.update(status="ok", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1),
               **_analyses(lowered, compiled, pod_boundary, donated))
    rec["roofline"] = roofline_terms(
        flops_per_chip=rec["flops_per_chip"],
        bytes_per_chip=rec["bytes_per_chip"],
        collective_bytes_per_chip=rec["collective_bytes_per_chip"],
        chips=chips, cfg=cfg, shape=shape)
    if top:
        from repro.launch.hlo_analysis import HloCostModel
        model = HloCostModel(compiled.as_text())
        for metric in ("bytes", "flops"):
            print(f"  -- top {metric} contributors (per chip, trip-scaled):")
            for val, name in model.top_contributors(metric, n=top):
                unit = val / 1e9
                print(f"     {unit:10.2f} G{'B' if metric=='bytes' else 'F'}"
                      f"  {name}")
    if verbose:
        m = rec["memory"]
        r = rec["roofline"]
        print(f"  mem/chip: args {m['argument_bytes']/2**30:.2f} GiB + "
              f"temp {m['temp_bytes']/2**30:.2f} GiB; "
              f"compute {r['compute_s']*1e3:.2f} ms, "
              f"memory {r['memory_s']*1e3:.2f} ms, "
              f"collective {r['collective_s']*1e3:.2f} ms "
              f"-> {r['dominant']}-bound; useful {r['useful_ratio']:.2f}",
              flush=True)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--technique", type=int, default=0,
                    help="H local steps for the paper's round (multi mesh)")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="override gradient-accumulation microbatches "
                         "(0 = per-arch ArchConfig.train_microbatches)")
    ap.add_argument("--top", type=int, default=0,
                    help="print top-N byte/flop contributor ops (profile)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            for shape in shapes:
                tag = f"{arch} x {shape} x {'multi' if multi else 'single'}"
                print(f"[dryrun] {tag}", flush=True)
                try:
                    rec = dryrun_pair(arch, shape, mesh,
                                      technique_steps=args.technique,
                                      microbatches=args.microbatches,
                                      top=args.top)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "fail", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                if rec["status"] == "skip":
                    print(f"  SKIP: {rec.get('skip_reason')}", flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Input specs (ShapeDtypeStruct stand-ins — no device allocation) and the
step functions lowered by the dry-run for every (arch x shape) pair.

Step kinds:
    train   — one optimizer step (Adam, remat scan over layers). This is
              also one *local* step of the paper's framework; the
              technique's round structure is lowered separately by
              ``local_round`` (multi-pod, H local steps + model exchange).
    prefill — prompt forward building the decode cache.
    decode  — ONE new token against a seq_len KV cache (serve_step).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import INPUT_SHAPES, InputShape
from repro.core.async_local_sgd import (broadcast_to_workers,
                                        local_sgd_round, worker_mean)
from repro.launch import shardings as shd
from repro.launch.mesh import mesh_axis_sizes
from repro.models import transformer as tfm
from repro.optim.optimizers import adam, apply_updates

PyTree = Any


def params_shape(cfg: ArchConfig) -> PyTree:
    return jax.eval_shape(functools.partial(tfm.init_lm, cfg),
                          jax.random.PRNGKey(0))


def input_specs(cfg: ArchConfig, shape: InputShape | str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        spec = {"tokens": sds((B, S), jnp.int32)}
        if cfg.family == "audio":
            spec["frames"] = sds((B, cfg.n_frames, cfg.d_model),
                                 jnp.bfloat16)
        return spec
    # decode: one token + a full cache
    cache = jax.eval_shape(lambda: tfm.init_cache(cfg, B, S))
    return {"token": sds((B,), jnp.int32), "cache": cache}


# --------------------------------------------------------------------------
# Step functions
# --------------------------------------------------------------------------

def make_optimizer(cfg: ArchConfig | None = None):
    mdt = jnp.float32
    if cfg is not None and cfg.adam_moment_dtype == "bfloat16":
        mdt = jnp.bfloat16
    return adam(clip_norm=1.0, moment_dtype=mdt)


def make_train_step(cfg: ArchConfig, lr: float = 1e-4,
                    microbatches: int = 1):
    """One optimizer step. With ``microbatches`` > 1 the global batch is
    split and gradients accumulate in f32 over a scan — activation peak
    (the remat-saved per-layer stacks) divides by the microbatch count,
    which is what keeps the 16 GiB/chip budget at batch 256 x 4k."""
    opt = make_optimizer(cfg)
    # f32 accumulation by default; archs running in the low-precision
    # optimizer mode (adam_moment_dtype=bfloat16, i.e. qwen3-moe-235b)
    # also accumulate in bf16 — the last ~1.9 GiB/chip that brings the
    # 235B model under 16 GiB on one pod (§Perf HC2; precision tradeoff
    # documented there).
    acc_dtype = (jnp.bfloat16 if cfg.adam_moment_dtype == "bfloat16"
                 else jnp.float32)

    def grad_fn(params, tokens, frames):
        return jax.value_and_grad(tfm.lm_loss, argnums=1)(
            cfg, params, tokens, frames)

    def train_step(params, opt_state, tokens, frames=None):
        if microbatches == 1:
            loss, grads = grad_fn(params, tokens, frames)
        else:
            B = tokens.shape[0]
            mb = tokens.reshape((microbatches, B // microbatches)
                                + tokens.shape[1:])
            fb = (None if frames is None else
                  frames.reshape((microbatches, B // microbatches)
                                 + frames.shape[1:]))

            def acc(carry, xs):
                loss_acc, g_acc = carry
                t = xs if fb is None else xs[0]
                f = None if fb is None else xs[1]
                loss, g = grad_fn(params, t, f)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dtype), g_acc, g)
                return (loss_acc + loss, g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            xs = mb if fb is None else (mb, fb)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), g0), xs)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        updates, opt_state = opt.update(grads, opt_state, params, lr)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return train_step, opt


def make_local_round(cfg: ArchConfig, n_workers: int, local_steps: int,
                     lr: float = 1e-4, tau: int = 0):
    """The paper's technique as one jittable round: every worker (pod)
    runs ``local_steps`` SGD-family steps with NO cross-worker collective,
    then models are averaged (one cross-pod all-reduce). With tau=1 the
    averaging consumes the previous round's dispatch (stale averaging) —
    the collective result is needed one call later, so on hardware it
    overlaps the whole next round of local compute."""
    opt = make_optimizer(cfg)

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        frames = batch.get("frames")
        return tfm.lm_loss(cfg, params, tokens, frames)

    def round_fn(stacked_params, stacked_opt, batches):
        p, o, losses = local_sgd_round(loss_fn, opt, stacked_params,
                                       stacked_opt, batches, lr)
        avg = worker_mean(p)           # <- the model exchange (all-reduce)
        p = broadcast_to_workers(avg, p)
        return p, o, jnp.mean(losses)

    return round_fn, opt


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, tokens, frames=None):
        return tfm.lm_prefill(cfg, params, tokens, frames)
    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, token, cache):
        return tfm.lm_decode_step(cfg, params, token, cache)
    return decode_step


# --------------------------------------------------------------------------
# Shardings for a (cfg, shape, mesh) triple
# --------------------------------------------------------------------------

def build_shardings(cfg: ArchConfig, shape: InputShape, mesh,
                    opt_shape: PyTree | None = None,
                    stacked_workers: int = 0) -> dict:
    ms = mesh_axis_sizes(mesh)
    pshape = params_shape(cfg)
    pspec = shd.param_specs(cfg, pshape, ms)
    if stacked_workers:
        pshape = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((stacked_workers,) + s.shape,
                                           s.dtype), pshape)
        pspec = jax.tree.map(
            lambda p: jax.sharding.PartitionSpec("pod", *p), pspec,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    out = {"params_shape": pshape, "params": pspec, "mesh_sizes": ms}
    if opt_shape is not None:
        out["opt"] = shd.opt_state_specs(pspec, opt_shape)
    B, S = shape.global_batch, shape.seq_len
    out["tokens"] = shd.token_spec(ms, B)
    out["frames"] = shd.frames_spec(ms, B)
    if shape.kind == "decode":
        cache_shape = jax.eval_shape(lambda: tfm.init_cache(cfg, B, S))
        out["cache_shape"] = cache_shape
        out["cache"] = shd.cache_specs(cfg, cache_shape, ms, B)
        out["token1"] = jax.sharding.PartitionSpec(
            shd.batch_axes(ms, B))
    logits_v = shd._div(ms, cfg.padded_vocab, "model")
    out["logits"] = jax.sharding.PartitionSpec(
        shd.batch_axes(ms, B), logits_v)
    return out

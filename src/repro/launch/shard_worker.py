"""Standalone shard worker: run ONE serving-mesh shard on this host and
wait for a router to dial in.

    python -m repro.launch.shard_worker --host 0.0.0.0 --port 7070

Then, from the router process (any machine that can reach this one):

    mesh = MultiProcessServingEngine(...).start()
    mesh.connect_shard("hostB:7070")

The worker carries NO configuration of its own — the router's ``hello``
frame ships the shard id, batcher config and session budget, so the
same worker binary serves any mesh. With ``--forever`` the worker
outlives its router: serving state (weights, warm jit cache, session
carries) persists across connections, which is how a crashed router —
or a mesh re-adopting this shard after a network partition
(``awaiting_rejoin``) — resumes where it left off.
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.shard_worker",
        description="serve one mesh shard on this host (see module "
                    "docstring for the remote-join recipe)")
    ap.add_argument("--host", default="0.0.0.0",
                    help="interface to bind (default 0.0.0.0)")
    ap.add_argument("--port", type=int, default=0,
                    help="port to bind (default 0 = ephemeral; the "
                         "bound port is printed either way)")
    ap.add_argument("--forever", action="store_true",
                    help="keep serving across router connections "
                         "instead of exiting after the first one")
    ap.add_argument("--state-dir", default=None, metavar="DIR",
                    help="DurableStore root (shared with the router's "
                         "--state-dir): a cold worker restart primes "
                         "its weight replicas from the last good "
                         "checkpoint before the router re-adopts it")
    args = ap.parse_args(argv)

    from repro.serving.transport import serve_shard

    def _report(port: int) -> None:
        # machine-greppable: launch scripts scrape the bound port
        print(f"shard-worker listening on {args.host}:{port}",
              flush=True)

    try:
        serve_shard(args.host, args.port, forever=args.forever,
                    on_bound=_report, state_dir=args.state_dir)
    except KeyboardInterrupt:
        pass
    return 0


if __name__ == "__main__":
    sys.exit(main())

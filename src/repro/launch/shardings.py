"""Sharding rules: ArchConfig + mesh -> PartitionSpec trees for params,
optimizer state, inputs and caches (DESIGN.md §5).

2-D "ZeRO-ish" param sharding: with layers stacked [L, ...], the
contracting/output feature dims shard on ``model`` and d_model rows shard
on ``data`` — params *and* Adam moments are fully sharded, which is what
lets qwen3-moe-235b (2.35 TB with fp32 moments) fit 256 x 16 GiB.

Every rule guards on divisibility (``_div``): a dim that does not divide
the axis stays unsharded rather than failing at compile (e.g. mixtral's
8 experts on a 16-way model axis fall back to sharding d_ff instead —
GSPMD would otherwise pad; we prefer the explicit fallback).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

PyTree = Any


def _axis(mesh_sizes: dict, name: str):
    return name if name in mesh_sizes else None


def _div(mesh_sizes: dict, dim: int, axis: str):
    """axis name if it exists and divides dim, else None."""
    size = mesh_sizes.get(axis)
    return axis if size and dim % size == 0 and dim >= size else None


def batch_axes(mesh_sizes: dict, batch: int):
    """Largest prefix of ('pod','data') whose product divides batch."""
    axes = []
    prod = 1
    for name in ("pod", "data"):
        size = mesh_sizes.get(name)
        if size and batch % (prod * size) == 0:
            axes.append(name)
            prod *= size
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


# --------------------------------------------------------------------------
# Param specs — walk the param pytree by key path
# --------------------------------------------------------------------------

def param_specs(cfg: ArchConfig, params_shape: PyTree,
                mesh_sizes: dict) -> PyTree:
    """PartitionSpec tree matching ``jax.eval_shape(init_lm)`` output."""

    def spec_of(path, leaf) -> P:
        keys = [_k(p) for p in path]
        name = keys[-1]
        stacked = any(k in ("layers", "enc_layers") for k in keys)
        shape = leaf.shape
        dims = shape[1:] if stacked else shape
        lead = (None,) if stacked else ()

        def d(i, axis):  # shard dims[i] on axis if divisible
            return _div(mesh_sizes, dims[i], axis)

        if name == "embed":
            return P(d(0, "model"), d(1, "data"))
        if name == "lm_head":
            return P(d(0, "data"), d(1, "model"))
        if name == "enc_pos":
            return P(None, d(1, "data"))
        if name in ("wq", "wk", "wv"):
            return P(*lead, d(0, "data"), d(1, "model"))
        if name == "wo":
            return P(*lead, d(0, "model"), d(1, "data"))
        if name in ("bq", "bk", "bv"):
            return P(*lead, d(0, "model"))
        if name == "router":
            return P(*lead, d(0, "data"), d(1, "model"))
        if name in ("w1", "w3") and len(dims) == 3:      # MoE [E, D, F]
            e = d(0, "model")
            return P(*lead, e, d(1, "data"),
                     None if e else d(2, "model"))
        if name == "w2" and len(dims) == 3:              # MoE [E, F, D]
            e = d(0, "model")
            return P(*lead, e, None if e else d(1, "model"), d(2, "data"))
        if name in ("w1", "w3"):                         # MLP [D, F]
            return P(*lead, d(0, "data"), d(1, "model"))
        if name == "w2":                                 # MLP [F, D]
            return P(*lead, d(0, "model"), d(1, "data"))
        if name in ("b1",):
            return P(*lead, d(0, "model"))
        if name in ("b2",):
            return P(*lead, None)
        if name == "in_proj":                            # SSM [D, X]
            return P(*lead, d(0, "data"), d(1, "model"))
        if name == "out_proj":                           # SSM [d_inner, D]
            return P(*lead, d(0, "model"), d(1, "data"))
        if name == "conv_w":
            return P(*lead, d(0, "model"), None)
        if name in ("conv_b", "norm_w"):
            return P(*lead, d(0, "model"))
        # norms, scalars, biases, A_log, D, dt_bias: replicate
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec_of, params_shape)


def _k(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


def opt_state_specs(param_spec_tree: PyTree, opt_state_shape: PyTree,
                    params_shape: PyTree | None = None) -> PyTree:
    """Adam moments mirror param sharding (matched by leaf shape —
    AdamState.mu/nu are isomorphic to params); scalars and small
    bookkeeping leaves (e.g. the per-worker step counter) replicate."""
    is_p = lambda x: isinstance(x, P)
    specs = jax.tree_util.tree_leaves(param_spec_tree, is_leaf=is_p)
    if params_shape is not None:
        shapes = [tuple(l.shape)
                  for l in jax.tree_util.tree_leaves(params_shape)]
    else:
        shapes = [None] * len(specs)
    by_shape: dict = {}
    for shp, sp in zip(shapes, specs):
        if shp is not None:
            by_shape.setdefault(shp, sp)

    leaves, treedef = jax.tree_util.tree_flatten(opt_state_shape)
    out = []
    pi = 0
    for leaf in leaves:
        shp = tuple(leaf.shape)
        if shp in by_shape:
            out.append(by_shape[shp])
        elif leaf.ndim == 0:
            out.append(P())
        elif params_shape is None and pi < len(specs) and leaf.ndim > 0:
            # legacy positional fallback (moments traverse like params)
            out.append(specs[pi % len(specs)])
        else:
            out.append(P(*([None] * leaf.ndim)))
        pi += 1
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------------
# Input / cache specs
# --------------------------------------------------------------------------

def token_spec(mesh_sizes: dict, batch: int) -> P:
    return P(batch_axes(mesh_sizes, batch), None)


def frames_spec(mesh_sizes: dict, batch: int) -> P:
    return P(batch_axes(mesh_sizes, batch), None, None)


def cache_specs(cfg: ArchConfig, cache_shape: PyTree,
                mesh_sizes: dict, batch: int) -> PyTree:
    """KV/state cache sharding: batch on data axes; kv-heads on model when
    divisible, else the cache *sequence* dim on model (granite kv=1 etc.)."""
    b_ax = batch_axes(mesh_sizes, batch)

    def spec_of(path, leaf) -> P:
        name = _k(path[-1])
        if name in ("len", "flushed"):
            return P()
        if name in ("kr", "vr"):
            # replicated decode write buffer (small): batch-sharded only
            return P(None, b_ax, None, None, None)
        if name in ("k", "v", "xk", "xv"):
            # main cache [Lc, B, S, Hkv, hd] — READ-ONLY in a decode step
            # (writes go through kr/vr + flush_recent), so it can shard
            # on kv-heads when divisible, else on the sequence dim.
            _, _, S, Hkv, _ = leaf.shape
            h_ax = _div(mesh_sizes, Hkv, "model")
            s_ax = None if h_ax else _div(mesh_sizes, S, "model")
            return P(None, b_ax, s_ax, h_ax, None)
        if name == "conv":
            # [L, B, K-1, conv_dim]
            return P(None, b_ax, None, _div(mesh_sizes, leaf.shape[-1],
                                            "model"))
        if name == "ssm":
            # [L, B, H, P, N]
            return P(None, b_ax, _div(mesh_sizes, leaf.shape[2], "model"),
                     None, None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_of, cache_shape)


def as_shardings(mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))

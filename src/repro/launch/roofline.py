"""Roofline-term extraction from a compiled dry-run artifact.

    compute term    = HLO_FLOPs_global / (chips * PEAK_FLOPS)
    memory term     = HLO_bytes_global / (chips * HBM_BW)
    collective term = collective_bytes_per_chip / ICI_BW

cost_analysis() reports per-program (= per-device, post-SPMD-partition)
numbers, so global = per_device * chips. Collective bytes are parsed from
the optimized HLO (result-shape bytes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute, start ops only once) —
a per-chip traffic proxy; ring-algorithm constant factors (2(n-1)/n etc.)
are absorbed into the term's interpretation. MODEL_FLOPS = 6*N*D with N
the (active) parameter count.
"""

from __future__ import annotations

import re

from repro.configs.base import ArchConfig
from repro.configs.shapes import InputShape
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(typestr):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result bytes per collective kind from optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start)?\(", line)
        if not m:
            continue
        typestr, kind, _start = m.groups()
        # skip -done ops (matched only via -start suffix group); "-done"
        # never matches because the regex requires the base name.
        out[kind] += _shape_bytes(typestr)
    return out


def roofline_terms(*, flops_per_chip: float, bytes_per_chip: float,
                   collective_bytes_per_chip: float, chips: int,
                   cfg: ArchConfig, shape: InputShape) -> dict:
    compute_s = flops_per_chip / PEAK_FLOPS_BF16
    memory_s = bytes_per_chip / HBM_BW
    collective_s = collective_bytes_per_chip / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    # MODEL_FLOPS: 6 * N_active * D_tokens for train; 2 * N_active * D for
    # a forward-only step (prefill/decode).
    n_active = cfg.active_param_count()
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill") else shape.global_batch)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens
    hlo_global = flops_per_chip * chips
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_ratio": model_flops / hlo_global if hlo_global else 0.0,
        "chips": chips,
    }

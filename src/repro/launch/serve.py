"""Serving launcher: thin CLI over ``repro.serving`` — hosts the paper
LSTM and/or zoo archs behind the dynamic micro-batching engine and
replays a simulated many-client traffic trace against it.

    # stream stock windows from 64 synthetic clients at the paper model
    PYTHONPATH=src python -m repro.launch.serve --model paper-lstm \
        --clients 64 --requests 512 --max-batch 32 --max-wait-ms 2

    # host a zoo arch (reduced, CPU) serving next-token forecasts
    PYTHONPATH=src python -m repro.launch.serve --model qwen1.5-4b \
        --requests 128 --prompt-len 32
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def _traffic_windows(n_clients: int, window: int, seed: int):
    """Per-client normalized window streams from the synthetic S&P500
    generator (distinct ticker per client)."""
    from repro.data import load_stock, make_windows

    streams = []
    for c in range(n_clients):
        ohlcv = load_stock(f"CLIENT{c}", n_days=window + 64)
        ds = make_windows(ohlcv, window=window)
        streams.append(ds.x)
    return streams


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="paper-lstm",
                    help="'paper-lstm' or any zoo arch name")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="serve the reduced (CPU smoke) zoo config; "
                    "--no-reduced hosts the full config")
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--sessions", action="store_true",
                    help="also demo O(1) per-step session serving")
    ap.add_argument("--alert-threshold", type=float, default=0.9)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.serving import (BatcherConfig, ModelRegistry,
                               RecurrentSessionRunner, ServingEngine,
                               SessionCache, build_lstm_forecaster,
                               build_zoo_forecaster)

    registry = ModelRegistry()
    if args.model == "paper-lstm":
        fc = build_lstm_forecaster(seed=args.seed)
        windows = _traffic_windows(args.clients, fc.window, args.seed)
        payloads = [windows[i % args.clients][i % len(windows[i % args.clients])]
                    for i in range(args.requests)]
    else:
        from repro.data.tokens import synthetic_token_batch
        fc = build_zoo_forecaster(args.model, seed=args.seed,
                                  reduced=args.reduced)
        toks = synthetic_token_batch(args.requests, args.prompt_len,
                                     fc.cfg.vocab, seed=args.seed)
        payloads = list(toks)
    registry.register(args.model, fc)

    # bucket exactly the lengths this trace contains: no padding waste
    cfg = BatcherConfig(max_batch=args.max_batch,
                        max_wait_ms=args.max_wait_ms,
                        length_buckets=tuple(sorted(
                            {p.shape[0] for p in payloads})))
    with ServingEngine(registry, cfg) as engine:
        engine.warmup(args.model,
                      lengths=tuple({p.shape[0] for p in payloads}))
        engine.telemetry.reset_clock()
        t0 = time.time()
        futures = [engine.submit(args.model, p) for p in payloads]
        results = [f.result(timeout=60.0) for f in futures]
        wall = time.time() - t0
        snap = engine.telemetry.snapshot()

    alerts = [(i, y, p) for i, (y, p) in enumerate(results)
              if p >= args.alert_threshold]
    print(f"{args.model}: {len(results)} requests in {wall*1e3:.1f} ms")
    print(engine.telemetry.format(snap))
    print(f"extreme alerts (p >= {args.alert_threshold}): {len(alerts)}"
          + (f", first: req {alerts[0][0]} forecast {alerts[0][1]:+.4f} "
                 f"p {alerts[0][2]:.3f}" if alerts else ""))

    if args.sessions and args.model == "paper-lstm":
        runner = RecurrentSessionRunner(
            fc, SessionCache(max_sessions=args.clients,
                             telemetry=engine.telemetry))
        streams = _traffic_windows(min(args.clients, 8), fc.window,
                                   args.seed + 1)
        t0 = time.time()
        n_steps = 0
        for step in range(fc.window):
            for c, stream in enumerate(streams):
                runner.step(f"client-{c}", stream[0][step])
                n_steps += 1
        wall = time.time() - t0
        print(f"sessions: {n_steps} O(1) steps in {wall*1e3:.1f} ms "
              f"({n_steps/max(wall,1e-9):.0f} steps/s); "
              f"cache {runner.cache.stats()}")


if __name__ == "__main__":
    main()

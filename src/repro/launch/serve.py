"""Serving launcher: thin CLI over ``repro.serving`` — hosts the paper
LSTM and/or zoo archs behind the dynamic micro-batching engine (one
shard, or a sharded mesh with ``--shards``) and replays a simulated
many-client traffic trace against it.

    # stream stock windows from 64 synthetic clients at the paper model
    PYTHONPATH=src python -m repro.launch.serve --model paper-lstm \
        --clients 64 --requests 512 --max-batch 32 --max-wait-ms 2

    # the same trace over a 4-shard serving mesh
    PYTHONPATH=src python -m repro.launch.serve --shards 4 --requests 512

    # the mesh over OS processes (one EngineShard per process, socket
    # transport between router and workers)
    PYTHONPATH=src python -m repro.launch.serve --shards 2 --processes

    # durable state plane: publishes + periodic async session
    # checkpoints land under ./state; a later run with the same
    # --state-dir cold-restarts the fleet from the last good manifest
    PYTHONPATH=src python -m repro.launch.serve --shards 2 --processes \
        --state-dir ./state --checkpoint-interval-s 2

    # host a REAL trained checkpoint (from `-m repro.launch.train
    # --save ckpt.npz`) and score its extreme alerts against the
    # synthetic labels
    PYTHONPATH=src python -m repro.launch.serve --checkpoint ckpt.npz

    # host a zoo arch (reduced, CPU) serving next-token forecasts
    PYTHONPATH=src python -m repro.launch.serve --model qwen1.5-4b \
        --requests 128 --prompt-len 32
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def _traffic_datasets(n_clients: int, window: int, seed: int):
    """Per-client window datasets from the synthetic S&P500 generator
    (distinct ticker per client); ``.x`` feeds traffic, ``.v`` is the
    extreme-event label of each window's next step."""
    from repro.data import load_stock, make_windows

    streams = []
    for c in range(n_clients):
        ohlcv = load_stock(f"CLIENT{c}", n_days=window + 64, seed=seed + c)
        streams.append(make_windows(ohlcv, window=window))
    return streams


def _precision_recall(alerts: np.ndarray, labels: np.ndarray):
    tp = int(np.sum(alerts & (labels != 0)))
    fp = int(np.sum(alerts & (labels == 0)))
    fn = int(np.sum(~alerts & (labels != 0)))
    precision = tp / (tp + fp) if tp + fp else 0.0
    recall = tp / (tp + fn) if tp + fn else 0.0
    return precision, recall, tp, fp, fn


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="paper-lstm",
                    help="'paper-lstm' or any zoo arch name")
    ap.add_argument("--checkpoint", default=None, metavar="PATH",
                    help="host a trained serving checkpoint (the output "
                    "of `-m repro.launch.train --save`) instead of a "
                    "freshly initialized model, and report alert "
                    "precision/recall against the synthetic extreme "
                    "labels")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="serve the reduced (CPU smoke) zoo config; "
                    "--no-reduced hosts the full config")
    ap.add_argument("--shards", type=int, default=1,
                    help="serve through a sharded mesh with this many "
                    "EngineShard workers (1 = single engine)")
    ap.add_argument("--processes", action="store_true",
                    help="with --shards > 1: run each shard as its own "
                    "OS process behind the socket transport "
                    "(repro.serving.transport) instead of a thread")
    ap.add_argument("--connect", action="append", default=[],
                    metavar="HOST:PORT",
                    help="(implies --processes) also join a shard worker "
                    "already listening at HOST:PORT (started with "
                    "`python -m repro.launch.shard_worker`); repeatable")
    ap.add_argument("--heartbeat-s", type=float, default=0.5,
                    help="process-mesh supervision heartbeat interval "
                    "(crashed workers are detected within "
                    "heartbeat * 4 and respawned)")
    ap.add_argument("--state-dir", default=None, metavar="DIR",
                    help="durable state plane: DurableStore root. Every "
                    "publish lands there before acknowledgement; with "
                    "--processes the mesh also cold-restarts from the "
                    "last good checkpoint (weights, ensemble specs, "
                    "session carries) and a CheckpointDaemon snapshots "
                    "periodically off the hot path")
    ap.add_argument("--checkpoint-interval-s", type=float, default=5.0,
                    help="async checkpoint period for --state-dir "
                    "(a final checkpoint is always taken at shutdown)")
    ap.add_argument("--keep-last", type=int, default=3,
                    help="checkpoint retention for --state-dir: keep "
                    "this many manifests (older ones + unreferenced "
                    "blobs are garbage-collected)")
    ap.add_argument("--max-skew", type=int, default=1,
                    help="mesh swap-propagation staleness bound "
                    "(versions a shard may lag the primary)")
    ap.add_argument("--ensemble", type=int, default=1, metavar="N",
                    help="serve an N-member ensemble of the model "
                    "(distinct init seeds) fused by EVT-weighted "
                    "combination, with the anomaly-aware alert path; "
                    "traffic routes at the ensemble name and every "
                    "request fans out to N per-model fused dispatches")
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--sessions", action="store_true",
                    help="also demo O(1) per-step session serving")
    ap.add_argument("--alert-threshold", type=float, default=0.9)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", action="store_true",
                    help="record per-request trace spans (submit -> queue "
                    "-> flush -> ... -> reply) and print a span summary "
                    "of the slowest trace")
    ap.add_argument("--metrics-port", type=int, default=None,
                    metavar="PORT",
                    help="serve /metrics (Prometheus), /metrics.json, "
                    "/history, /traces and /events on this port while "
                    "the traffic runs (0 = ephemeral; fleet-merged view "
                    "on a mesh)")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="append phase markers + final snapshot as JSONL "
                    "events to PATH (tools/report.py renders them)")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the traffic "
                    "phase into DIR (view with TensorBoard / Perfetto)")
    args = ap.parse_args(argv)

    from repro.obs import EventLog, MetricsServer, Tracer
    from repro.serving import (BatcherConfig, CheckpointDaemon,
                               DurableStore, ModelRegistry,
                               MultiProcessServingEngine, ServingEngine,
                               ShardedServingEngine, Telemetry,
                               build_lstm_forecaster, build_zoo_forecaster)

    store = (DurableStore(args.state_dir, keep_last=args.keep_last)
             if args.state_dir else None)
    registry = ModelRegistry()
    if args.checkpoint:
        fc = registry.load(args.checkpoint, key=args.model)
        print(f"hosting checkpoint {args.checkpoint} as {args.model!r} "
              f"(kind={fc.kind}, v{registry.version(args.model)})")
    elif args.model == "paper-lstm":
        fc = build_lstm_forecaster(seed=args.seed)
    else:
        fc = build_zoo_forecaster(args.model, seed=args.seed,
                                  reduced=args.reduced)
    if args.model not in registry:
        registry.register(args.model, fc)

    serve_key = args.model
    if args.ensemble > 1:
        if args.checkpoint:
            ap.error("--ensemble needs distinct member inits; it does "
                     "not combine with --checkpoint")
        members = [args.model]
        for i in range(1, args.ensemble):
            key = f"{args.model}-{i}"
            if args.model == "paper-lstm":
                m = build_lstm_forecaster(seed=args.seed + i)
            else:
                m = build_zoo_forecaster(args.model, seed=args.seed + i,
                                         reduced=args.reduced)
            registry.register(key, m)
            members.append(key)
        serve_key = f"{args.model}-ensemble"
        registry.register_ensemble(serve_key, members,
                                   alert_threshold=args.alert_threshold)
        print(f"hosting {serve_key!r}: {args.ensemble} members "
              f"{members} fused by EVT-weighted combination")

    labels = None
    if fc.feature_dim:                      # window-stream (LSTM) traffic
        streams = _traffic_datasets(args.clients, fc.window, args.seed)
        payloads, labels_list = [], []
        for i in range(args.requests):
            ds = streams[i % args.clients]
            j = i % len(ds)
            payloads.append(ds.x[j])
            labels_list.append(int(ds.v[j]))
        labels = np.asarray(labels_list)
    else:                                   # token traffic for zoo archs
        from repro.data.tokens import synthetic_token_batch
        toks = synthetic_token_batch(args.requests, args.prompt_len,
                                     fc.cfg.vocab, seed=args.seed)
        payloads = list(toks)

    # bucket exactly the lengths this trace contains: no padding waste
    cfg = BatcherConfig(max_batch=args.max_batch,
                        max_wait_ms=args.max_wait_ms,
                        length_buckets=tuple(sorted(
                            {p.shape[0] for p in payloads})))
    lengths = tuple({p.shape[0] for p in payloads})
    tracer = Tracer(capacity=1024) if args.trace else None
    events = EventLog(path=args.events_out) if args.events_out else None
    if args.connect:
        args.processes = True
        args.shards = max(args.shards, 1)
    if (args.shards > 1 or args.connect) and args.processes:
        engine = MultiProcessServingEngine(registry, cfg,
                                           n_shards=args.shards,
                                           max_skew=args.max_skew,
                                           tracer=tracer,
                                           heartbeat_s=args.heartbeat_s,
                                           events=events,
                                           durable=store)
    elif args.shards > 1:
        if store is not None:
            registry.attach_durable(store)   # weights durable; the
            # session/restart plane needs the process mesh (--processes)
        engine = ShardedServingEngine(registry, cfg, n_shards=args.shards,
                                      max_skew=args.max_skew,
                                      tracer=tracer)
    else:
        if store is not None:
            registry.attach_durable(store)
        engine = ServingEngine(registry, cfg, tracer=tracer)

    is_mesh = args.shards > 1 or bool(args.connect)
    snapshot_fn = (engine.snapshot if is_mesh
                   else lambda: engine.telemetry.snapshot())
    metrics = None
    if args.metrics_port is not None:
        metrics = MetricsServer(snapshot_fn, port=args.metrics_port,
                                tracer=tracer, events=events,
                                sample_interval_s=0.5).start()
        print(f"metrics: {metrics.url}/metrics (also /metrics.json, "
              f"/history, /traces, /events)")
    if events is not None:
        events.log("phase", name="traffic", model=args.model,
                   shards=args.shards, requests=args.requests)

    profile_ctx = None
    if args.profile_dir:
        import jax

        profile_ctx = jax.profiler.trace(args.profile_dir)

    with engine:
        for addr in args.connect:
            sid = engine.connect_shard(addr)
            print(f"joined remote shard worker {addr} as shard {sid}")
        daemon = None
        if store is not None and isinstance(engine,
                                            MultiProcessServingEngine):
            restored = engine.restore_from(store)
            if restored["seq"] is not None:
                print(f"durable restore from {args.state_dir} (manifest "
                      f"{restored['seq']}): models {restored['models']}, "
                      f"{restored['restored_sessions']} sessions resumed"
                      f" ({restored['restored_stale']} stale ->"
                      f" history re-prime)")
            daemon = CheckpointDaemon(
                store, engine, interval_s=args.checkpoint_interval_s,
                events=events).start()
        engine.warmup(serve_key, lengths=lengths)
        if is_mesh:
            engine.reset_clock()
        else:
            engine.telemetry.reset_clock()
        if profile_ctx is not None:
            profile_ctx.__enter__()
        t0 = time.time()
        futures = [engine.submit(serve_key, p,
                                 client_id=f"client-{i % args.clients}")
                   for i, p in enumerate(payloads)]
        results = [f.result(timeout=60.0) for f in futures]
        wall = time.time() - t0
        if profile_ctx is not None:
            profile_ctx.__exit__(None, None, None)
            print(f"profiler capture written to {args.profile_dir}")
        snap = (engine.snapshot() if is_mesh
                else engine.telemetry.snapshot())
        if events is not None:
            events.log("snapshot", phase="traffic", wall_s=wall, **{
                k: v for k, v in snap.items()
                if isinstance(v, (int, float, bool))})
        if args.sessions and fc.feature_dim and is_mesh \
                and args.processes:
            # sessions live in the worker processes' shard-local caches:
            # each step is routed to the client's owning worker
            streams = _traffic_datasets(min(args.clients, 8), fc.window,
                                        args.seed + 1)
            t0s = time.time()
            n_steps = 0
            for step in range(fc.window):
                for c, ds in enumerate(streams):
                    engine.step(serve_key, f"client-{c}", ds.x[0][step])
                    n_steps += 1
            wall_s = time.time() - t0s
            # resident = device-lane residents + spilled-to-cache; the
            # slots figure shows how many sit in decode lanes right now
            by_worker = {
                sid: f"{len(st['clients'])}"
                     f"({st['slots']['active']}/{st['slots']['lanes']}"
                     f" in lanes)"
                for sid, st in engine.shard_stats().items()}
            print(f"sessions (worker-resident): {n_steps} O(1) steps in "
                  f"{wall_s*1e3:.1f} ms "
                  f"({n_steps/max(wall_s,1e-9):.0f} steps/s); "
                  f"resident by worker {by_worker}")
        elif args.sessions and fc.feature_dim:
            # engine-resident sessions over the slotted decode path:
            # carries live in device decode lanes between ticks, so each
            # tick's steps flush as ONE fused slots_generate dispatch
            # per shard instead of one jit dispatch per client (or a
            # per-tick host gather/scatter through the cache)
            streams = _traffic_datasets(min(args.clients, 8), fc.window,
                                        args.seed + 1)
            t0s = time.time()
            n_steps = 0
            for step in range(fc.window):
                futs = [engine.submit_step(serve_key, f"client-{c}",
                                           ds.x[0][step])
                        for c, ds in enumerate(streams)]
                for f in futs:
                    f.result(timeout=30.0)
                n_steps += len(futs)
            wall_s = time.time() - t0s
            ssnap = (engine.snapshot() if is_mesh
                     else engine.telemetry.snapshot())
            print(f"sessions (batched decode): {n_steps} steps in "
                  f"{wall_s*1e3:.1f} ms "
                  f"({n_steps/max(wall_s,1e-9):.0f} steps/s); "
                  f"{ssnap['step_batches']} fused flushes, mean batch "
                  f"{ssnap['mean_step_batch']:.1f}, step p95 "
                  f"{ssnap['step_p95_ms']:.2f} ms")
            if events is not None:
                events.log("snapshot", phase="sessions", wall_s=wall_s,
                           **{k: v for k, v in ssnap.items()
                              if isinstance(v, (int, float, bool))})
        if daemon is not None:
            # one last synchronous snapshot: a clean shutdown is as
            # durable as a crash-with-checkpoint, so the next
            # `--state-dir` run resumes every stream
            daemon.stop(final_checkpoint=True)
            print(f"durable: {daemon.commits} checkpoint commits to "
                  f"{args.state_dir} (last manifest {daemon.last_seq})")

    alert_mask = np.asarray([p >= args.alert_threshold
                             for _, p in results], dtype=bool)
    alerts = [(i, y, p) for i, (y, p) in enumerate(results)
              if p >= args.alert_threshold]
    print(f"{serve_key}: {len(results)} requests in {wall*1e3:.1f} ms"
          + (f" over {engine.n_shards} shards" if is_mesh else ""))
    print(Telemetry.format(snap))
    if is_mesh:
        print(f"mesh: requests by shard {snap['requests_by_shard']} | "
              f"{snap['pulls']} weight pulls "
              f"({snap['bytes_pulled']/1e6:.2f} MB) | version vector "
              f"{engine.version_vector(args.model)}")
    print(f"extreme alerts (p >= {args.alert_threshold}): {len(alerts)}"
          + (f", first: req {alerts[0][0]} forecast {alerts[0][1]:+.4f} "
                 f"p {alerts[0][2]:.3f}" if alerts else ""))
    if labels is not None and labels.size:
        precision, recall, tp, fp, fn = _precision_recall(alert_mask,
                                                          labels)
        print(f"alert quality vs synthetic extreme labels: precision "
              f"{precision:.3f}  recall {recall:.3f}  (tp={tp} fp={fp} "
              f"fn={fn}, base rate {float(np.mean(labels != 0)):.3f})")
    if tracer is not None:
        done = tracer.traces()
        if done:
            slow = max(done, key=lambda t: t.duration)
            parts = "  ".join(
                f"{s.name} {s.dur*1e3:.2f}ms"
                for s in sorted(slow.spans, key=lambda s: s.t0))
            print(f"traces: {len(done)} recorded; slowest "
                  f"({slow.op}, {slow.duration*1e3:.2f} ms): {parts}")
    if events is not None:
        events.log("phase", name="done")
        events.close()
        print(f"events written to {args.events_out}")
    if metrics is not None:
        metrics.stop()


if __name__ == "__main__":
    main()

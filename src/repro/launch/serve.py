"""Serving launcher: batched prefill + decode for any zoo arch (reduced
configs run on host CPU; full configs are exercised via dryrun.py).

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.data.tokens import (synthetic_embedding_batch,
                                   synthetic_token_batch)
    from repro.models.model_zoo import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    toks = jnp.asarray(synthetic_token_batch(args.batch, args.prompt_len,
                                             cfg.vocab, seed=args.seed))
    frames = None
    if cfg.family == "audio":
        frames = jnp.asarray(synthetic_embedding_batch(
            args.batch, cfg.n_frames, cfg.d_model, seed=args.seed))

    from repro.models.transformer import flush_recent

    max_len = args.prompt_len + args.gen
    t0 = time.time()
    logits, cache = jax.jit(model.prefill)(params, toks, frames)
    # re-home the prefill cache into a max_len buffer for decoding
    full = model.init_cache(args.batch, max_len)

    def _place(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        if dst.ndim == src.ndim and dst.shape[2] != src.shape[2]:
            return dst.at[:, :, :src.shape[2]].set(src)
        return src
    cache = jax.tree.map(_place, full, cache)
    cache["len"] = jnp.asarray(args.prompt_len, jnp.int32)
    t_prefill = time.time() - t0

    decode = jax.jit(model.decode_step)
    flush = jax.jit(lambda c: flush_recent(cfg, c))
    out_tokens = []
    tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.gen):
        out_tokens.append(np.asarray(tok))
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
        if "kr" in cache and int(cache["len"] - cache["flushed"]) >= \
                cfg.decode_buffer:
            cache = flush(cache)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    gen = np.stack(out_tokens, 1)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(f"{cfg.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.1f} ms; {args.gen} decode steps in "
          f"{t_decode*1e3:.1f} ms "
          f"({args.batch*args.gen/max(t_decode,1e-9):.1f} tok/s)")
    print("sample generations:", gen[:2, :8].tolist())


if __name__ == "__main__":
    main()

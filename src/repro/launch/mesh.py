"""Production mesh construction.

TPU v5e target: one pod = 16 x 16 = 256 chips, axes (data, model);
multi-pod = 2 pods = 512 chips, axes (pod, data, model). The paper's
local-SGD workers map onto the ``pod`` axis (DESIGN.md §2): no cross-pod
collective during a round, one cross-pod model all-reduce per round.

Functions, not module constants — importing this module never touches
jax device state.
"""

from __future__ import annotations

import jax

# v5e hardware constants (per chip) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
HBM_BYTES = 16 * 1024**3        # 16 GiB


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1, devices=None):
    """Small mesh over whatever devices exist (CPU smoke / examples).
    ``devices`` pins the mesh to an explicit device subset — e.g. the
    serving swarm builds a one-device mesh per shard so each shard's
    replica weights live on (and its flushes run on) its own device."""
    if devices is None:
        return jax.make_mesh((n_data, n_model), ("data", "model"))
    import numpy as np

    arr = np.asarray(devices, dtype=object).reshape(n_data, n_model)
    return jax.sharding.Mesh(arr, ("data", "model"))


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def n_chips(mesh) -> int:
    return int(mesh.devices.size)

"""HLO-text cost analyzer with while-loop trip-count accounting.

XLA's ``compiled.cost_analysis()`` counts a while body ONCE, so a
scan-over-layers model under-reports FLOPs/bytes/collectives by ~n_layers
(verified empirically in this repo). This analyzer parses the optimized
(post-SPMD-partition) HLO text and:

  * builds a per-computation symbol table (name -> shape) so dot FLOPs can
    use true contraction sizes;
  * multiplies while-body costs by the loop trip count (recovered from the
    canonical scan condition ``compare(iv, constant), direction=LT``);
  * attributes fusion/call/conditional bodies to their call sites;
  * counts collective result bytes per kind with the same trip scaling;
  * estimates HBM bytes as operand+result bytes of top-level (post-fusion)
    ops, which is the fusion-boundary traffic model.

All numbers are per-device (the HLO is already partitioned).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|"
    r"pred|c64|c128|token)\[([0-9,]*)\]")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?([%\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}\/#]+?))\s+"
    r"([\w\-]+)\(")


@dataclasses.dataclass
class _Op:
    name: str
    typestr: str
    opcode: str
    line: str


def _numel_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(typestr: str) -> list[int]:
    m = _SHAPE_RE.search(typestr)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs",
    "logistic", "cosine", "sine", "expm1", "log1p", "floor", "ceil",
    "select", "compare", "and", "or", "not", "xor",
}


def _is_cross_pod(line: str, boundary: int) -> bool:
    """True when a collective's groups span the pod boundary (device ids
    on both sides of ``boundary``) — classifies inter-pod ICI traffic."""
    import numpy as np

    m = re.search(r"replica_groups=\{(\{[0-9, ]+\}(?:,\{[0-9, ]+\})*)\}",
                  line)
    if m:
        for grp in re.findall(r"\{([0-9, ]+)\}", m.group(1)):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if ids and min(ids) < boundary <= max(ids):
                return True
        return False
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
                  r"(?:T\(([0-9,]+)\))?", line)
    if m:
        n, g = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(",")])
        ids = ids.reshape(n, g)
        return bool(np.any((ids.min(1) < boundary)
                           & (ids.max(1) >= boundary)))
    m = re.search(r"source_target_pairs=\{(\{[0-9, ]+\}(?:,\{[0-9, ]+\})*)\}",
                  line)
    if m:
        for pair in re.findall(r"\{([0-9, ]+)\}", m.group(1)):
            a, b = [int(x) for x in pair.replace(" ", "").split(",")[:2]]
            if (a < boundary) != (b < boundary):
                return True
    return False


class HloCostModel:
    def __init__(self, hlo_text: str, pod_boundary: int | None = None):
        self.computations = self._split_computations(hlo_text)
        self.entry = self._find_entry(hlo_text)
        self.pod_boundary = pod_boundary
        self._memo: dict[str, dict] = {}

    # -- parsing ----------------------------------------------------------
    @staticmethod
    def _split_computations(text: str) -> dict[str, list[_Op]]:
        """Computation headers sit at column 0 (``%name (params) -> ty {`` /
        ``ENTRY ...``); body ops are indented. Params may contain
        ``/*index=N*/`` comments, so headers are recognized purely by
        position + trailing '{'."""
        comps: dict[str, list[_Op]] = {}
        current = None
        for line in text.splitlines():
            if line and not line[0].isspace():
                if line.rstrip().endswith("{"):
                    m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line)
                    if m and m.group(1) not in ("HloModule",):
                        current = m.group(1)
                        comps[current] = []
                    continue
                if line.strip() == "}":
                    current = None
                continue
            if line.strip() == "}":
                continue
            if current is None:
                continue
            m = _OP_RE.match(line)
            if m:
                name, typestr, opcode = m.groups()
                comps[current].append(_Op(name.lstrip("%"), typestr, opcode,
                                          line))
        return comps

    @staticmethod
    def _find_entry(text: str) -> str | None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
        return m.group(1) if m else None

    # -- trip counts ------------------------------------------------------
    def _trip_count(self, cond_name: str) -> int:
        """Recover scan trip count from the loop condition computation."""
        ops = self.computations.get(cond_name, [])
        consts: dict[str, int] = {}
        best = None
        for op in ops:
            if op.opcode == "constant":
                m = re.search(r"constant\((-?\d+)\)", op.line)
                if m:
                    consts[op.name] = int(m.group(1))
            if op.opcode == "compare":
                m = re.search(r"compare\(([^)]*)\)", op.line)
                direction = re.search(r"direction=(\w+)", op.line)
                if not m or not direction:
                    continue
                args = [self._operand_name(a.strip())
                        for a in m.group(1).split(",")]
                for a in args:
                    if a in consts:
                        c = consts[a]
                        if direction.group(1) == "LT":
                            best = c
                        elif direction.group(1) in ("GT", "GE", "LE"):
                            best = c if best is None else best
        if best is None or best <= 0:
            return 1
        return best

    # -- per-op local cost --------------------------------------------------
    def _dot_flops(self, op: _Op, symbols: dict[str, str]) -> float:
        out = _shape_dims(op.typestr)
        out_elems = 1
        for d in out:
            out_elems *= d
        # contraction size from lhs shape and contracting dims
        operands = self._operands_raw(op)
        cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        k = 1
        if operands and cdims and cdims.group(1):
            dims = self._operand_shape(operands[0], symbols)
            for ci in cdims.group(1).split(","):
                ci = int(ci)
                if ci < len(dims):
                    k *= dims[ci]
        return 2.0 * out_elems * k

    def _conv_flops(self, op: _Op, symbols: dict[str, str]) -> float:
        out = _shape_dims(op.typestr)
        out_elems = 1
        for d in out:
            out_elems *= d
        operands = self._operands_raw(op)
        k = 1
        if len(operands) > 1:
            dims = self._operand_shape(operands[1], symbols)
            for d in dims[:-1]:
                k *= d
        return 2.0 * out_elems * k

    def _operands_raw(self, op: _Op) -> list[str]:
        """Raw operand strings — either ``%name`` or, in newer HLO text,
        ``f32[128,256]{1,0} %name`` (operand types printed inline)."""
        idx = op.line.find(op.opcode + "(")
        if idx < 0:
            return []
        args = op.line[idx + len(op.opcode) + 1:]
        depth = 1
        out = []
        cur = ""
        for ch in args:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
                if depth == 0:
                    break
            if ch == "," and depth == 1:
                out.append(cur.strip())
                cur = ""
            else:
                cur += ch
        if cur.strip():
            out.append(cur.strip())
        return [a for a in out if a]

    @staticmethod
    def _operand_name(raw: str) -> str:
        tok = raw.split()[-1] if raw.split() else raw
        return tok.lstrip("%")

    def _operand_names(self, op: _Op) -> list[str]:
        names = []
        for raw in self._operands_raw(op):
            tok = self._operand_name(raw)
            if tok and not tok[0].isdigit():
                names.append(tok)
        return names

    def _operand_shape(self, raw: str, symbols: dict[str, str]) -> list[int]:
        """Shape of an operand: from the symbol table when the operand is a
        bare name, else from the type printed inline with the operand."""
        t = symbols.get(self._operand_name(raw))
        return _shape_dims(t if t else raw)

    # -- computation cost ---------------------------------------------------
    def computation_cost(self, name: str) -> dict:
        if name in self._memo:
            return self._memo[name]
        ops = self.computations.get(name, [])
        symbols = {op.name: op.typestr for op in ops}
        cost = {"flops": 0.0, "bytes": 0.0,
                "collectives": defaultdict(float),
                "cross_pod": defaultdict(float)}
        # guard against recursion
        self._memo[name] = cost
        for op in ops:
            oc = op.opcode
            if oc == "dot":
                cost["flops"] += self._dot_flops(op, symbols)
                cost["bytes"] += self._io_bytes(op, symbols)
            elif oc == "convolution":
                cost["flops"] += self._conv_flops(op, symbols)
                cost["bytes"] += self._io_bytes(op, symbols)
            elif oc == "fusion":
                called = self._called(op, ("calls",))
                for c in called:
                    sub = self.computation_cost(c)
                    cost["flops"] += sub["flops"]
                    for k, v in sub["collectives"].items():
                        cost["collectives"][k] += v
                    for k, v in sub["cross_pod"].items():
                        cost["cross_pod"][k] += v
                # fusion boundary = HBM traffic; operands that are only
                # dynamic-sliced inside the fusion count as the slice
                cost["bytes"] += self._fusion_io_bytes(op, symbols, called)
            elif oc == "while":
                body = self._called(op, ("body",))
                # XLA records the trip count on the op itself
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.line)
                if mt:
                    trips = int(mt.group(1))
                else:
                    cond = self._called(op, ("condition",))
                    trips = self._trip_count(cond[0]) if cond else 1
                for c in body:
                    sub = self.computation_cost(c)
                    cost["flops"] += trips * sub["flops"]
                    cost["bytes"] += trips * sub["bytes"]
                    for k, v in sub["collectives"].items():
                        cost["collectives"][k] += trips * v
                    for k, v in sub["cross_pod"].items():
                        cost["cross_pod"][k] += trips * v
            elif oc in ("call", "custom-call", "conditional"):
                for c in self._called(op, ("to_apply", "calls",
                                           "branch_computations",
                                           "true_computation",
                                           "false_computation")):
                    sub = self.computation_cost(c)
                    cost["flops"] += sub["flops"]
                    cost["bytes"] += sub["bytes"]
                    for k, v in sub["collectives"].items():
                        cost["collectives"][k] += v
                    for k, v in sub["cross_pod"].items():
                        cost["cross_pod"][k] += v
            elif any(oc.startswith(c) for c in _COLLECTIVES):
                if oc.endswith("-done"):
                    continue
                base = next(c for c in _COLLECTIVES if oc.startswith(c))
                nbytes = _numel_bytes(op.typestr)
                cost["collectives"][base] += nbytes
                if self.pod_boundary and _is_cross_pod(op.line,
                                                       self.pod_boundary):
                    cost["cross_pod"][base] += nbytes
                cost["bytes"] += self._io_bytes(op, symbols)
            elif oc in _ELEMENTWISE_FLOP_OPS:
                cost["flops"] += sum(
                    1 for _ in [0]) * self._result_elems(op)
                cost["bytes"] += self._io_bytes(op, symbols)
            elif oc in ("reduce", "reduce-window"):
                cost["flops"] += self._result_elems(op)
                cost["bytes"] += self._io_bytes(op, symbols)
            else:
                # data movement ops: copy, transpose, broadcast, reshape...
                cost["bytes"] += self._io_bytes(op, symbols)
        self._memo[name] = cost
        return cost

    def _result_elems(self, op: _Op) -> float:
        dims = _shape_dims(op.typestr)
        n = 1
        for d in dims:
            n *= d
        return float(n)

    _NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id"}

    def _io_bytes(self, op: _Op, symbols: dict[str, str]) -> float:
        """HBM traffic model: result write + operand reads, with
        slice-aware exceptions (a dynamic-slice reads only the slice; a
        dynamic-update-slice touches 2x the update window)."""
        if op.opcode in self._NO_TRAFFIC:
            return 0.0
        if op.opcode == "dynamic-slice":
            return 2.0 * _numel_bytes(op.typestr)
        if op.opcode == "dynamic-update-slice":
            ops_ = self._operand_names(op)
            upd = symbols.get(ops_[1]) if len(ops_) > 1 else None
            return 2.0 * _numel_bytes(upd or op.typestr)
        total = _numel_bytes(op.typestr)
        for raw in self._operands_raw(op):
            name = self._operand_name(raw)
            if name and name[0].isdigit():
                continue  # literal operand
            t = symbols.get(name)
            total += _numel_bytes(t if t else raw)
        return float(total)

    def _fusion_io_bytes(self, op: _Op, symbols: dict[str, str],
                         called: list[str]) -> float:
        total = float(_numel_bytes(op.typestr))
        operands = self._operands_raw(op)
        # map fused-computation parameter index -> effective read bytes
        slice_reads: dict[int, float] = {}
        for c in called:
            ops = self.computations.get(c, [])
            fsyms = {o.name: o.typestr for o in ops}
            param_idx: dict[str, int] = {}
            for o in ops:
                if o.opcode == "parameter":
                    mi = re.search(r"parameter\((\d+)\)", o.line)
                    if mi:
                        param_idx[o.name] = int(mi.group(1))
            uses: dict[str, list[_Op]] = defaultdict(list)
            for o in ops:
                for name in self._operand_names(o):
                    if name in param_idx:
                        uses[name].append(o)
            for pname, idx in param_idx.items():
                us = uses.get(pname, [])
                if us and all(u.opcode in ("dynamic-slice",
                                           "dynamic-update-slice")
                              for u in us):
                    slice_reads[idx] = sum(
                        2.0 * _numel_bytes(
                            fsyms.get(self._operand_names(u)[1], u.typestr)
                            if u.opcode == "dynamic-update-slice"
                            else u.typestr)
                        for u in us)
        for i, raw in enumerate(operands):
            if i in slice_reads:
                total += slice_reads[i]
                continue
            t = symbols.get(self._operand_name(raw))
            total += _numel_bytes(t if t else raw)
        return total

    @staticmethod
    def _called(op: _Op, keys: tuple[str, ...]) -> list[str]:
        out = []
        for key in keys:
            # brace form: calls={%a, %b}; plain form: body=%name
            mb = re.search(key + r"=\{([^}]*)\}", op.line)
            if mb:
                out.extend(n.strip().lstrip("%")
                           for n in mb.group(1).split(",") if n.strip())
                continue
            m = re.search(key + r"=%?([\w.\-]+)", op.line)
            if m:
                out.append(m.group(1))
        return out

    # -- public -------------------------------------------------------------
    def totals(self) -> dict:
        if not self.entry:
            return {"flops": 0.0, "bytes": 0.0, "collectives": {},
                    "cross_pod": {}}
        c = self.computation_cost(self.entry)
        return {"flops": c["flops"], "bytes": c["bytes"],
                "collectives": dict(c["collectives"]),
                "cross_pod": dict(c["cross_pod"])}

    def top_contributors(self, metric: str = "bytes", n: int = 15,
                         _comp: str | None = None, _scale: float = 1.0,
                         _acc: dict | None = None) -> list[tuple[float, str]]:
        """Top-n individual ops by trip-scaled flops/bytes — the profile
        view used by the §Perf hillclimbs (what to optimize first)."""
        root = _comp or self.entry
        acc = _acc if _acc is not None else {}
        ops = self.computations.get(root, [])
        symbols = {op.name: op.typestr for op in ops}
        for op in ops:
            oc = op.opcode
            if oc == "while":
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.line)
                trips = int(mt.group(1)) if mt else 1
                for c in self._called(op, ("body",)):
                    self.top_contributors(metric, n, c, _scale * trips, acc)
            elif oc in ("call", "conditional", "fusion"):
                keys = ("calls", "to_apply", "true_computation",
                        "false_computation", "branch_computations")
                if oc == "fusion" and metric == "bytes":
                    val = self._fusion_io_bytes(
                        op, symbols, self._called(op, ("calls",)))
                    key = _short(op.line) or op.opcode
                    acc[key] = acc.get(key, 0.0) + val * _scale
                    if metric == "bytes":
                        continue
                for c in self._called(op, keys):
                    self.top_contributors(metric, n, c, _scale, acc)
            else:
                if metric == "flops":
                    if oc == "dot":
                        val = self._dot_flops(op, symbols)
                    elif oc == "convolution":
                        val = self._conv_flops(op, symbols)
                    else:
                        continue
                else:
                    val = self._io_bytes(op, symbols)
                if val:
                    key = _short(op.line) or op.opcode
                    acc[key] = acc.get(key, 0.0) + val * _scale
        if _acc is not None:
            return []
        return sorted(((v, k) for k, v in acc.items()), reverse=True)[:n]


def _short(line: str) -> str:
    """op_name metadata (jax source op) + result type, for attribution."""
    m = re.search(r'op_name="([^"]+)"', line)
    t = _SHAPE_RE.search(line)
    ty = f"{t.group(1)}[{t.group(2)}]" if t else "?"
    if m:
        name = m.group(1)
        if len(name) > 90:
            name = "..." + name[-87:]
        return f"{name} {ty}"
    mo = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)", line)
    return f"{mo.group(1) if mo else '?'} {ty}"


def analyze_hlo(hlo_text: str) -> dict:
    return HloCostModel(hlo_text).totals()

"""Online-learning launcher: train and serve in ONE process — the
paper-faithful "continuously retrain on streaming stock data while
serving forecasts" scenario (ROADMAP north-star, unlocked by the
hot-swap bridge in ``repro.serving.hotswap``).

A background thread runs the async local-SGD round loop over
``data/sp500.py`` windows; after every cross-worker model exchange the
round's worker-averaged parameters are published into the live
``ModelRegistry`` (EVT tail re-calibrated on the new weights), and the
serving engine picks the new version up between micro-batch flushes —
no request is ever dropped by a weight update. The foreground thread
plays client traffic against the engine the whole time and reports
swap count, staleness at serve time, and per-version request counts.

With ``--shards N`` the serving side is the sharded mesh: the publisher
publishes into the swap-propagation swarm's primary registry and every
shard's replica pulls the new weights within ``--max-skew`` versions,
while all shards keep draining traffic. With ``--processes`` the mesh
shards are separate OS processes behind the socket transport
(``repro.serving.transport``): each publish ships a serialized
checkpoint to every worker under the same skew bound.

    PYTHONPATH=src python -m repro.launch.online --ticker AAPL \
        --workers 3 --iterations 600 --requests 400

    PYTHONPATH=src python -m repro.launch.online --shards 4 \
        --iterations 300 --requests 200

    PYTHONPATH=src python -m repro.launch.online --shards 2 --processes \
        --iterations 200 --requests 100
"""

from __future__ import annotations

import argparse
import threading
import time

import numpy as np


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticker", default="AAPL")
    ap.add_argument("--days", type=int, default=800)
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--iterations", type=int, default=600)
    ap.add_argument("--tau", type=int, default=0)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--requests", type=int, default=400,
                    help="minimum client requests to play against the "
                    "engine; traffic keeps flowing until training ends")
    ap.add_argument("--rps", type=float, default=100.0,
                    help="client traffic rate (requests/s), paced so the "
                    "trace spans the whole training run")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--shards", type=int, default=1,
                    help="serve through a sharded mesh with this many "
                    "EngineShard workers (1 = single engine)")
    ap.add_argument("--processes", action="store_true",
                    help="with --shards > 1: one OS process per shard "
                    "over the socket transport")
    ap.add_argument("--max-skew", type=int, default=1,
                    help="mesh staleness bound: versions a shard may lag "
                    "the primary before a publish forces its pull")
    ap.add_argument("--min-publish-interval-ms", type=float, default=0.0,
                    help="rate-limit weight publishes (0 = every round)")
    ap.add_argument("--calib-windows", type=int, default=64,
                    help="reference windows for per-publish EVT "
                    "re-calibration (0 disables re-calibration)")
    ap.add_argument("--save", default=None, metavar="PATH",
                    help="save the final published version as a serving "
                    "checkpoint on exit")
    ap.add_argument("--evl-weight", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-port", type=int, default=None,
                    metavar="PORT",
                    help="serve /metrics (Prometheus), /metrics.json and "
                    "/history on this port while training + serving run "
                    "(0 = ephemeral; fleet-merged view on a mesh) — the "
                    "live time-series view of serve-under-churn")
    args = ap.parse_args(argv)

    from repro.configs.paper_lstm import CONFIG
    from repro.data import load_stock, make_windows, train_test_split
    from repro.models.rnn import init_rnn
    from repro.serving import (BatcherConfig, LSTMForecaster, ModelRegistry,
                               MultiProcessServingEngine, ServingEngine,
                               ShardedServingEngine, Telemetry,
                               WeightPublisher)
    from repro.training.loop import train_rnn_local_sgd

    import jax

    ohlcv = load_stock(args.ticker, n_days=args.days, seed=args.seed)
    tr, te = train_test_split(ohlcv)
    train_ds, test_ds = make_windows(tr), make_windows(te)
    print(f"{args.ticker}: {len(train_ds)} train windows feeding the "
          f"trainer, {len(test_ds)} test windows as client traffic")

    # v1: freshly initialized paper model, calibrated on the train set —
    # what a cold-started service would host before training catches up
    key = "paper-lstm"
    fc0 = LSTMForecaster(cfg=CONFIG,
                         params=init_rnn(jax.random.PRNGKey(args.seed),
                                         CONFIG))
    fc0.calibrate(train_ds.x[:max(args.calib_windows, 16)])
    registry = ModelRegistry()
    registry.register(key, fc0)

    calib = (train_ds.x[:args.calib_windows]
             if args.calib_windows else None)
    bcfg = BatcherConfig(max_batch=args.max_batch,
                         max_wait_ms=args.max_wait_ms,
                         length_buckets=(CONFIG.window,))
    mesh = args.shards > 1
    if mesh and args.processes:
        engine = MultiProcessServingEngine(registry, bcfg,
                                           n_shards=args.shards,
                                           max_skew=args.max_skew)
        # publish through the mesh facade: each publish ships a
        # serialized checkpoint to every worker process under the
        # skew bound, atomically with the primary swap
        publish_target, pub_telemetry = engine, None
    elif mesh:
        engine = ShardedServingEngine(registry, bcfg,
                                      n_shards=args.shards,
                                      max_skew=args.max_skew)
        # publish into the swarm: the primary swap fans out to every
        # shard's replica within the skew bound (pulls count as swaps
        # on each shard's telemetry, so no publisher telemetry here)
        publish_target, pub_telemetry = engine.swarm, None
    else:
        engine = ServingEngine(registry, bcfg)
        publish_target, pub_telemetry = registry, engine.telemetry
    publisher = WeightPublisher(
        publish_target, key, calib_windows=calib,
        min_interval_s=args.min_publish_interval_ms * 1e-3,
        telemetry=pub_telemetry)

    metrics = None
    if args.metrics_port is not None:
        from repro.obs import MetricsServer

        snapshot_fn = (engine.snapshot if mesh
                       else lambda: engine.telemetry.snapshot())
        metrics = MetricsServer(snapshot_fn, port=args.metrics_port,
                                sample_interval_s=0.5).start()
        print(f"metrics: {metrics.url}/metrics (also /metrics.json, "
              f"/history)")

    trainer_err: list[BaseException] = []

    def train() -> None:
        try:
            train_rnn_local_sgd(
                train_ds, test_ds, n_workers=args.workers,
                iterations=args.iterations, batch=args.batch,
                tau=args.tau, seed=args.seed, evl_weight=args.evl_weight,
                round_callback=publisher)
        except BaseException as e:  # noqa: BLE001 — surfaced after join
            trainer_err.append(e)

    with engine:
        engine.warmup(key, lengths=(CONFIG.window,))
        if mesh:
            engine.reset_clock()
        else:
            engine.telemetry.reset_clock()
        trainer = threading.Thread(target=train, name="online-trainer")
        t0 = time.time()
        trainer.start()
        served = 0
        alerts = 0
        burst = max(1, min(args.max_batch, 8))
        period = burst / max(args.rps, 1e-3)
        next_t = time.perf_counter()
        while trainer.is_alive() or served < args.requests:
            now = time.perf_counter()
            if now < next_t:
                time.sleep(min(next_t - now, 0.05))
                continue
            futs = [engine.submit(key, test_ds.x[(served + j) % len(test_ds)],
                                  client_id=f"client-{(served + j) % 32}")
                    for j in range(burst)]
            for f in futs:
                _, p = f.result(timeout=60.0)
                alerts += p >= 0.9
            served += burst
            next_t += period
            if next_t < time.perf_counter() - 1.0:
                next_t = time.perf_counter()   # engine slower than --rps:
                # shed schedule debt instead of bursting to catch up
        trainer.join()
        # a rate-limited final round must still reach the registry: the
        # served (and --save'd) model is never staler than the trained one
        publisher.flush()
        if mesh:
            # shards converge to the final version before the engine
            # stops (swarm pulls in-process, checkpoint pushes across)
            (engine if args.processes else engine.swarm).propagate(key)
        wall = time.time() - t0
        snap = engine.snapshot() if mesh else engine.telemetry.snapshot()
    if metrics is not None:
        metrics.stop()
    if trainer_err:
        raise trainer_err[0]

    print(f"served {served} requests ({alerts} extreme alerts) while "
          f"training ran, {wall:.1f}s wall"
          + (f" over {args.shards} shards" if mesh else ""))
    print(Telemetry.format(snap))
    if mesh:
        print(f"mesh: requests by shard {snap['requests_by_shard']} | "
              f"{snap['pulls']} weight pulls "
              f"({snap['bytes_pulled']/1e6:.2f} MB) | version vector "
              f"{engine.version_vector(key)} | max skew bound "
              f"{args.max_skew}")
    by_version = snap["requests_by_version"]
    print(f"swaps {snap['swaps']} (publisher: {publisher.published} "
          f"published, {publisher.skipped} rate-limited) | final version "
          f"v{registry.version(key)} | staleness at serve p50 "
          f"{snap['staleness_p50_s']*1e3:.0f} ms")
    print("requests by version: "
          + ", ".join(f"v{v}: {n}" for v, n in sorted(by_version.items())))
    if args.save:
        registry.save(key, args.save)
        print(f"saved v{registry.version(key)} -> {args.save}")


if __name__ == "__main__":
    main()

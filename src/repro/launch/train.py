"""Training launcher.

Two modes:
  * ``--arch paper-lstm`` (default): the paper's experiment — async local
    SGD on stock windows, n workers, linear schedule (runs on host CPU).
  * ``--arch <zoo id>``: train a (reduced or full) transformer config on
    synthetic tokens on whatever devices exist, using the same local-SGD
    round machinery (workers = data shards of the host mesh).

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch paper-lstm \
        --workers 5 --iterations 2000
    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b \
        --reduced --steps 50
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def run_paper_lstm(args, round_callback=None):
    """Paper experiment. ``round_callback(round_idx, avg_params)`` — when
    given — receives every round's worker-averaged parameters as they are
    produced (the online-learning hook ``repro.launch.online`` uses to
    hot-swap weights into a live serving engine); the final model is no
    longer the only artifact the loop emits. Returns the TrainResult."""
    from repro.core.schedules import ConstantSchedule, SampleSchedule
    from repro.data import load_stock, make_windows, train_test_split
    from repro.training.loop import train_rnn_local_sgd, train_rnn_serial

    ohlcv = load_stock(args.ticker, n_days=args.days, seed=args.seed)
    tr, te = train_test_split(ohlcv)
    train_ds, test_ds = make_windows(tr), make_windows(te)
    print(f"{args.ticker}: {len(train_ds)} train / {len(test_ds)} test "
          f"windows; extreme fraction "
          f"{float(np.mean(train_ds.v != 0)):.3f}")

    t0 = time.time()
    if args.workers <= 1:
        res = train_rnn_serial(train_ds, test_ds,
                               iterations=args.iterations,
                               batch=args.batch, seed=args.seed,
                               evl_weight=args.evl_weight)
    else:
        schedule = (ConstantSchedule(size=args.constant_rounds)
                    if args.constant_rounds else SampleSchedule())
        res = train_rnn_local_sgd(
            train_ds, test_ds, n_workers=args.workers,
            iterations=args.iterations, batch=args.batch,
            schedule=schedule, tau=args.tau, seed=args.seed,
            evl_weight=args.evl_weight, round_callback=round_callback)
    dt = time.time() - t0
    print(f"done in {dt:.1f}s: test MSE {res.test_mse:.5f}, "
          f"iterations {res.iterations}, communications "
          f"{res.communications}, comm bytes {res.comm_bytes/1e6:.2f} MB")
    if res.test_extreme:
        print("extreme-event:", res.test_extreme)
    if getattr(args, "save", None):
        _save_serving_checkpoint(args.save, res, train_ds)
    return res


def _save_serving_checkpoint(path: str, res, train_ds) -> None:
    """Persist the trained model as a *serving* checkpoint: EVT-calibrated
    forecaster + model-version metadata (the version is the number of
    cross-worker exchanges that produced the weights, so a registry that
    later loads it slots into the monotone version sequence)."""
    from repro.configs.paper_lstm import CONFIG
    from repro.serving import LSTMForecaster, ModelRegistry

    fc = LSTMForecaster(cfg=CONFIG, params=res.params)
    fc.calibrate(train_ds.x)
    reg = ModelRegistry()
    reg.register("trained", fc, version=max(res.communications, 1))
    reg.save("trained", path)
    print(f"saved serving checkpoint v{reg.version('trained')} -> {path}")


def run_zoo(args) -> None:
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.data.tokens import synthetic_token_batch
    from repro.launch.specs import make_train_step
    from repro.models import transformer as tfm

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model_params = tfm.init_lm(cfg, jax.random.PRNGKey(args.seed))
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(model_params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params")
    step, opt = make_train_step(cfg, lr=args.lr)
    opt_state = opt.init(model_params)
    jstep = jax.jit(step)

    losses = []
    for i in range(args.steps):
        toks = jnp.asarray(synthetic_token_batch(
            args.batch, args.seq, cfg.vocab, seed=args.seed + i))
        frames = None
        if cfg.family == "audio":
            from repro.data.tokens import synthetic_embedding_batch
            frames = jnp.asarray(synthetic_embedding_batch(
                args.batch, cfg.n_frames, cfg.d_model, seed=i))
            model_params, opt_state, loss = jstep(model_params, opt_state,
                                                  toks, frames)
        else:
            model_params, opt_state, loss = jstep(model_params, opt_state,
                                                  toks)
        losses.append(float(loss))
        if i % max(1, args.steps // 10) == 0:
            print(f"step {i}: loss {losses[-1]:.4f}", flush=True)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    assert np.isfinite(losses[-1])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-lstm")
    ap.add_argument("--ticker", default="AAPL")
    ap.add_argument("--days", type=int, default=1430)
    ap.add_argument("--iterations", type=int, default=2000)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--tau", type=int, default=0)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--evl-weight", type=float, default=0.0)
    ap.add_argument("--constant-rounds", type=int, default=0,
                    help="use constant local-SGD schedule of this size")
    ap.add_argument("--save", default=None, metavar="PATH",
                    help="save the trained paper model as a serving "
                    "checkpoint (EVT-calibrated, version metadata)")
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()
    if args.arch == "paper-lstm":
        run_paper_lstm(args)
    else:
        run_zoo(args)


if __name__ == "__main__":
    main()

"""Prediction metrics for the paper's experiments."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def mse(pred, target):
    return float(jnp.mean(jnp.square(jnp.asarray(pred) - jnp.asarray(target))))


def rmse(pred, target):
    return float(np.sqrt(mse(pred, target)))


def extreme_event_metrics(u_pred, v_true, threshold: float = 0.5) -> dict:
    """Precision / recall / F1 for the (right-)extreme-event indicator head.
    v_true in {-1, 0, 1} is binarized to |v| (any extreme)."""
    u = np.asarray(u_pred) >= threshold
    v = np.abs(np.asarray(v_true)) > 0
    tp = int(np.sum(u & v))
    fp = int(np.sum(u & ~v))
    fn = int(np.sum(~u & v))
    precision = tp / max(tp + fp, 1)
    recall = tp / max(tp + fn, 1)
    f1 = 2 * precision * recall / max(precision + recall, 1e-12)
    return {"precision": precision, "recall": recall, "f1": f1,
            "tp": tp, "fp": fp, "fn": fn, "n_extreme": int(np.sum(v))}

from repro.training.loop import TrainResult, train_rnn_serial, train_rnn_local_sgd
from repro.training.metrics import extreme_event_metrics, mse, rmse

__all__ = [
    "TrainResult",
    "extreme_event_metrics",
    "mse",
    "rmse",
    "train_rnn_local_sgd",
    "train_rnn_serial",
]

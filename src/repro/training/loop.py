"""Training loops for the paper's stock-prediction experiments.

- ``train_rnn_serial``: single-node baseline (paper's reference point).
- ``train_rnn_local_sgd``: the proposed framework (n workers, linearly
  increasing rounds, model exchange, optional staleness) via
  ``repro.core.AsyncLocalSGD``.

Both share the same loss construction: MSE on the next-step prediction,
optionally + EVL on the extreme-indicator head, optionally per-sample
weights (the "evl" resampling strategy).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.async_local_sgd import AsyncLocalSGD, LocalSGDConfig
from repro.core.schedules import SampleSchedule, StepSizeSchedule
from repro.data.sharding import client_splits
from repro.data.windows import WindowDataset
from repro.extreme.evl import evl_loss
from repro.extreme.indicators import extreme_fractions
from repro.models.rnn import RNNConfig, init_rnn, rnn_apply
from repro.optim.optimizers import Optimizer, apply_updates, sgd
from repro.training.metrics import extreme_event_metrics, mse

PyTree = Any


@dataclasses.dataclass
class TrainResult:
    params: PyTree
    loss_history: list
    test_mse: float
    test_extreme: dict
    communications: int
    iterations: int
    comm_bytes: int = 0


def make_loss_fn(cfg: RNNConfig, evl_weight: float = 0.0,
                 beta0: float = 0.95, beta1: float = 0.05,
                 gamma: float = 2.0, l2: float = 0.0):
    """batch = (x, y, v, w): windows, targets, indicators, sample weights."""

    def loss_fn(params, batch):
        x, y, v, w = batch
        pred, u = rnn_apply(params, x, cfg)
        per = jnp.square(pred - y)
        loss = jnp.mean(per * w)
        if evl_weight > 0.0 and u is not None:
            vbin = (jnp.abs(v) > 0).astype(jnp.float32)
            loss = loss + evl_weight * evl_loss(u, vbin, beta0, beta1, gamma)
        if l2 > 0.0:
            sq = sum(jnp.sum(jnp.square(p))
                     for p in jax.tree_util.tree_leaves(params))
            loss = loss + 0.5 * l2 * sq
        return loss

    return loss_fn


def _batch_arrays(ds: WindowDataset, idx: np.ndarray, weights=None):
    w = (weights[idx] if weights is not None
         else np.ones(len(idx), np.float32))
    return (ds.x[idx], ds.y[idx], ds.v.astype(np.float32)[idx], w)


def _stack_batches(ds, order, pos, n, batch, weights=None):
    """n consecutive batches starting at cursor pos (wrapping)."""
    out = []
    for i in range(n):
        start = (pos + i * batch) % max(len(order) - batch, 1)
        out.append(_batch_arrays(ds, order[start:start + batch], weights))
    return tuple(np.stack([b[i] for b in out]) for i in range(4))


def evaluate(params, cfg: RNNConfig, ds: WindowDataset) -> tuple[float, dict]:
    pred, u = rnn_apply(params, jnp.asarray(ds.x), cfg)
    test_mse = mse(pred, ds.y)
    ext = (extreme_event_metrics(np.asarray(u), ds.v)
           if u is not None else {})
    return test_mse, ext


def train_rnn_serial(train_ds: WindowDataset, test_ds: WindowDataset,
                     cfg: RNNConfig | None = None, iterations: int = 2000,
                     batch: int = 32, optimizer: Optimizer | None = None,
                     stepsize: StepSizeSchedule | None = None,
                     evl_weight: float = 0.0, weights=None,
                     seed: int = 0) -> TrainResult:
    """Single-compute-node baseline: plain SGD with the paper's
    diminishing step size."""
    cfg = cfg or RNNConfig()
    stepsize = stepsize or StepSizeSchedule()
    fr = extreme_fractions(train_ds.v)
    loss_fn = make_loss_fn(cfg, evl_weight, beta0=fr["normal"],
                           beta1=max(fr["right"] + fr["left"], 1e-3))
    opt = optimizer or sgd(momentum=0.0)
    params = init_rnn(jax.random.PRNGKey(seed), cfg)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch_data, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch_data)
        upd, opt_state = opt.update(grads, opt_state, params, lr)
        return apply_updates(params, upd), opt_state, loss

    rng = np.random.default_rng(seed)
    order = np.arange(len(train_ds))
    rng.shuffle(order)
    losses = []
    pos = 0
    for t in range(iterations):
        if pos + batch > len(order):
            rng.shuffle(order)
            pos = 0
        b = _batch_arrays(train_ds, order[pos:pos + batch], weights)
        pos += batch
        params, opt_state, loss = step(params, opt_state, b,
                                       float(stepsize(t)))
        losses.append(float(loss))

    test_mse, ext = evaluate(params, cfg, test_ds)
    return TrainResult(params=params, loss_history=losses, test_mse=test_mse,
                       test_extreme=ext, communications=0,
                       iterations=iterations)


def train_rnn_local_sgd(train_ds: WindowDataset, test_ds: WindowDataset,
                        n_workers: int = 2, cfg: RNNConfig | None = None,
                        iterations: int = 2000, batch: int = 32,
                        schedule: SampleSchedule | None = None,
                        stepsize: StepSizeSchedule | None = None,
                        optimizer: Optimizer | None = None,
                        tau: int = 0, split: str = "iid",
                        evl_weight: float = 0.0, seed: int = 0,
                        round_callback=None) -> TrainResult:
    """The paper's framework on the stacked-worker SPMD path.

    ``round_callback(round_idx, avg_params)`` — when given — is invoked
    after every cross-worker exchange with the worker-averaged (single
    model) parameters of that round. This is the online-learning hook: a
    ``repro.serving.WeightPublisher`` passed here hot-swaps each round's
    average into a live serving engine (``repro.launch.online``)."""
    cfg = cfg or RNNConfig()
    fr = extreme_fractions(train_ds.v)
    loss_fn = make_loss_fn(cfg, evl_weight, beta0=fr["normal"],
                           beta1=max(fr["right"] + fr["left"], 1e-3))
    opt = optimizer or sgd(momentum=0.0)
    lcfg = LocalSGDConfig(
        n_workers=n_workers, tau=tau,
        schedule=schedule or SampleSchedule(),
        stepsize=stepsize or StepSizeSchedule())
    trainer = AsyncLocalSGD(loss_fn, opt, lcfg)
    params = init_rnn(jax.random.PRNGKey(seed), cfg)
    stacked, opt_state = trainer.init(params)

    splits = client_splits(len(train_ds), n_workers, mode=split, seed=seed)
    rng = np.random.default_rng(seed)
    orders = [s.copy() for s in splits]
    for o in orders:
        rng.shuffle(o)
    cursors = [0] * n_workers

    round_i = 0
    while trainer.iterations_done < iterations:
        round_i += 1
        h = trainer.local_steps_for_round(round_i)
        per_worker = []
        for wkr in range(n_workers):
            bw = _stack_batches(train_ds, orders[wkr], cursors[wkr], h, batch)
            cursors[wkr] = (cursors[wkr] + h * batch) % max(
                len(orders[wkr]) - batch, 1)
            per_worker.append(bw)
        batches = tuple(np.stack([pw[i] for pw in per_worker])
                        for i in range(4))
        stacked, opt_state, _ = trainer.run_round(stacked, opt_state, batches)
        if round_callback is not None:
            from repro.core.async_local_sgd import worker_mean
            round_callback(round_i, worker_mean(stacked))

    final = jax.tree.map(lambda a: a[0], stacked)
    test_mse, ext = evaluate(final, cfg, test_ds)
    return TrainResult(params=final, loss_history=trainer.loss_history,
                       test_mse=test_mse, test_extreme=ext,
                       communications=trainer.communications,
                       iterations=trainer.iterations_done,
                       comm_bytes=trainer.communication_bytes(stacked))

"""Metrics export: Prometheus text exposition, JSONL event logs, and a
tiny stdlib HTTP endpoint serving both — the data source for the
ROADMAP's telemetry-driven autoscaler and canary comparator.

- ``render_prometheus(snapshot)`` flattens a ``Telemetry.snapshot()`` /
  ``merge()`` dict (or any numeric dict) into the text exposition
  format: scalars become gauges, ``*_by_<label>`` dicts/lists become
  labeled series.
- ``EventLog`` is a bounded ring of timestamped JSON events with an
  optional append-to-file mirror — the serving CLIs log phase markers
  and periodic snapshots into it, and ``tools/report.py`` renders the
  resulting JSONL into a per-phase summary table.
- ``MetricsServer`` serves ``/metrics`` (Prometheus), ``/metrics.json``
  (raw snapshot), ``/history`` (the sampled time series), ``/traces``
  (the tracer's completed ring) and ``/events`` (the JSONL log) from a
  daemon ``ThreadingHTTPServer`` — ``--metrics-port`` on the launch
  CLIs; on a mesh the snapshot callable is the merged fleet view.

Everything here is stdlib-only and off the serving hot path: rendering
happens per scrape, sampling on its own thread.
"""

from __future__ import annotations

import json
import re
import threading
import time
from collections import deque
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _metric_name(prefix: str, key: str) -> str:
    return _NAME_RE.sub("_", f"{prefix}_{key}")


def _fmt_labels(labels: dict | None) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_NAME_RE.sub("_", str(k))}="{v}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _label_for(key: str) -> str:
    # requests_by_version -> "version", requests_by_shard -> "shard";
    # anything else labels by the generic "key"
    m = re.search(r"_by_([a-z0-9]+)$", key)
    return m.group(1) if m else "key"


def render_prometheus(snapshot: dict, prefix: str = "repro",
                      labels: dict | None = None) -> str:
    """One snapshot as Prometheus text exposition. Scalars (int, float,
    bool) become gauges; dict values one labeled series per entry; list
    values one series per index (labeled by ``_by_<x>`` when the key
    names one). Non-numeric values are skipped."""
    base = _fmt_labels(labels)
    lines: list[str] = []

    def emit(name: str, value, extra: dict | None = None) -> None:
        if isinstance(value, bool):
            value = int(value)
        if not isinstance(value, (int, float)):
            return
        lab = dict(labels or {})
        if extra:
            lab.update(extra)
        lines.append(f"{name}{_fmt_labels(lab) if lab else base} "
                     f"{float(value):g}")

    for key in sorted(snapshot):
        value = snapshot[key]
        name = _metric_name(prefix, key)
        if isinstance(value, dict):
            lines.append(f"# TYPE {name} gauge")
            label = _label_for(key)
            for k in sorted(value, key=str):
                emit(name, value[k], {label: k})
        elif isinstance(value, (list, tuple)):
            lines.append(f"# TYPE {name} gauge")
            label = _label_for(key)
            for i, v in enumerate(value):
                emit(name, v, {label: i})
        elif isinstance(value, (bool, int, float)):
            lines.append(f"# TYPE {name} gauge")
            emit(name, value)
    return "\n".join(lines) + "\n"


class EventLog:
    """Bounded ring of timestamped events, optionally mirrored to a
    JSONL file (append-only, flushed per event — the log must survive a
    crash of the process it is diagnosing)."""

    def __init__(self, capacity: int = 4096, path: str | None = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self._lock = threading.Lock()
        self._events: deque[dict] = deque(maxlen=capacity)
        self._file = open(path, "a") if path else None
        self.path = path

    def log(self, kind: str, **fields) -> dict:
        event = {"ts": time.time(), "kind": kind, **fields}
        with self._lock:
            self._events.append(event)
            if self._file is not None:
                self._file.write(json.dumps(event) + "\n")
                self._file.flush()
        return event

    def events(self, n: int | None = None) -> list[dict]:
        with self._lock:
            out = list(self._events)
        return out if n is None else out[-n:]

    def lines(self) -> str:
        return "".join(json.dumps(e) + "\n" for e in self.events())

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None


class MetricsServer:
    """Stdlib HTTP endpoint over a snapshot callable.

    ``snapshot_fn`` is whatever produces the current metrics dict —
    ``engine.telemetry.snapshot`` for one engine, ``engine.snapshot``
    for a mesh (the merged fleet view). ``history_fn`` serves the
    sampled time series (``Telemetry.history`` for one engine); when
    omitted but ``sample_interval_s`` is set, the server samples
    ``snapshot_fn`` itself on a daemon thread. ``tracer`` and ``events``
    expose the trace ring and the event log when given."""

    def __init__(self, snapshot_fn, host: str = "127.0.0.1",
                 port: int = 0, prefix: str = "repro",
                 labels: dict | None = None, tracer=None,
                 history_fn=None, events: EventLog | None = None,
                 sample_interval_s: float | None = None,
                 history_capacity: int = 512):
        self.snapshot_fn = snapshot_fn
        self.host = host
        self.port = port
        self.prefix = prefix
        self.labels = labels
        self.tracer = tracer
        self.events = events
        self._history_fn = history_fn
        self._history: deque[dict] = deque(maxlen=history_capacity)
        self._interval = sample_interval_s
        self._httpd: ThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None
        self._sampler: threading.Thread | None = None
        self._stop = threading.Event()

    # -- content -----------------------------------------------------------
    def history(self) -> list[dict]:
        if self._history_fn is not None:
            return list(self._history_fn())
        return list(self._history)

    def _sample_loop(self) -> None:
        while not self._stop.wait(self._interval):
            try:
                snap = dict(self.snapshot_fn())
                snap["ts"] = time.time()
                self._history.append(snap)
            except Exception:  # noqa: BLE001 — sampling must not kill serving
                pass

    def _routes(self) -> dict:
        return {
            "/metrics": lambda: ("text/plain; version=0.0.4",
                                 render_prometheus(self.snapshot_fn(),
                                                   self.prefix,
                                                   self.labels)),
            "/metrics.json": lambda: (
                "application/json", json.dumps(self.snapshot_fn())),
            "/history": lambda: (
                "application/json", json.dumps(self.history())),
            "/traces": lambda: ("application/json", json.dumps(
                [t.to_dict() for t in self.tracer.traces()]
                if self.tracer is not None else [])),
            "/events": lambda: (
                "application/x-ndjson",
                self.events.lines() if self.events is not None else ""),
        }

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "MetricsServer":
        if self._httpd is not None:
            return self
        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 — stdlib API name
                route = server._routes().get(self.path.split("?")[0])
                if route is None:
                    self.send_error(404)
                    return
                try:
                    ctype, body = route()
                except Exception as e:  # noqa: BLE001 — scrape, not serving
                    self.send_error(500, f"{type(e).__name__}: {e}")
                    return
                data = body.encode()
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def log_message(self, *args):  # quiet: scrapes are not news
                pass

        self._httpd = ThreadingHTTPServer((self.host, self.port), Handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="metrics-http", daemon=True)
        self._thread.start()
        if self._interval is not None and self._history_fn is None:
            self._stop.clear()
            self._sampler = threading.Thread(target=self._sample_loop,
                                             name="metrics-sampler",
                                             daemon=True)
            self._sampler.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._sampler is not None:
            self._sampler.join()
            self._sampler = None
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

"""Per-request trace spans for the serving stack: stdlib-only,
thread-safe, cheap enough for the flush hot path.

A trace is born at ``Tracer.start`` (one per request), accumulates
spans as the request moves ``submit -> queue -> flush -> gather ->
dispatch -> scatter -> reply``, and is ``finish``-ed into a bounded
ring of completed traces. Spans are plain (t0, t1) wall-clock pairs —
``now()`` is a monotonic ``perf_counter`` anchored to the epoch once at
import.

The recording path is built around the same amortization as the engine
it observes:

- One object, no registry, no lock: ``Trace`` is both the span store
  and the context handle threaded alongside the request (``start`` is a
  single allocation), and a request has exactly ONE writer at any time
  — the submitter records nothing after the enqueue (even its "submit"
  span is reconstructed by the flush worker from the request's own
  enqueue stamp), so the worker owns the trace outright. Recording is a
  clock read and a tuple append. An abandoned trace is garbage-collected
  with the request — there is no active table to leak. A ``closed``
  flag makes recording on a finished/exported trace a silent no-op.
- Per-flush, not per-request: the engine stamps one shared
  ``FlushSpans`` record per micro-batch (queue/gather/dispatch/scatter/
  reply — ONE clock read per stage per *flush*) and each traced request
  attaches to it with a single tuple append. Spans materialize lazily
  when a trace is read (``trace.spans``) or shipped (``export``) — the
  hot path never allocates Span objects.
- Fully deferred on the in-process hot path: when the engine's own
  tracer covers a request (no upstream context to stitch into), no
  Trace object exists during serving at all — the submitter stashes one
  clock stamp, the flush worker appends one ``(t_start, t_enq)`` pair,
  and the whole micro-batch completes as a single ``finish_block``
  (one ring append, one lock, per FLUSH). Trace objects materialize,
  once, when the ring is read. Per-request cost is ~one clock read on
  each side — which is what keeps always-on tracing inside the
  serving benchmark's 5% overhead budget.
- Trace ids are lazy too: only the cross-process path (which must ship
  an id in the request frame) ever pays for one. ``meta`` is taken as a
  prebuilt dict, by reference — hot callers share one dict per
  (model, shard) instead of building one per request.

Cross-process stitching: the socket transport ships the trace id + the
parent span id in its request frames, the worker ``adopt``s the id into
its own tracer (span ids offset so they never collide with the
router's), and the result frame carries the worker's materialized spans
back — ``add_spans`` merges them so one request yields ONE trace whose
spans cover submit->reply across the process boundary. Timestamps from
the two processes share the system clock (same machine), so
``Trace.gaps`` takes an epsilon for the residual skew; in-process
traces chain timestamps exactly (each ``mark`` starts where the
previous span ended) and have zero gaps by construction.

Disabling: a ``Tracer(enabled=False)`` (or ``tracer.enabled = False``)
returns ``None`` from ``start``/``adopt`` and every caller in the
serving stack guards on that — tracing off means no clock reads, no
allocations, nothing on the hot path.
"""

from __future__ import annotations

import itertools
import os
import threading
import time
from collections import deque

# perf_counter anchored to the epoch once: span timestamps are monotonic
# within a process but comparable across processes on one machine
_EPOCH = time.time() - time.perf_counter()
_perf_counter = time.perf_counter


def now() -> float:
    """Wall-clock seconds from a monotonic source (see ``_EPOCH``)."""
    return _EPOCH + _perf_counter()


# trace ids must be unique across the router and worker processes that
# share one stitched trace: pid + per-process counter (generated lazily
# — in-process traces never need one)
_ids = itertools.count(1)


def _new_trace_id() -> str:
    return f"{os.getpid():x}-{next(_ids)}"


class Span:
    """One named [t0, t1] interval — materialized from a trace's raw
    records when the trace is read, never allocated on the hot path."""

    __slots__ = ("name", "t0", "t1", "sid", "parent", "meta")

    def __init__(self, name: str, t0: float, t1: float, sid: int,
                 parent: int | None = None, meta: dict | None = None):
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.sid = sid
        self.parent = parent
        self.meta = meta

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        d = {"name": self.name, "t0": self.t0, "t1": self.t1,
             "sid": self.sid}
        if self.parent is not None:
            d["parent"] = self.parent
        if self.meta:
            d["meta"] = self.meta
        return d

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, dur={self.dur * 1e3:.3f}ms, "
                f"sid={self.sid})")


class FlushSpans:
    """One micro-batch flush's span stamps, shared by every traced
    request in the batch: the engine stamps each stage ONCE and each
    request's trace holds a reference — per-flush cost, not
    per-request (see the module docstring)."""

    __slots__ = ("stamps", "umb")

    def __init__(self):
        self.stamps: list[tuple] = []     # (name, t, meta)
        self.umb: tuple | None = None     # (name, t0, t1)

    def stamp(self, name: str, meta: dict | None = None) -> float:
        """Record stage ``name`` at now (one clock read per flush
        stage; ``meta``, if given, is shared by reference). Returns the
        stamp so callers can chain umbrella spans off it."""
        t = _EPOCH + _perf_counter()
        self.stamps.append((name, t, meta))
        return t

    def umbrella(self, name: str, t0: float, t1: float) -> None:
        """The explicit [t0, t1] span overlapping the chained stamps
        (the engine's whole-flush span)."""
        self.umb = (name, t0, t1)


# raw record kinds in Trace._raw (materialized in insertion order):
#   ("m", name, t0, t1, meta)      eager span (mark / explicit span)
#   ("f", FlushSpans, t0, t_sub)   flush attach; expands to a "submit"
#                                  span [t0, t_sub] (the client-side
#                                  validate+enqueue, reconstructed from
#                                  the request's enqueue stamp so the
#                                  submitter never records) followed by
#                                  the record's stamps chained from t_sub
#   ("d", span_dict)               a span shipped from another process,
#                                  with its original sid/parent


class Trace:
    """One request's spans AND the context handle threaded alongside the
    request — a single allocation per traced request. ``t_last`` chains
    span boundaries (each ``mark`` records [t_last, now] and advances
    it), so in-process traces are gapless by construction. Recording is
    lock-free: see the module docstring's single-writer argument. The
    tracer hands out completed traces by reference, so treat them as
    read-only once finished."""

    __slots__ = ("tracer", "op", "meta", "status", "closed", "t_last",
                 "_tid", "_raw", "_sid_base", "_live_sid", "__weakref__")

    def __init__(self, tracer: "Tracer", op: str, meta: dict | None,
                 t0: float, sid_base: int = 0,
                 trace_id: str | None = None):
        self.tracer = tracer
        self.op = op
        self.meta = meta if meta is not None else {}
        self.status = "open"
        self.closed = False
        self.t_last = t0
        self._tid = trace_id
        self._raw: list[tuple] = []
        self._sid_base = sid_base
        self._live_sid = sid_base - 1

    # backward-compatible context alias (context and trace are one
    # object now; ``req.trace.trace`` still resolves)
    @property
    def trace(self) -> "Trace":
        return self

    @property
    def trace_id(self) -> str:
        tid = self._tid
        if tid is None:
            tid = self._tid = _new_trace_id()
        return tid

    @property
    def last_sid(self) -> int | None:
        """Sid of the last eagerly marked span (the frame-carried
        parent for cross-process stitching). Meaningful only before a
        flush record attaches — exactly when the transport reads it."""
        sid = self._live_sid
        return sid if sid >= self._sid_base else None

    # -- recording ---------------------------------------------------------
    def mark(self, name: str, t: float | None = None,
             **meta) -> int | None:
        """Record the span [t_last, t] (t defaults to now) and advance
        t_last to its end."""
        if self.closed:
            return None
        t = _EPOCH + _perf_counter() if t is None else t
        self._raw.append(("m", name, self.t_last, t, meta or None))
        self.t_last = t
        self._live_sid += 1
        return self._live_sid

    def span(self, name: str, t0: float | None = None,
             t1: float | None = None, **meta):
        """With (t0, t1): record an explicit span without moving
        ``t_last`` (umbrella spans overlapping the chained ones).
        With only a name: look up the first materialized span called
        ``name`` (None if absent)."""
        if t0 is None:
            for s in self.spans:
                if s.name == name:
                    return s
            return None
        if self.closed:
            return None
        self._raw.append(("m", name, t0, t1, meta or None))
        self._live_sid += 1
        return self._live_sid

    def attach_flush(self, flush: FlushSpans,
                     t_submit: float | None = None) -> None:
        """Join this request to a shared per-flush record: ONE tuple
        append, and the submitter is completely off the recording path.
        ``t_submit`` is the request's enqueue stamp as a RAW
        ``perf_counter`` reading (the engine's ``t_enq``) — it becomes
        the end of a reconstructed "submit" span [t_last, t_submit],
        and the flush's stamps chain from it at materialization."""
        if not self.closed:
            t0 = self.t_last
            t_sub = t0 if t_submit is None else _EPOCH + t_submit
            self._raw.append(("f", flush, t0, t_sub))

    def finish(self, status: str = "ok") -> "Trace | None":
        return self.tracer.finish(self, status=status)

    # -- reading -----------------------------------------------------------
    @property
    def spans(self) -> list[Span]:
        """Materialize the raw records, in recording order. Cheap
        relative to recording frequency: reading happens per scrape or
        per export, recording per request."""
        out: list[Span] = []
        sid = self._sid_base
        for rec in self._raw:
            kind = rec[0]
            if kind == "m":
                _, name, t0, t1, meta = rec
                out.append(Span(name, t0, t1, sid, None, meta))
                sid += 1
            elif kind == "f":
                _, flush, t0, prev = rec
                if prev > t0:
                    out.append(Span("submit", t0, prev, sid))
                    sid += 1
                for name, t, meta in flush.stamps:
                    out.append(Span(name, prev, t, sid, None, meta))
                    sid += 1
                    prev = t
                if flush.umb is not None:
                    name, u0, u1 = flush.umb
                    out.append(Span(name, u0, u1, sid))
                    sid += 1
            else:  # "d": shipped from another process, sid preserved
                d = rec[1]
                out.append(Span(d["name"], d["t0"], d["t1"],
                                d.get("sid", -1), d.get("parent"),
                                d.get("meta")))
        return out

    @property
    def t_start(self) -> float:
        spans = self.spans
        return min(s.t0 for s in spans) if spans else 0.0

    @property
    def t_end(self) -> float:
        spans = self.spans
        return max(s.t1 for s in spans) if spans else 0.0

    @property
    def duration(self) -> float:
        spans = self.spans
        if not spans:
            return 0.0
        return max(s.t1 for s in spans) - min(s.t0 for s in spans)

    def names(self) -> list[str]:
        return [s.name for s in sorted(self.spans, key=lambda s: s.t0)]

    def gaps(self, eps: float = 0.0) -> list[tuple[float, float]]:
        """Uncovered intervals inside [t_start, t_end] longer than
        ``eps`` — empty means the spans cover the request end to end."""
        spans = self.spans
        if not spans:
            return []
        out = []
        covered_to = None
        for s in sorted(spans, key=lambda s: s.t0):
            if covered_to is not None and s.t0 > covered_to + eps:
                out.append((covered_to, s.t0))
            covered_to = s.t1 if covered_to is None else max(covered_to,
                                                             s.t1)
        return out

    def to_dict(self) -> dict:
        spans = sorted(self.spans, key=lambda s: s.t0)
        return {"trace_id": self.trace_id, "op": self.op,
                "status": self.status, "meta": self.meta,
                "t_start": spans[0].t0 if spans else 0.0,
                "duration": (max(s.t1 for s in spans) - spans[0].t0
                             if spans else 0.0),
                "spans": [s.to_dict() for s in spans]}


# the context handle and the trace are one object (see Trace docstring);
# the old name stays importable for callers that annotate with it
TraceContext = Trace


class _TraceBlock:
    """A whole flush's deferred traces in one object: per request only a
    raw ``(t_start, t_enq)`` stamp pair (perf_counter clock), plus the
    shared ``FlushSpans`` record — the in-process serving hot path
    allocates NO Trace objects at all. ``Trace``s materialize (and are
    cached, so ids stay stable across reads) the first time the ring is
    read."""

    __slots__ = ("op", "meta", "flush", "entries", "status", "_traces")

    def __init__(self, op: str, meta: dict | None, flush: FlushSpans,
                 entries: list, status: str):
        self.op = op
        self.meta = meta
        self.flush = flush
        self.entries = entries          # [(t_start, t_enq) perf stamps]
        self.status = status
        self._traces: list[Trace] | None = None

    @property
    def n(self) -> int:
        return len(self.entries)

    def materialize(self, tracer: "Tracer") -> list[Trace]:
        if self._traces is None:
            out = []
            for t0, t_enq in self.entries:
                tr = Trace(tracer, self.op, self.meta, _EPOCH + t0)
                tr._raw.append(("f", self.flush, _EPOCH + t0,
                                _EPOCH + t_enq))
                tr.closed = True
                tr.status = self.status
                out.append(tr)
            self._traces = out
        return self._traces


def finish_all(traces, status: str = "ok") -> None:
    """Finish a whole flush's traces, taking each tracer's ring lock
    ONCE (traces in one flush almost always share a tracer)."""
    by_tracer: dict[int, tuple[Tracer, list[Trace]]] = {}
    for t in traces:
        by_tracer.setdefault(id(t.tracer), (t.tracer, []))[1].append(t)
    for tracer, group in by_tracer.values():
        tracer.finish_many(group, status=status)


class Tracer:
    """Bounded, thread-safe trace store: a ring of the most recent
    completed traces (active traces live only on their requests and are
    garbage-collected if abandoned — nothing to leak, nothing to
    evict)."""

    def __init__(self, capacity: int = 256, enabled: bool = True):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.enabled = enabled
        self.capacity = capacity
        self._lock = threading.Lock()
        # completed traces, oldest first: Trace entries interleaved with
        # _TraceBlock entries (a block counts as its n traces)
        self._done: deque = deque()
        self._count = 0
        # counters are advisory (updated without the lock; a concurrent
        # increment may occasionally be lost — recording must stay
        # lock-free, and monitoring does not need exact totals)
        self.started = 0
        self.finished = 0
        self.exported = 0

    def _evict(self) -> None:
        """Trim the ring to capacity (caller holds the lock). Blocks are
        trimmed entry-by-entry so capacity is exact, not block-granular."""
        while self._count > self.capacity:
            head = self._done[0]
            if isinstance(head, Trace):
                self._done.popleft()
                self._count -= 1
            else:
                drop = min(head.n, self._count - self.capacity)
                del head.entries[:drop]
                if head._traces is not None:
                    del head._traces[:drop]
                self._count -= drop
                if not head.entries:
                    self._done.popleft()

    # -- producing ---------------------------------------------------------
    def start(self, op: str, t0: float | None = None,
              meta: dict | None = None) -> Trace | None:
        """Open a new trace; returns None when tracing is disabled (all
        downstream recording is guarded on that). ``meta`` is kept by
        REFERENCE — hot callers pass one shared dict per (model, shard)
        rather than building a fresh one per request."""
        if not self.enabled:
            return None
        self.started += 1
        return Trace(self, op, meta,
                     _EPOCH + _perf_counter() if t0 is None else t0)

    def adopt(self, trace_id: str, op: str = "", t0: float | None = None,
              parent: int | None = None, sid_base: int = 64,
              meta: dict | None = None) -> Trace | None:
        """Open a trace under an EXISTING id — the worker side of a
        cross-process request. ``sid_base`` offsets this process's span
        ids so they never collide with the originator's; ``parent``
        (the frame-carried parent span id) is kept in the trace meta."""
        if not self.enabled:
            return None
        meta = dict(meta) if meta else {}
        if parent is not None:
            meta["parent_span"] = parent
        self.started += 1
        return Trace(self, op, meta, now() if t0 is None else t0,
                     sid_base=sid_base, trace_id=trace_id)

    def add_spans(self, trace: Trace, spans) -> None:
        """Stitch span dicts recorded by another process (the worker's
        half of a cross-process trace) into the trace, with their
        original sids."""
        if trace.closed:
            return
        for d in spans:
            trace._raw.append(("d", d))

    def export(self, trace: Trace) -> list[dict]:
        """Close the trace and return its materialized spans as dicts —
        the worker ships these back in the result frame. Later
        recording / ``finish`` calls become no-ops, so the engine's
        post-set_result bookkeeping is harmless on exported traces."""
        if trace.closed:
            return []
        trace.closed = True
        self.exported += 1
        return [s.to_dict() for s in trace.spans]

    def finish(self, trace: Trace, status: str = "ok") -> Trace | None:
        """Move the trace into the completed ring; returns it (or None
        when the trace was already exported/finished)."""
        if trace.closed:
            return None
        trace.closed = True
        trace.status = status
        with self._lock:
            self._done.append(trace)
            self._count += 1
            self.finished += 1
            self._evict()
        return trace

    def finish_many(self, traces, status: str = "ok") -> None:
        """``finish`` a whole flush's traces under one ring lock."""
        with self._lock:
            for trace in traces:
                if trace.closed:
                    continue
                trace.closed = True
                trace.status = status
                self._done.append(trace)
                self._count += 1
                self.finished += 1
            self._evict()

    def finish_block(self, op: str, meta: dict | None, flush: FlushSpans,
                     entries: list, status: str = "ok") -> None:
        """Complete a whole flush's DEFERRED traces in one shot: one
        ring append + one lock for the entire micro-batch, no Trace
        allocations (they materialize lazily when the ring is read).
        ``entries`` are raw perf_counter ``(t_start, t_enq)`` pairs —
        see ``_TraceBlock``."""
        if not entries:
            return
        block = _TraceBlock(op, meta, flush, entries, status)
        with self._lock:
            self._done.append(block)
            n = len(entries)
            self._count += n
            self.started += n     # deferred traces skip start() entirely
            self.finished += n
            self._evict()

    # -- reading -----------------------------------------------------------
    def traces(self, n: int | None = None) -> list[Trace]:
        """Most recent completed traces, oldest first (deferred blocks
        materialize here, once, with stable identities)."""
        with self._lock:
            out: list[Trace] = []
            for e in self._done:
                if isinstance(e, Trace):
                    out.append(e)
                else:
                    out.extend(e.materialize(self))
        return out if n is None else out[-n:]

    def find(self, trace_id: str) -> Trace | None:
        for t in reversed(self.traces()):
            if t._tid == trace_id:
                return t
        return None

    def last(self) -> Trace | None:
        out = self.traces(1)
        return out[-1] if out else None

    def clear(self) -> None:
        with self._lock:
            self._done.clear()
            self._count = 0

    def stats(self) -> dict:
        with self._lock:
            return {"enabled": self.enabled, "started": self.started,
                    "finished": self.finished, "exported": self.exported,
                    "completed": self._count}

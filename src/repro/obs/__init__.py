"""Observability plane for the serving stack (stdlib-only):

- ``trace.py``  per-request trace spans — ``Tracer`` records
                submit -> queue -> flush -> gather -> dispatch ->
                scatter -> reply as cheap monotonic-clock pairs in a
                bounded ring, with cross-process stitching over the
                socket transport (frames carry trace id + parent span);
- ``export.py`` metrics export — Prometheus text exposition, JSONL
                ``EventLog``, and the ``MetricsServer`` stdlib HTTP
                endpoint (``--metrics-port`` on the launch CLIs).

Dispatch accounting (assert "one fused dispatch per flush" instead of
trusting comments) lives with the dispatch decision in
``repro.kernels.dispatch`` (``counting()``); the sampled telemetry time
series lives with the counters in ``repro.serving.telemetry``
(``Telemetry.history``).
"""

from repro.obs.export import EventLog, MetricsServer, render_prometheus
from repro.obs.trace import (FlushSpans, Span, Trace, TraceContext, Tracer,
                             finish_all, now)

__all__ = [
    "EventLog",
    "FlushSpans",
    "MetricsServer",
    "Span",
    "Trace",
    "TraceContext",
    "Tracer",
    "finish_all",
    "now",
    "render_prometheus",
]

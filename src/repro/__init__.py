"""repro — production-grade JAX framework reproducing *Distributed Learning
and its Application for Time-Series Prediction* (Nguyen & Legitime, 2021).

Core technique: asynchronous local SGD (Hogwild!-style bounded delay) with
linearly increasing sample sequences and model-exchange aggregation,
integrated as a first-class distributed-training feature, plus extreme-event
modeling (EVL) for time-series prediction.
"""

__version__ = "0.1.0"

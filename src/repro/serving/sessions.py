"""Recurrent session cache: per-client carry state kept resident between
requests so a streaming step is O(1) instead of O(window).

``SessionCache`` is model-agnostic (it stores opaque carries with byte
accounting); ``RecurrentSessionRunner`` binds it to a forecaster that
exposes ``init_carry`` / ``step`` / ``replay``. Eviction is LRU with an
optional TTL and byte budget. A cache miss replays the client's window
prefix through the same compiled step function the hot path uses, so —
provided the client supplies its history on a miss — eviction never
changes the numbers a client sees, only the latency. Misses without
history start a fresh session from zero state (or raise, with
``on_miss="error"``).

``ShardedSessionCache`` splits the fleet budget over per-shard
``SessionCache`` instances keyed by a consistent hash of the client id
(the same rendezvous hash the request router uses, so a client's carry
lives on the shard its requests land on). LRU/TTL state and locks are
shard-local: session traffic on one shard never contends with another,
and a shard leaving takes exactly its own clients' carries with it.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Any

from repro.serving.router import ConsistentRouter
from repro.serving.telemetry import Telemetry


@dataclasses.dataclass
class _Session:
    carry: Any
    nbytes: int
    last_used: float
    created: float
    steps: int = 0
    version: int = 0             # model version the carry was built under


class SessionCache:
    """LRU + TTL cache of per-client carries with capacity accounting."""

    def __init__(self, max_sessions: int = 4096,
                 max_bytes: int | None = None,
                 ttl_s: float | None = None,
                 telemetry: Telemetry | None = None,
                 clock=time.monotonic):
        if max_sessions < 1:
            raise ValueError("max_sessions must be >= 1")
        self.max_sessions = max_sessions
        self.max_bytes = max_bytes
        self.ttl_s = ttl_s
        self.telemetry = telemetry
        self._clock = clock
        self._lock = threading.Lock()
        self._sessions: OrderedDict[str, _Session] = OrderedDict()
        self.nbytes_in_use = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._sessions)

    def __contains__(self, client_id: str) -> bool:
        with self._lock:
            return client_id in self._sessions

    def get(self, client_id: str):
        """Return the cached carry (refreshing LRU order) or None."""
        entry = self.get_entry(client_id)
        return entry[0] if entry is not None else None

    def get_entry(self, client_id: str) -> tuple[Any, int] | None:
        """Like ``get`` but returns (carry, model_version) so callers can
        detect carries built under a weight version that has since been
        hot-swapped out."""
        with self._lock:
            expired = self._expire_locked()
            s = self._sessions.get(client_id)
            hit = s is not None
            if hit:
                self._sessions.move_to_end(client_id)
                s.last_used = self._clock()
                self.hits += 1
            else:
                self.misses += 1
        if self.telemetry is not None:
            if expired:
                self.telemetry.record_eviction(expired)
            self.telemetry.record_cache(hit)
        return (s.carry, s.version) if hit else None

    def put(self, client_id: str, carry, nbytes: int,
            version: int = 0) -> None:
        evicted = 0
        with self._lock:
            now = self._clock()
            old = self._sessions.pop(client_id, None)
            if old is not None:
                self.nbytes_in_use -= old.nbytes
            s = _Session(carry=carry, nbytes=nbytes, last_used=now,
                         created=old.created if old else now,
                         steps=(old.steps + 1) if old else 1,
                         version=version)
            self._sessions[client_id] = s
            self.nbytes_in_use += nbytes
            while len(self._sessions) > self.max_sessions or (
                    self.max_bytes is not None
                    and self.nbytes_in_use > self.max_bytes
                    and len(self._sessions) > 1):
                _, victim = self._sessions.popitem(last=False)
                self.nbytes_in_use -= victim.nbytes
                self.evictions += 1
                evicted += 1
        if evicted and self.telemetry is not None:
            self.telemetry.record_eviction(evicted)

    def drop(self, client_id: str) -> bool:
        with self._lock:
            s = self._sessions.pop(client_id, None)
            if s is not None:
                self.nbytes_in_use -= s.nbytes
            return s is not None

    def _expire_locked(self) -> int:
        if self.ttl_s is None:
            return 0
        cutoff = self._clock() - self.ttl_s
        stale = [cid for cid, s in self._sessions.items()
                 if s.last_used < cutoff]
        for cid in stale:
            s = self._sessions.pop(cid)
            self.nbytes_in_use -= s.nbytes
            self.evictions += 1
        return len(stale)

    def stats(self) -> dict:
        with self._lock:
            lookups = self.hits + self.misses
            return {
                "sessions": len(self._sessions),
                "nbytes_in_use": self.nbytes_in_use,
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / lookups if lookups else 0.0,
                "evictions": self.evictions,
            }


class ShardedSessionCache:
    """Fleet session cache: the ``SessionCache`` API over per-shard
    caches, routed by a consistent hash of the client id.

    ``max_sessions`` / ``max_bytes`` are FLEET budgets, split exactly
    over shards (remainders go to the first shards, so the fleet total
    never exceeds the budget); eviction is shard-local LRU (a hot shard
    evicts its own LRU client even while another shard has room — the
    price of lock-free-across-shards operation). Pass the mesh's
    ``router`` so session shards coincide with serving shards, or omit
    it for a standalone sharded cache."""

    def __init__(self, n_shards: int = 2, max_sessions: int = 4096,
                 max_bytes: int | None = None, ttl_s: float | None = None,
                 telemetry: Telemetry | None = None, clock=time.monotonic,
                 router=None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.n_shards = n_shards
        self.router = router if router is not None \
            else ConsistentRouter(range(n_shards))
        bad = [s for s in self.router.shard_ids
               if not 0 <= s < n_shards]
        if bad:
            raise ValueError(
                f"router shard ids {bad} are outside this cache's "
                f"0..{n_shards - 1} shard range")
        self.telemetry = telemetry
        if max_sessions < n_shards:
            raise ValueError(
                f"max_sessions={max_sessions} must be >= n_shards="
                f"{n_shards} (every shard needs at least one slot)")

        def split(total: int, i: int) -> int:
            return total // n_shards + (1 if i < total % n_shards else 0)

        self.shards = [SessionCache(
            max_sessions=split(max_sessions, i),
            max_bytes=None if max_bytes is None else split(max_bytes, i),
            ttl_s=ttl_s, telemetry=telemetry, clock=clock)
            for i in range(n_shards)]

    def shard_for(self, client_id: str) -> int:
        return self.router.shard_for(str(client_id))

    def _shard(self, client_id: str) -> SessionCache:
        sid = self.shard_for(client_id)
        if not 0 <= sid < self.n_shards:      # router mutated after init
            raise KeyError(
                f"router returned shard {sid} for {client_id!r} but this "
                f"cache has {self.n_shards} shards — the shard set is "
                f"pinned at construction")
        return self.shards[sid]

    # -- SessionCache API, routed ------------------------------------------
    def get(self, client_id: str):
        return self._shard(client_id).get(client_id)

    def get_entry(self, client_id: str):
        return self._shard(client_id).get_entry(client_id)

    def put(self, client_id: str, carry, nbytes: int,
            version: int = 0) -> None:
        self._shard(client_id).put(client_id, carry, nbytes,
                                   version=version)

    def drop(self, client_id: str) -> bool:
        return self._shard(client_id).drop(client_id)

    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)

    def __contains__(self, client_id: str) -> bool:
        return client_id in self._shard(client_id)

    @property
    def hits(self) -> int:
        return sum(s.hits for s in self.shards)

    @property
    def misses(self) -> int:
        return sum(s.misses for s in self.shards)

    @property
    def evictions(self) -> int:
        return sum(s.evictions for s in self.shards)

    @property
    def nbytes_in_use(self) -> int:
        return sum(s.nbytes_in_use for s in self.shards)

    def stats(self) -> dict:
        """Fleet aggregate plus per-shard session/byte occupancy."""
        shard_stats = [s.stats() for s in self.shards]
        lookups = self.hits + self.misses
        return {
            "sessions": sum(st["sessions"] for st in shard_stats),
            "nbytes_in_use": sum(st["nbytes_in_use"] for st in shard_stats),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / lookups if lookups else 0.0,
            "evictions": self.evictions,
            "shards": len(self.shards),
            "sessions_by_shard": [st["sessions"] for st in shard_stats],
        }


class RecurrentSessionRunner:
    """Streaming serving for a recurrent forecaster: each client is a
    session whose carry lives in the cache between requests.

    ``forecaster`` may be the forecaster itself or a zero-arg provider
    returning the *current* forecaster (e.g. ``lambda: registry.get(key)``)
    so a runner keeps tracking a registry key across weight hot-swaps.
    Carries are stamped with the model version they were built under; a
    step that observes a newer version re-primes the carry lazily — by
    replaying ``history`` through the new weights when given, otherwise by
    carrying the live hidden state across (valid shapes: swapped versions
    share the config) — instead of dropping the session.
    """

    def __init__(self, forecaster, cache: SessionCache | None = None,
                 on_miss: str = "zeros"):
        if callable(forecaster) and not hasattr(forecaster, "step"):
            self._provider = forecaster
        else:
            self._provider = None
            self.forecaster = forecaster
        fc = self._resolve()
        if on_miss not in ("zeros", "error"):
            raise ValueError("on_miss must be 'zeros' or 'error'")
        self.cache = cache if cache is not None else SessionCache()
        self.on_miss = on_miss
        self._nbytes = fc.carry_nbytes(1)
        self.reprimes = 0            # carries replayed onto new weights
        self.carried_across_swap = 0  # carries reused without history

    def _resolve(self):
        fc = self._provider() if self._provider is not None \
            else self.forecaster
        for attr in ("init_carry", "step", "replay"):
            if not hasattr(fc, attr):
                raise TypeError(
                    f"forecaster {type(fc).__name__} does not "
                    f"support incremental serving (missing {attr!r})")
        return fc

    def step(self, client_id: str, x_t, history=None):
        """One streaming step for ``client_id``. ``x_t`` is one feature
        vector [F] (or [1, F]). On a cache miss the carry is rebuilt from
        ``history`` ([T, F] window prefix, replayed through the same
        compiled step the hot path uses). Without history, a miss starts
        a fresh zero-state session — correct for a new client, but an
        evicted client's forecasts silently restart from scratch, so
        deployments where eviction is expected should pass history or
        construct the runner with ``on_miss="error"``.
        Returns (forecast, p_extreme) scalars."""
        import numpy as np

        fc = self._resolve()
        version = getattr(fc, "version", 0)
        x_t = np.asarray(x_t, np.float32)
        if x_t.ndim == 1:
            x_t = x_t[None, :]
        entry = self.cache.get_entry(client_id)
        carry = None
        stamp = version
        if entry is not None:
            carry, carry_version = entry
            if carry_version != version:
                if history is not None:
                    hist = np.asarray(history, np.float32)
                    _, _, carry = fc.replay(hist[None])
                    self.reprimes += 1
                    if self.cache.telemetry is not None:
                        self.cache.telemetry.record_reprime()
                else:
                    # same config, new weights: the live state stays a
                    # usable prefix approximation until history arrives —
                    # keep the OLD stamp so a later step that does bring
                    # history still sees the mismatch and re-primes
                    self.carried_across_swap += 1
                    stamp = carry_version
        if carry is None:
            if history is not None:
                hist = np.asarray(history, np.float32)
                _, _, carry = fc.replay(hist[None])
            elif self.on_miss == "error":
                raise KeyError(
                    f"no session for {client_id!r} and no history given")
            else:
                carry = fc.init_carry(1)
        y, p, carry = fc.step(x_t, carry)
        self.cache.put(client_id, carry, self._nbytes, version=stamp)
        return float(y[0]), float(p[0])

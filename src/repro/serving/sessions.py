"""Recurrent session cache: per-client carry state kept resident between
requests so a streaming step is O(1) instead of O(window).

``SessionCache`` is model-agnostic (it stores opaque carries with byte
accounting); ``RecurrentSessionRunner`` binds it to a forecaster that
exposes ``init_carry`` / ``step`` / ``replay``. For forecasters that
expose the device-resident slot lifecycle (``init_slots`` /
``prefill`` / ``insert`` / ``generate``), the runner IS a slot
allocator: sessions live in fixed device lanes between steps, and a
batched ``step_many`` is "ensure resident → one fused generate dispatch
→ read only the requested rows" — the cache is demoted to a *spill
tier* that holds carries only for sessions LRU-evicted off the lanes
(or spilled for migration), bitwise-identical on reload. Forecasters
without slot support keep the PR-5 gather/scatter path: carries
gathered from the cache, advanced in one fused dispatch per decode-lane
chunk, and scattered back. Both are bitwise-equal to stepping each
session alone. Eviction is LRU with an optional TTL and byte budget. A
cache miss replays the client's window prefix through the same compiled
step function the hot path uses, so — provided the client supplies its
history on a miss — eviction never changes the numbers a client sees,
only the latency. Misses without history start a fresh session from
zero state (or raise, with ``on_miss="error"``).

``ShardedSessionCache`` splits the fleet budget over per-shard
``SessionCache`` instances keyed by a consistent hash of the client id
(the same rendezvous hash the request router uses, so a client's carry
lives on the shard its requests land on). LRU/TTL state and locks are
shard-local: session traffic on one shard never contends with another.
Membership is LIVE: ``add_shard``/``remove_shard`` follow the router's
assignment laws — only the clients the rendezvous hash moves (to an
arriving shard, or off a departing one) are migrated, carries intact,
and the fleet budget is re-split over the new shard set.

Carries are OPAQUE throughout: single models store per-layer (h, c)
tuples; an ``EnsembleForecaster`` session stores one composite
``{member_key: member_carry}`` dict under ONE client id. The runner
never looks inside — init/step/replay/extract on the ensemble build and
split the dict — so a composite session spills, migrates and re-homes
as a unit, and version mismatches (the ensemble version string changes
when ANY member is swapped) re-prime every member from history in one
replay, exactly like a single model.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from collections import OrderedDict
from typing import Any

import jax

from repro.serving.router import ConsistentRouter
from repro.serving.telemetry import Telemetry


@dataclasses.dataclass
class _Session:
    carry: Any
    nbytes: int
    last_used: float
    created: float
    steps: int = 0
    version: int = 0             # model version the carry was built under


class SessionCache:
    """LRU + TTL cache of per-client carries with capacity accounting."""

    def __init__(self, max_sessions: int = 4096,
                 max_bytes: int | None = None,
                 ttl_s: float | None = None,
                 telemetry: Telemetry | None = None,
                 clock=time.monotonic):
        if max_sessions < 1:
            raise ValueError("max_sessions must be >= 1")
        self.max_sessions = max_sessions
        self.max_bytes = max_bytes
        self.ttl_s = ttl_s
        self.telemetry = telemetry
        self._clock = clock
        self._lock = threading.Lock()
        self._sessions: OrderedDict[str, _Session] = OrderedDict()
        self.nbytes_in_use = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.oversize_admissions = 0   # carries bigger than max_bytes
        self._warned_oversize = False

    def __len__(self) -> int:
        with self._lock:
            return len(self._sessions)

    def __contains__(self, client_id: str) -> bool:
        with self._lock:
            return client_id in self._sessions

    def get(self, client_id: str):
        """Return the cached carry (refreshing LRU order) or None."""
        entry = self.get_entry(client_id)
        return entry[0] if entry is not None else None

    def get_entry(self, client_id: str) -> tuple[Any, int] | None:
        """Like ``get`` but returns (carry, model_version) so callers can
        detect carries built under a weight version that has since been
        hot-swapped out."""
        with self._lock:
            expired = self._expire_locked()
            s = self._sessions.get(client_id)
            hit = s is not None
            if hit:
                self._sessions.move_to_end(client_id)
                s.last_used = self._clock()
                self.hits += 1
            else:
                self.misses += 1
        if self.telemetry is not None:
            if expired:
                self.telemetry.record_eviction(expired)
            self.telemetry.record_cache(hit)
        return (s.carry, s.version) if hit else None

    def put(self, client_id: str, carry, nbytes: int,
            version: int = 0) -> None:
        evicted = 0
        warn_oversize = False
        with self._lock:
            now = self._clock()
            old = self._sessions.pop(client_id, None)
            if old is not None:
                self.nbytes_in_use -= old.nbytes
            if self.max_bytes is not None and nbytes > self.max_bytes:
                # a single carry bigger than the whole byte budget: it is
                # admitted (evicting it would silently restart the
                # client's stream from zero state) but the cache sits
                # over budget until normal LRU pressure reclaims it —
                # warn once and surface ``over_budget`` in stats()
                # instead of doing that silently
                self.oversize_admissions += 1
                if not self._warned_oversize:
                    self._warned_oversize = True
                    warn_oversize = True
            s = _Session(carry=carry, nbytes=nbytes, last_used=now,
                         created=old.created if old else now,
                         steps=(old.steps + 1) if old else 1,
                         version=version)
            self._sessions[client_id] = s
            self.nbytes_in_use += nbytes
            evicted = self._evict_over_locked()
        if warn_oversize:
            warnings.warn(
                f"session carry for {client_id!r} is {nbytes} bytes, over "
                f"the cache's max_bytes={self.max_bytes}: admitted, but "
                f"the cache is over budget until it is evicted "
                f"(stats()['over_budget'] tracks this)",
                RuntimeWarning, stacklevel=2)
        if evicted and self.telemetry is not None:
            self.telemetry.record_eviction(evicted)

    def put_new(self, client_id: str, carry, nbytes: int,
                version: int = 0) -> bool:
        """Insert only if absent, atomically — the migration path. A
        carry arriving from a departing shard must never clobber a
        fresher one a concurrent step already wrote to the new owner.
        Returns whether the carry was installed."""
        with self._lock:
            if client_id in self._sessions:
                return False
            now = self._clock()
            self._sessions[client_id] = _Session(
                carry=carry, nbytes=nbytes, last_used=now, created=now,
                steps=1, version=version)
            self.nbytes_in_use += nbytes
            evicted = self._evict_over_locked()
        if evicted and self.telemetry is not None:
            self.telemetry.record_eviction(evicted)
        return True

    def _evict_over_locked(self) -> int:
        """Evict LRU entries until within the session/byte budgets (a
        lone over-budget session is kept — see ``put``)."""
        evicted = 0
        while len(self._sessions) > self.max_sessions or (
                self.max_bytes is not None
                and self.nbytes_in_use > self.max_bytes
                and len(self._sessions) > 1):
            _, victim = self._sessions.popitem(last=False)
            self.nbytes_in_use -= victim.nbytes
            self.evictions += 1
            evicted += 1
        return evicted

    _KEEP = object()               # resize sentinel: leave a budget as-is

    def resize(self, max_sessions=None, max_bytes=_KEEP) -> int:
        """Change the budgets (fleet re-split on membership change),
        evicting LRU entries down to the new limits. ``max_bytes=None``
        removes the byte budget; omit it to keep the current one.
        Returns #evicted."""
        with self._lock:
            if max_sessions is not None:
                if max_sessions < 1:
                    raise ValueError("max_sessions must be >= 1")
                self.max_sessions = max_sessions
            if max_bytes is not SessionCache._KEEP:
                self.max_bytes = max_bytes
            evicted = self._evict_over_locked()
        if evicted and self.telemetry is not None:
            self.telemetry.record_eviction(evicted)
        return evicted

    def clients(self) -> list[str]:
        """Ids of the currently cached sessions (LRU -> MRU order)."""
        with self._lock:
            return list(self._sessions)

    def export(self, client_ids=None) -> list[tuple[str, Any, int, int]]:
        """Remove and return ``(client_id, carry, nbytes, version)``
        tuples — for ``client_ids`` (missing ids skipped), or every
        session when None. This is the migration path: a shard handing
        its clients to the new owners on membership change."""
        with self._lock:
            ids = list(self._sessions) if client_ids is None \
                else [c for c in client_ids if c in self._sessions]
            out = []
            for cid in ids:
                s = self._sessions.pop(cid)
                self.nbytes_in_use -= s.nbytes
                out.append((cid, s.carry, s.nbytes, s.version))
            return out

    def snapshot(self, client_ids=None) -> list[tuple[str, Any, int, int]]:
        """READ ``(client_id, carry, nbytes, version)`` tuples without
        removing them — the durable-checkpoint path (``export`` is the
        migration path and drains what it returns). No LRU refresh and
        no hit/miss accounting: observing the cache for a checkpoint
        must not perturb its eviction order or its telemetry."""
        with self._lock:
            ids = list(self._sessions) if client_ids is None \
                else [c for c in client_ids if c in self._sessions]
            return [(cid, self._sessions[cid].carry,
                     self._sessions[cid].nbytes,
                     self._sessions[cid].version) for cid in ids]

    def peek_version(self, client_id: str) -> int | None:
        """The version stamp of a cached session, without touching LRU
        order or hit/miss counts (None when absent) — the partition
        re-adoption reconcile compares these against the store."""
        with self._lock:
            s = self._sessions.get(client_id)
            return s.version if s is not None else None

    def drop(self, client_id: str) -> bool:
        with self._lock:
            s = self._sessions.pop(client_id, None)
            if s is not None:
                self.nbytes_in_use -= s.nbytes
            return s is not None

    def _expire_locked(self) -> int:
        if self.ttl_s is None:
            return 0
        cutoff = self._clock() - self.ttl_s
        stale = [cid for cid, s in self._sessions.items()
                 if s.last_used < cutoff]
        for cid in stale:
            s = self._sessions.pop(cid)
            self.nbytes_in_use -= s.nbytes
            self.evictions += 1
        return len(stale)

    def stats(self) -> dict:
        with self._lock:
            lookups = self.hits + self.misses
            return {
                "sessions": len(self._sessions),
                "nbytes_in_use": self.nbytes_in_use,
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / lookups if lookups else 0.0,
                "evictions": self.evictions,
                "over_budget": (self.max_bytes is not None
                                and self.nbytes_in_use > self.max_bytes),
                "oversize_admissions": self.oversize_admissions,
            }


class ShardedSessionCache:
    """Fleet session cache: the ``SessionCache`` API over per-shard
    caches, routed by a consistent hash of the client id.

    ``max_sessions`` / ``max_bytes`` are FLEET budgets, split exactly
    over shards (remainders go to the lowest shard ids, so the fleet
    total never exceeds the budget); eviction is shard-local LRU (a hot
    shard evicts its own LRU client even while another shard has room —
    the price of lock-free-across-shards operation). Pass the mesh's
    ``router`` so session shards coincide with serving shards, or omit
    it for a standalone sharded cache.

    Membership is a live view of the router: ``add_shard`` /
    ``remove_shard`` migrate exactly the clients the rendezvous hash
    moves (carries intact) and re-split the fleet budget — the
    assignment laws the router is property-tested for extend to the
    cached sessions."""

    def __init__(self, n_shards: int = 2, max_sessions: int = 4096,
                 max_bytes: int | None = None, ttl_s: float | None = None,
                 telemetry: Telemetry | None = None, clock=time.monotonic,
                 router=None):
        if router is None and n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.router = router if router is not None \
            else ConsistentRouter(range(n_shards))
        self.telemetry = telemetry
        self.max_sessions_fleet = max_sessions
        self.max_bytes_fleet = max_bytes
        self.ttl_s = ttl_s
        self._clock = clock
        self._members_lock = threading.Lock()
        ids = self.router.shard_ids
        if max_sessions < len(ids):
            raise ValueError(
                f"max_sessions={max_sessions} must be >= n_shards="
                f"{len(ids)} (every shard needs at least one slot)")
        self.shards: dict[int, SessionCache] = {
            sid: SessionCache(
                max_sessions=self._split(max_sessions, i, len(ids)),
                max_bytes=(None if max_bytes is None
                           else self._split(max_bytes, i, len(ids))),
                ttl_s=ttl_s, telemetry=telemetry, clock=clock)
            for i, sid in enumerate(ids)}

    @staticmethod
    def _split(total: int, i: int, n: int) -> int:
        return total // n + (1 if i < total % n else 0)

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    # -- live membership ---------------------------------------------------
    def _resplit_locked(self) -> None:
        n = len(self.shards)
        for i, sid in enumerate(sorted(self.shards)):
            self.shards[sid].resize(
                max_sessions=self._split(self.max_sessions_fleet, i, n),
                max_bytes=(None if self.max_bytes_fleet is None
                           else self._split(self.max_bytes_fleet, i, n)))

    def add_shard(self, shard_id: int) -> None:
        """Open a shard-local cache for ``shard_id`` (adding it to the
        router if the caller has not already) and migrate exactly the
        clients the rendezvous hash re-homes onto it, carries intact."""
        sid = int(shard_id)
        with self._members_lock:
            if sid in self.shards:
                return
            if self.max_sessions_fleet < len(self.shards) + 1:
                raise ValueError(
                    f"fleet max_sessions={self.max_sessions_fleet} cannot "
                    f"give shard {sid} a slot (already {len(self.shards)} "
                    f"shards)")
            self.shards[sid] = SessionCache(
                max_sessions=1, ttl_s=self.ttl_s, telemetry=self.telemetry,
                clock=self._clock)
            if sid not in self.router.shard_ids:
                self.router.add_shard(sid)
            self._resplit_locked()
            # minimal disruption: only clients the new shard WINS move;
            # insert-if-absent so a fresher carry a concurrent step
            # already wrote to the new shard is never clobbered
            for old_sid, cache in list(self.shards.items()):
                if old_sid == sid:
                    continue
                moving = [c for c in cache.clients()
                          if self.router.shard_for(c) == sid]
                for cid, carry, nbytes, version in cache.export(moving):
                    self.shards[sid].put_new(cid, carry, nbytes,
                                             version=version)

    def remove_shard(self, shard_id: int) -> None:
        """Close ``shard_id``'s cache (removing it from the router if the
        caller has not already) and hand its clients — and only its
        clients — to their new owner shards, carries intact.

        A ``get`` racing the migration window can still miss (the carry
        is in flight between shards); a re-homed client that keeps
        streaming through the change should supply its history on a
        miss (standard consistent-hashing cache semantics — the session
        runner replays it through the same compiled step). ``put`` is
        loss-proof: one landing in a just-removed shard's cache detects
        the change and re-routes itself."""
        sid = int(shard_id)
        with self._members_lock:
            if sid not in self.shards:
                raise KeyError(f"no session shard {sid}; have "
                               f"{sorted(self.shards)}")
            if len(self.shards) == 1:
                raise ValueError("cannot remove the last session shard")
            if sid in self.router.shard_ids:
                self.router.remove_shard(sid)
            departing = self.shards.pop(sid)
            self._resplit_locked()
            for cid, carry, nbytes, version in departing.export():
                # insert-if-absent: a concurrent step may already have
                # written a fresher carry on the new owner
                self.shards[self.router.shard_for(cid)].put_new(
                    cid, carry, nbytes, version=version)

    def shard_for(self, client_id: str) -> int:
        return self.router.shard_for(str(client_id))

    def _shard(self, client_id: str) -> SessionCache:
        sid = self.shard_for(client_id)
        cache = self.shards.get(sid)
        if cache is None:                     # router mutated directly
            raise KeyError(
                f"router maps {client_id!r} to shard {sid} but this cache "
                f"has no such shard — change membership through "
                f"add_shard/remove_shard (or the owning mesh), not by "
                f"mutating the router")
        return cache

    # -- SessionCache API, routed ------------------------------------------
    def get(self, client_id: str):
        return self._shard(client_id).get(client_id)

    def get_entry(self, client_id: str):
        return self._shard(client_id).get_entry(client_id)

    def put(self, client_id: str, carry, nbytes: int,
            version: int = 0) -> None:
        while True:
            sid = self.shard_for(client_id)
            cache = self._shard(client_id)
            cache.put(client_id, carry, nbytes, version=version)
            if self.shards.get(sid) is cache \
                    and self.shard_for(client_id) == sid:
                return
            # membership changed mid-put: the entry may sit in a cache
            # that was just removed (its export already ran) or that no
            # longer owns the client — never lose the carry silently;
            # pull it back and re-route
            cache.drop(client_id)

    def drop(self, client_id: str) -> bool:
        return self._shard(client_id).drop(client_id)

    def __len__(self) -> int:
        return sum(len(s) for s in list(self.shards.values()))

    def __contains__(self, client_id: str) -> bool:
        return client_id in self._shard(client_id)

    @property
    def hits(self) -> int:
        return sum(s.hits for s in list(self.shards.values()))

    @property
    def misses(self) -> int:
        return sum(s.misses for s in list(self.shards.values()))

    @property
    def evictions(self) -> int:
        return sum(s.evictions for s in list(self.shards.values()))

    @property
    def nbytes_in_use(self) -> int:
        return sum(s.nbytes_in_use for s in list(self.shards.values()))

    def stats(self) -> dict:
        """Fleet aggregate plus per-shard session/byte occupancy."""
        shards = dict(self.shards)       # snapshot vs live membership
        shard_stats = {sid: shards[sid].stats() for sid in sorted(shards)}
        lookups = self.hits + self.misses
        return {
            "sessions": sum(st["sessions"] for st in shard_stats.values()),
            "nbytes_in_use": sum(st["nbytes_in_use"]
                                 for st in shard_stats.values()),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / lookups if lookups else 0.0,
            "evictions": self.evictions,
            "over_budget": any(st["over_budget"]
                               for st in shard_stats.values()),
            "shards": len(self.shards),
            "sessions_by_shard": [st["sessions"]
                                  for st in shard_stats.values()],
        }


DEFAULT_NUM_SLOTS = 64               # lanes per runner when unspecified


class RecurrentSessionRunner:
    """Streaming serving for a recurrent forecaster: each client is a
    session whose state lives in a device-resident decode lane between
    requests (slot forecasters) or in the session cache (others).

    ``forecaster`` may be the forecaster itself or a zero-arg provider
    returning the *current* forecaster (e.g. ``lambda: registry.get(key)``)
    so a runner keeps tracking a registry key across weight hot-swaps.
    Carries are stamped with the model version they were built under; a
    step that observes a newer version re-primes the carry lazily — by
    replaying ``history`` through the new weights when given, otherwise by
    carrying the live hidden state across (valid shapes: swapped versions
    share the config) — instead of dropping the session.

    With slots (``num_slots`` > 0, the default when the forecaster
    supports it), the runner is the slot ALLOCATOR: an LRU over lanes
    decides which sessions stay device-resident; a session that loses
    its lane spills its carry to the cache (the spill tier) and reloads
    bitwise-identically on its next step; lanes idle past the cache's
    TTL are expired like cache entries. ``num_slots=0`` disables slots
    and keeps the gather/scatter path.
    """

    def __init__(self, forecaster, cache: SessionCache | None = None,
                 on_miss: str = "zeros",
                 donate_carries: bool | None = None,
                 num_slots: int | None = None):
        if callable(forecaster) and not hasattr(forecaster, "step"):
            self._provider = forecaster
        else:
            self._provider = None
            self.forecaster = forecaster
        # donate_carries: the fused programs consume carry buffers in
        # place (slot state for generate/insert, cached carries for the
        # gather/scatter path). None resolves to the platform default:
        # ON off-CPU, off on CPU (where XLA donation is a warn + copy).
        # ONLY safe when this runner's state is touched by a single
        # thread during serving — the engine-internal runner qualifies
        # (one worker flushes, exports happen after drain); a cache
        # shared with concurrent readers (live-membership migration)
        # must pass False — the transport workers do.
        if donate_carries is None:
            from repro.serving.forecaster import _donate_default
            donate_carries = _donate_default()
        self.donate_carries = bool(donate_carries)
        self.last_step_slots = 0     # lane slots of the last step_many
        fc = self._resolve()
        if on_miss not in ("zeros", "error"):
            raise ValueError("on_miss must be 'zeros' or 'error'")
        self.cache = cache if cache is not None else SessionCache()
        self.on_miss = on_miss
        self._nbytes = fc.carry_nbytes(1)
        self.reprimes = 0            # carries replayed onto new weights
        self.carried_across_swap = 0  # carries reused without history
        # -- device-resident decode slots --------------------------------
        slot_capable = hasattr(fc, "init_slots") \
            and getattr(fc, "feature_dim", 0)
        if num_slots is None:
            # a ShardedSessionCache's contract is that carries live in
            # per-shard caches and FOLLOW mesh membership — runner-local
            # lanes would hide sessions from that migration, so slots
            # default off over sharded caches (pass num_slots explicitly
            # to opt in; you then own spilling around membership ops)
            sharded = isinstance(self.cache, ShardedSessionCache)
            num_slots = DEFAULT_NUM_SLOTS \
                if (slot_capable and not sharded) else 0
        if num_slots and not slot_capable:
            raise TypeError(
                f"forecaster {type(fc).__name__} does not support "
                f"decode slots (missing init_slots); pass num_slots=0")
        self._slots = fc.init_slots(num_slots) if num_slots else None
        self.num_slots = self._slots.num_slots if self._slots else 0
        self._slots_lock = threading.Lock()
        self._lanes: OrderedDict[str, int] = OrderedDict()  # cid -> lane
        self._free = list(reversed(range(self.num_slots)))  # pop() -> 0..
        self._lane_stamp: dict[str, int] = {}
        self._lane_last_used: dict[str, float] = {}
        self.slot_inserts = 0        # sessions written into a lane
        self.slot_spills = 0         # lane carries spilled to the cache
        self.slot_expiries = 0       # lanes freed by TTL (state dropped)
        window = getattr(fc, "window", None)
        if window and getattr(fc, "feature_dim", 0):
            import numpy as np

            # compile the full-window replay program HERE, off the
            # serving path — otherwise the first cache miss / swap
            # re-prime pays the jit compile at serve time
            fc.replay(np.zeros((1, window, fc.feature_dim), np.float32))
            if self._slots is not None:
                # same deal for the slot lifecycle programs: the first
                # flush must not pay the generate compile at serve time
                fc.warm_slots(self.num_slots)

    def _resolve(self):
        fc = self._provider() if self._provider is not None \
            else self.forecaster
        for attr in ("init_carry", "step", "replay"):
            if not hasattr(fc, attr):
                raise TypeError(
                    f"forecaster {type(fc).__name__} does not "
                    f"support incremental serving (missing {attr!r})")
        return fc

    def _resolve_carry(self, fc, client_id: str, hist, version: int):
        """Carry-resolution shared by ``step`` and ``step_many``: cache
        hit (with lazy re-prime when the weights hot-swapped under the
        carry), else rebuild from history, else zero state / error.
        Returns (carry, version stamp for the put-back)."""
        entry = self.cache.get_entry(client_id)
        carry = None
        stamp = version
        if entry is not None:
            carry, carry_version = entry
            if carry_version != version:
                if hist is not None:
                    _, _, carry = fc.replay(hist[None])
                    self.reprimes += 1
                    if self.cache.telemetry is not None:
                        self.cache.telemetry.record_reprime()
                else:
                    # same config, new weights: the live state stays a
                    # usable prefix approximation until history arrives —
                    # keep the OLD stamp so a later step that does bring
                    # history still sees the mismatch and re-primes
                    self.carried_across_swap += 1
                    stamp = carry_version
        if carry is None:
            if hist is not None:
                _, _, carry = fc.replay(hist[None])
            elif self.on_miss == "error":
                raise KeyError(
                    f"no session for {client_id!r} and no history given")
            else:
                carry = fc.init_carry(1)
        return carry, stamp

    def _clamp_history(self, fc, history):
        if history is None:
            return None
        import numpy as np

        hist = np.asarray(history, np.float32)
        window = getattr(fc, "window", None)
        if window and hist.shape[0] > window:
            # clamp to the newest `window` steps: the serving
            # contract replays window prefixes (the model is causal
            # over a sliding window), and an unbounded set of
            # history lengths would compile one replay program per
            # distinct length
            hist = hist[-window:]
        return hist

    def step(self, client_id: str, x_t, history=None):
        """One streaming step for ``client_id``. ``x_t`` is one feature
        vector [F] (or [1, F]). On a cache miss the carry is rebuilt from
        ``history`` ([T, F] window prefix, replayed through the same
        compiled step the hot path uses). Without history, a miss starts
        a fresh zero-state session — correct for a new client, but an
        evicted client's forecasts silently restart from scratch, so
        deployments where eviction is expected should pass history or
        construct the runner with ``on_miss="error"``.
        Returns (forecast, p_extreme) scalars."""
        import numpy as np

        if self._slots is not None:
            # slot runners have no out-of-lane step path: a lane-resident
            # session stepped outside its lane would fork its state
            return self.step_many([(client_id, x_t, history)])[0]
        fc = self._resolve()
        version = getattr(fc, "version", 0)
        x_t = np.asarray(x_t, np.float32)
        if x_t.ndim == 1:
            x_t = x_t[None, :]
        hist = self._clamp_history(fc, history)
        carry, stamp = self._resolve_carry(fc, client_id, hist, version)
        y, p, carry = fc.step(x_t, carry)
        self.cache.put(client_id, carry, self._nbytes, version=stamp)
        return float(y[0]), float(p[0])

    def step_many(self, items):
        """Batched streaming step: ``items`` is a list of
        ``(client_id, x_t, history)`` tuples (history may be None). All
        sessions step in ONE fused dispatch per decode-lane chunk
        (``forecaster.step_many``) instead of one dispatch per client —
        carries are gathered from the cache, stepped stacked, and
        scattered back, bitwise-identical to calling ``step`` per item
        (the lane runs every path at one fixed batch width).

        Duplicate client ids are legal: later occurrences run in a
        follow-up wave so each step sees the carry its predecessor
        wrote, preserving per-client stream order. Returns
        ``[(forecast, p_extreme), ...]`` in item order. Requires the
        forecaster to expose ``step_many``; per-session ``step`` is the
        fallback."""
        import numpy as np

        fc = self._resolve()
        self.last_step_slots = len(items)
        if not items:
            return []
        if not hasattr(fc, "step_many"):
            return [self.step(cid, x_t, history=h) for cid, x_t, h in items]
        version = getattr(fc, "version", 0)
        results: list = [None] * len(items)
        # waves: index items so one client's steps never share a batch
        waves: list[list[int]] = []
        seen_at: dict[str, int] = {}
        for idx, (cid, _x, _h) in enumerate(items):
            wave = seen_at.get(cid, -1) + 1
            seen_at[cid] = wave
            if wave == len(waves):
                waves.append([])
            waves[wave].append(idx)
        if self._slots is not None:
            # slot path: every wave is one fused generate over the full
            # slot state (chunked only when a wave holds more distinct
            # clients than there are lanes)
            S = self.num_slots
            n_chunks = 0
            with jax.profiler.TraceAnnotation("repro.session_step_many"):
                with self._slots_lock:
                    self._expire_lanes_locked(fc)
                    for wave in waves:
                        for lo in range(0, len(wave), S):
                            n_chunks += 1
                            self._generate_chunk_locked(
                                fc, items, wave[lo:lo + S], version,
                                results)
                    tel = self.cache.telemetry
                    if tel is not None:
                        tel.record_slots(active=len(self._lanes), lanes=S)
            self.last_step_slots = n_chunks * S
            return results
        # decode-lane slots this call dispatches (each wave pads to the
        # lane width, chunking beyond it) — the engine reads this for
        # its occupancy telemetry, so the accounting lives with the
        # dispatch decision instead of being re-derived
        width = getattr(fc, "decode_width", None)
        self.last_step_slots = sum(
            (-(-len(w) // width) * width) if width else len(w)
            for w in waves)
        with jax.profiler.TraceAnnotation("repro.session_step_many"):
            self._run_waves(fc, items, waves, version, results)
        return results

    # -- slot allocator ----------------------------------------------------
    def _expire_lanes_locked(self, fc) -> None:
        """TTL sweep over the lanes, mirroring the cache's expiry: a
        lane idle past the cache's TTL is freed and its state DROPPED
        (not spilled) — exactly what the cache would have done to the
        entry. The client re-primes from history on its next step."""
        ttl = self.cache.ttl_s
        if ttl is None or not self._lanes:
            return
        cutoff = self.cache._clock() - ttl
        stale = [cid for cid, _lane in self._lanes.items()
                 if self._lane_last_used.get(cid, cutoff) < cutoff]
        for cid in stale:
            lane = self._lanes.pop(cid)
            self._lane_stamp.pop(cid, None)
            self._lane_last_used.pop(cid, None)
            fc.release(self._slots, lane)
            self._free.append(lane)
            self.slot_expiries += 1
        if stale and self.cache.telemetry is not None:
            self.cache.telemetry.record_eviction(len(stale))

    def _alloc_lane_locked(self, fc) -> int:
        """A free lane, else the LRU lane — its session spills its
        carry to the cache (the spill tier) and reloads bitwise-equal
        on its next step."""
        if self._free:
            return self._free.pop()
        victim, lane = next(iter(self._lanes.items()))
        self._lanes.pop(victim)
        carry = fc.extract(self._slots, lane)
        self.cache.put(victim, carry, self._nbytes,
                       version=self._lane_stamp.pop(victim))
        self._lane_last_used.pop(victim, None)
        self.slot_spills += 1
        if self.cache.telemetry is not None:
            self.cache.telemetry.record_slots(spills=1)
        return lane

    def _ensure_resident_locked(self, fc, cid, hist, version) -> int:
        """The 'ensure resident' half of a slot step: lane hit refreshes
        LRU (re-priming in place if the weights hot-swapped under the
        lane); otherwise the carry is resolved through the spill tier /
        history / zeros path and inserted into an allocated lane."""
        now = self.cache._clock()
        lane = self._lanes.get(cid)
        if lane is not None:
            self._lanes.move_to_end(cid)
            self._lane_last_used[cid] = now
            if self._lane_stamp[cid] != version:
                if hist is not None:
                    _, _, carry = fc.prefill(hist[None])
                    self._guarded_insert(fc, lane, carry)
                    self._lane_stamp[cid] = version
                    self.reprimes += 1
                    if self.cache.telemetry is not None:
                        self.cache.telemetry.record_reprime()
                else:
                    # same config, new weights: keep the OLD stamp so a
                    # later step that does bring history still re-primes
                    self.carried_across_swap += 1
            if self.cache.telemetry is not None:
                self.cache.telemetry.record_cache(True)
            return lane
        # lane miss: spill tier -> history prefill -> zeros/error, with
        # the same version semantics as the cache path
        carry, stamp = self._resolve_carry(fc, cid, hist, version)
        self.cache.drop(cid)          # the lane owns the state now
        lane = self._alloc_lane_locked(fc)
        self._guarded_insert(fc, lane, carry)
        self._lanes[cid] = lane
        self._lane_stamp[cid] = stamp
        self._lane_last_used[cid] = now
        self.slot_inserts += 1
        if self.cache.telemetry is not None:
            self.cache.telemetry.record_slots(inserts=1)
        return lane

    def _guarded_insert(self, fc, lane, carry) -> None:
        try:
            fc.insert(self._slots, lane, carry,
                      donate=self.donate_carries)
        except Exception:
            if self.donate_carries:
                self._reset_slots_locked(fc)
            raise

    def _reset_slots_locked(self, fc) -> None:
        """A donating program failed mid-flight: the slot state may be
        consumed. Rebuild it empty — every resident session is dropped
        and re-primes from history (or zeros) on its next step."""
        self._slots = fc.init_slots(self.num_slots)
        self._lanes.clear()
        self._lane_stamp.clear()
        self._lane_last_used.clear()
        self._free = list(reversed(range(self.num_slots)))

    def _generate_chunk_locked(self, fc, items, chunk, version,
                               results) -> None:
        import numpy as np

        xs = np.zeros((self.num_slots, fc.feature_dim), np.float32)
        lanes = []
        for idx in chunk:
            cid, x_t, history = items[idx]
            x_t = np.asarray(x_t, np.float32)
            hist = self._clamp_history(fc, history)
            lane = self._ensure_resident_locked(fc, cid, hist, version)
            xs[lane] = x_t[0] if x_t.ndim == 2 else x_t
            lanes.append(lane)
        try:
            ys, ps, _ = fc.generate(self._slots, xs, lanes=lanes,
                                    donate=self.donate_carries)
        except Exception:
            if self.donate_carries:
                # the donating generate may have consumed the slot
                # state before failing — poisoned lanes would corrupt
                # every resident session, so reset the whole plane
                self._reset_slots_locked(fc)
            raise
        for row, idx in enumerate(chunk):
            results[idx] = (float(ys[lanes[row]]), float(ps[lanes[row]]))

    def spill(self, client_ids=None) -> int:
        """Spill lane-resident sessions (all, or just ``client_ids``)
        into the cache — the migration/export path: after a spill the
        cache's ``export`` sees every session, carries bitwise-identical
        to the lane state. Returns the number of sessions spilled."""
        if self._slots is None:
            return 0
        fc = self._resolve()
        if isinstance(client_ids, str):
            client_ids = [client_ids]
        with self._slots_lock:
            if client_ids is None:
                ids = list(self._lanes)
            else:
                ids = [c for c in client_ids if c in self._lanes]
            for cid in ids:
                lane = self._lanes.pop(cid)
                carry = fc.extract(self._slots, lane)
                self.cache.put(cid, carry, self._nbytes,
                               version=self._lane_stamp.pop(cid))
                self._lane_last_used.pop(cid, None)
                fc.release(self._slots, lane)
                self._free.append(lane)
                self.slot_spills += 1
            tel = self.cache.telemetry
            if ids and tel is not None:
                tel.record_slots(spills=len(ids),
                                 active=len(self._lanes),
                                 lanes=self.num_slots)
            return len(ids)

    def spill_all(self) -> int:
        return self.spill(None)

    def resident_clients(self) -> list[str]:
        """Client ids currently occupying a device lane."""
        with self._slots_lock:
            return list(self._lanes)

    def slot_stats(self) -> dict:
        with self._slots_lock:
            return {"lanes": self.num_slots,
                    "active": len(self._lanes),
                    "inserts": self.slot_inserts,
                    "spills": self.slot_spills,
                    "expiries": self.slot_expiries}

    def _run_waves(self, fc, items, waves, version, results) -> None:
        import numpy as np

        for wave in waves:
            xs = np.zeros((len(wave), fc.feature_dim), np.float32)
            carries, stamps = [], []
            for row, idx in enumerate(wave):
                cid, x_t, history = items[idx]
                x_t = np.asarray(x_t, np.float32)
                xs[row] = x_t[0] if x_t.ndim == 2 else x_t
                hist = self._clamp_history(fc, history)
                carry, stamp = self._resolve_carry(fc, cid, hist, version)
                carries.append(carry)
                stamps.append(stamp)
            try:
                ys, ps, new_carries = fc.step_many(
                    xs, carries, donate=self.donate_carries)
            except Exception:
                if self.donate_carries:
                    # the fused program may have consumed some of the
                    # donated carry buffers before failing — a cache
                    # entry pointing at a deleted buffer would poison
                    # every later step for that client. Drop the wave's
                    # sessions instead: clients re-prime from history
                    # (or zeros) on their next step.
                    for idx in wave:
                        self.cache.drop(items[idx][0])
                raise
            for row, idx in enumerate(wave):
                cid = items[idx][0]
                self.cache.put(cid, new_carries[row], self._nbytes,
                               version=stamps[row])
                results[idx] = (float(ys[row]), float(ps[row]))

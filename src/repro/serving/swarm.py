"""Fleet-wide weight propagation for the sharded serving mesh: one
*primary* ``ModelRegistry`` (where the trainer publishes) plus one
replica registry per serving shard, kept in sync by pull-based weight
transfer under a bounded staleness skew.

Model: ``WeightPublisher`` (or anyone) publishes into the swarm exactly
as into a plain registry — ``ShardSwarm`` exposes the registry facade
(``register`` / ``swap`` / ``get`` / ``version`` / ``in``) over the
primary. Every publication notifies the swarm (via
``ModelRegistry.subscribe``), which *pulls* the newest entry into each
replica that is missing the key or has fallen more than ``max_skew``
versions behind. Replicas therefore skip intermediate versions — a shard
can jump v3 -> v7 in one transfer — which is what bounded staleness
buys: per-publish fan-out cost is amortized while every shard's served
version stays within ``max_skew`` of the primary.

The skew invariant is observable atomically: ``version_vector`` /
``skew`` / ``staleness`` take the same lock the publish path holds, so
a concurrent reader never sees a shard more than ``max_skew`` versions
behind (for publishes routed through the swarm facade; publishes made
directly against the primary registry converge in the subscription
callback, one notify later).

Weight transfer reuses the launch-layer machinery: with
``transfer="device"`` a pull re-materializes the parameters through
``launch/mesh.py`` + ``launch/shardings.py`` (replicated placement on a
host mesh — the single-process stand-in for a cross-host fetch);
``transfer="reference"`` (default) shares the on-host buffers zero-copy.
"""

from __future__ import annotations

import threading
from typing import Any

from repro.serving.registry import ModelRegistry

PyTree = Any


def _params_nbytes(params) -> int:
    import jax

    return sum(int(getattr(leaf, "nbytes", 0))
               for leaf in jax.tree_util.tree_leaves(params))


class ShardSwarm:
    """Primary registry + per-shard replicas with bounded-skew pulls.

    Args:
        n_shards: number of replica registries (one per serving shard).
        primary: the registry the trainer publishes into; a fresh one is
            created when omitted. Existing entries seed every replica.
        max_skew: how many versions a replica may lag the primary before
            a publish forces it to pull (0 = every shard sees every
            version; k = shards may skip up to k-1 intermediates).
        transfer: "reference" shares parameter buffers zero-copy;
            "device" re-places each shard's replica on its own device
            (round-robin over ``jax.local_devices()``) through the host
            mesh shardings — the stand-in for a real cross-host weight
            fetch, and what lets shard flushes execute concurrently
            when multiple (real or forced-host) devices exist;
            "auto" picks "device" iff more than one device is visible.
        telemetries: optional ``{shard_id: Telemetry}`` map; a pull into
            shard i records one swap on ``telemetries[i]``.
        durable: optional ``repro.serving.durable.DurableStore``; when
            given, the primary commits every publish to it before the
            replicas (or any subscriber) are notified.

    Membership is live: ``add_replica`` seeds a new shard's registry
    from the primary (the joining shard pulls weights before taking
    traffic) and ``remove_replica`` drops a departing shard's registry.
    """

    def __init__(self, n_shards: int, primary: ModelRegistry | None = None,
                 max_skew: int = 1, transfer: str = "auto",
                 telemetries=None, durable=None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if max_skew < 0:
            raise ValueError("max_skew must be >= 0")
        if transfer not in ("reference", "device", "auto"):
            raise ValueError("transfer must be 'reference', 'device' or "
                             "'auto'")
        if transfer == "auto":
            import jax

            transfer = "device" if len(jax.local_devices()) > 1 \
                else "reference"
        self.primary = primary if primary is not None else ModelRegistry()
        if durable is not None:
            # publishes through this swarm land in the store before
            # replicas (or anyone else) see the new version
            self.primary.attach_durable(durable)
        self.replicas: dict[int, ModelRegistry] = {
            sid: ModelRegistry() for sid in range(n_shards)}
        self.max_skew = max_skew
        self.telemetries = telemetries
        self._transfer = transfer
        self._shard_shardings: dict[int, Any] = {}
        # RLock: the facade publish path re-enters via the subscription
        # callback on the same thread
        self._lock = threading.RLock()
        self._dirty: set[str] = set()
        self._wake = threading.Event()
        self._stop_evt = threading.Event()
        self._thread: threading.Thread | None = None
        self.pulls = 0               # replica weight transfers performed
        self.bytes_pulled = 0        # parameter bytes copied by device pulls
        self._attached = False
        with self._lock:
            for key, _ in self.primary.entries():
                self._pull_lagging_locked(key, force=True)
            self._sync_ensembles_locked()
        self.attach()

    @property
    def n_shards(self) -> int:
        return len(self.replicas)

    @property
    def shard_ids(self) -> list[int]:
        with self._lock:
            return sorted(self.replicas)

    # -- live membership ---------------------------------------------------
    def add_replica(self, shard_id: int) -> ModelRegistry:
        """Open a replica registry for a joining shard and pull every
        hosted key into it (the join-time weight fetch, BEFORE the shard
        takes traffic). Returns the new replica."""
        sid = int(shard_id)
        with self._lock:
            if sid in self.replicas:
                raise ValueError(f"shard {sid} already has a replica")
            self.replicas[sid] = ModelRegistry()
            for key in self.primary.keys():
                self._pull_locked(sid, key, self.primary.get_entry(key))
            # specs after weights: install validates members hosted
            self._sync_ensembles_locked()
            return self.replicas[sid]

    def remove_replica(self, shard_id: int) -> None:
        """Drop a departing shard's replica registry (no-op if absent —
        the mesh may remove a shard it already detached)."""
        with self._lock:
            self.replicas.pop(int(shard_id), None)
            if self.telemetries is not None:
                self.telemetries.pop(int(shard_id), None)

    # -- primary subscription lifecycle ------------------------------------
    def attach(self) -> "ShardSwarm":
        """(Re)subscribe to the primary's publish notifications and
        catch every replica up to the newest versions — publishes made
        while detached are reconciled here."""
        with self._lock:
            if not self._attached:
                self.primary.subscribe(self._on_publish)
                self.primary.subscribe_ensembles(self._on_ensemble)
                self._attached = True
        self.propagate()
        return self

    def detach(self) -> None:
        """Stop tracking the primary: publishes no longer fan out into
        this swarm's replicas (a stopped mesh must not keep pulling
        weights). Facade publishes still propagate — only *direct*
        primary publishes go unobserved until ``attach``."""
        with self._lock:
            if self._attached:
                self.primary.unsubscribe(self._on_publish)
                self.primary.unsubscribe_ensembles(self._on_ensemble)
                self._attached = False

    # -- registry facade (WeightPublisher-compatible) ----------------------
    def register(self, key: str, forecaster, version: int | None = None):
        with self._lock:
            self.primary.register(key, forecaster, version)
            if not self._attached:    # no callback fired: enforce inline
                self._on_publish(key, self.primary.version(key))
            return forecaster

    def swap(self, key: str, forecaster, version: int | None = None) -> int:
        with self._lock:
            v = self.primary.swap(key, forecaster, version)
            if not self._attached:
                self._on_publish(key, v)
            return v

    # ensemble specs take the same facade shape: publish on the
    # primary, sync into every replica atomically under the swarm lock.
    # Specs live in their OWN registry namespace with their own
    # subscriber list, so the weight path (`_on_publish` ->
    # `get_entry`) never sees a spec name.
    def register_ensemble(self, name: str, members, **opts):
        with self._lock:
            spec = self.primary.register_ensemble(name, members, **opts)
            if not self._attached:
                self._sync_ensembles_locked(name)
            return spec

    def swap_ensemble(self, name: str, members, **opts) -> int:
        with self._lock:
            v = self.primary.swap_ensemble(name, members, **opts)
            if not self._attached:
                self._sync_ensembles_locked(name)
            return v

    def ensemble(self, name: str):
        return self.primary.ensemble(name)

    def ensembles(self) -> dict:
        return self.primary.ensembles()

    def ensemble_version(self, name: str) -> int:
        return self.primary.ensemble_version(name)

    def get(self, key: str):
        return self.primary.get(key)

    def get_entry(self, key: str):
        return self.primary.get_entry(key)

    def version(self, key: str) -> int:
        return self.primary.version(key)

    def keys(self) -> list[str]:
        return self.primary.keys()

    def __contains__(self, key: str) -> bool:
        return key in self.primary

    def registry_for(self, shard_id: int) -> ModelRegistry:
        return self.replicas[shard_id]

    # -- propagation -------------------------------------------------------
    def _on_publish(self, key: str, version: int) -> None:
        # runs on the publishing thread, outside the primary's lock; for
        # facade publishes the swarm lock is already held, so the skew
        # bound below is enforced before the publish becomes observable
        with self._lock:
            self._dirty.add(key)
            self._pull_lagging_locked(key)
        self._wake.set()             # freshness sweep for skipped versions

    def _on_ensemble(self, name: str, spec, version: int) -> None:
        with self._lock:
            self._sync_ensembles_locked(name)

    def _sync_ensembles_locked(self, name: str | None = None) -> int:
        """Install the primary's ensemble specs into every replica
        (stale versions are skipped by ``install_ensemble``)."""
        names = ([name] if name is not None
                 else list(self.primary.ensembles()))
        installed = 0
        for n in names:
            spec = self.primary.ensemble(n)
            if spec is None:
                continue
            v = self.primary.ensemble_version(n)
            for replica in self.replicas.values():
                installed += bool(replica.install_ensemble(n, spec, v))
        return installed

    def _pull_lagging_locked(self, key: str, force: bool = False) -> int:
        entry = self.primary.get_entry(key)
        pulled = 0
        for sid, replica in self.replicas.items():
            have = replica.version(key) if key in replica else None
            behind = have is None or entry.version - have > self.max_skew
            if force:
                behind = have is None or have < entry.version
            if behind:
                self._pull_locked(sid, key, entry)
                pulled += 1
        return pulled

    def _pull_locked(self, sid: int, key: str, entry) -> None:
        replica = self.replicas[sid]
        if key in replica and replica.version(key) >= entry.version:
            return
        fc = entry.forecaster
        params = getattr(fc, "params", None)
        # a forecaster without with_params cannot carry re-placed params,
        # so don't device-transfer (and don't account) what would be
        # dropped — the replica shares the primary's object instead
        can_clone = params is not None and hasattr(fc, "with_params")
        moved = False
        if can_clone and self._transfer == "device":
            params = self._transfer_params(params, sid)
            moved = True
        if can_clone:
            # per-shard clone: each replica owns its version/published_at
            # stamps while sharing the compiled programs of the template
            fc = fc.with_params(params)
        if key in replica:
            replica.swap(key, fc, version=entry.version)
        else:
            replica.register(key, fc, version=entry.version)
        self.pulls += 1
        if moved:
            # only real copies count: reference pulls share buffers
            self.bytes_pulled += _params_nbytes(params)
        if self.telemetries is not None and sid in self.telemetries:
            self.telemetries[sid].record_swap()

    def _transfer_params(self, params: PyTree, sid: int) -> PyTree:
        import jax
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import make_host_mesh
        from repro.launch.shardings import as_shardings

        sharding = self._shard_shardings.get(sid)
        if sharding is None:
            devices = jax.local_devices()
            mesh = make_host_mesh(1, 1,
                                  devices=[devices[sid % len(devices)]])
            sharding = as_shardings(mesh, P())
            self._shard_shardings[sid] = sharding
        specs = jax.tree.map(lambda _: sharding, params)
        return jax.device_put(params, specs)

    def propagate(self, key: str | None = None) -> int:
        """Pull every replica up to the primary's newest version for
        ``key`` (or for all keys): the freshness sweep, beyond what the
        skew bound forces. Returns the number of pulls performed."""
        with self._lock:
            spec = self.primary.ensemble(key) if key is not None else None
            if spec is not None:
                # an ensemble name resolves to member weights + the spec
                pulled = sum(self._pull_lagging_locked(m, force=True)
                             for m in spec.members)
                return pulled + self._sync_ensembles_locked(key)
            keys = [key] if key is not None else self.primary.keys()
            pulled = 0
            for k in keys:
                pulled += self._pull_lagging_locked(k, force=True)
                self._dirty.discard(k)
            if key is None:
                pulled += self._sync_ensembles_locked()
            return pulled

    # -- observation -------------------------------------------------------
    def version_vector(self, key: str) -> dict:
        """Atomic fleet snapshot: ``{"primary": v, 0: v0, 1: v1, ...}``
        (missing key -> 0). Taken under the publish lock, so the skew
        bound holds in every vector this returns."""
        with self._lock:
            vec: dict = {"primary": self.primary.version(key)
                         if key in self.primary else 0}
            for sid, replica in sorted(self.replicas.items()):
                vec[sid] = replica.version(key) if key in replica else 0
            return vec

    def skew(self, key: str) -> int:
        """Largest version gap between any two serving shards."""
        vec = self.version_vector(key)
        shard_vs = [v for sid, v in vec.items() if sid != "primary"]
        return max(shard_vs) - min(shard_vs)

    def staleness(self, key: str) -> int:
        """Versions the most-lagging shard is behind the primary."""
        vec = self.version_vector(key)
        shard_vs = [v for sid, v in vec.items() if sid != "primary"]
        return vec["primary"] - min(shard_vs)

    # -- background freshness sweeps ---------------------------------------
    def start_background(self, interval_s: float = 0.02) -> "ShardSwarm":
        """Run freshness sweeps on a daemon thread: replicas that the
        skew bound allowed to skip a version still converge to the
        newest weights within ~interval_s."""
        if self._thread is not None:
            return self
        self._stop_evt.clear()

        def loop() -> None:
            while not self._stop_evt.is_set():
                self._wake.wait(interval_s)
                self._wake.clear()
                if self._stop_evt.is_set():
                    return
                self.propagate()

        self._thread = threading.Thread(target=loop, name="swarm-propagate",
                                        daemon=True)
        self._thread.start()
        return self

    def stop_background(self) -> None:
        if self._thread is None:
            return
        self._stop_evt.set()
        self._wake.set()
        self._thread.join()
        self._thread = None

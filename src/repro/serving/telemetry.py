"""Serving telemetry: latency percentiles, throughput, batch occupancy
and cache hit-rate. Pure stdlib, thread-safe, O(1) per event — cheap
enough to sit on the hot path of the micro-batcher.
"""

from __future__ import annotations

import threading
import time
from collections import deque


def _pick(data: list[float], p: float) -> float:
    """Nearest-rank percentile from an ALREADY SORTED sample list."""
    if not data:
        return 0.0
    k = min(len(data) - 1, max(0, int(round(p / 100.0 * (len(data) - 1)))))
    return data[k]


def _percentile(data: list[float], p: float) -> float:
    return _pick(sorted(data), p)


def _percentiles(data: list[float], ps) -> list[float]:
    """Several percentiles of one sample set with a SINGLE sort —
    ``snapshot``/``merge`` ask for p50/p95/p99 of the same <= 8192-sample
    reservoir, and sorting it once per snapshot instead of once per
    percentile is a 3x on the read path."""
    data = sorted(data)
    return [_pick(data, p) for p in ps]


class _Reservoir:
    """Fixed-size ring of the most recent samples (enough for stable
    p50/p95/p99 at serving rates without unbounded memory)."""

    def __init__(self, capacity: int = 8192):
        self.capacity = capacity
        self._buf: list[float] = []
        self._pos = 0

    def add(self, value: float) -> None:
        if len(self._buf) < self.capacity:
            self._buf.append(value)
        else:
            self._buf[self._pos] = value
            self._pos = (self._pos + 1) % self.capacity

    def percentile(self, p: float) -> float:
        return _percentile(self._buf, p)

    def percentiles(self, ps) -> list[float]:
        return _percentiles(self._buf, ps)


class Telemetry:
    """Counters + reservoirs for one serving engine (or one model)."""

    # per-client attribution tracks at most this many distinct client
    # ids (like the reservoirs, memory must stay bounded on a
    # long-running engine); requests from clients beyond the cap are
    # counted in ``untracked_client_requests``
    MAX_TRACKED_CLIENTS = 4096

    # sampled time-series ring: ``sample()`` snapshots land here (the
    # metrics endpoint's /history and the future autoscaler read it)
    HISTORY_CAPACITY = 512

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._lock = threading.Lock()
        self._t0 = clock()
        self._history: deque[dict] = deque(maxlen=self.HISTORY_CAPACITY)
        self._sampler: threading.Thread | None = None
        self._sampler_stop = threading.Event()
        self.requests = 0
        self.batches = 0
        self.padded_slots = 0      # total batch capacity dispatched
        self.real_slots = 0        # non-padding rows dispatched
        self.cache_hits = 0
        self.cache_misses = 0
        self.cache_evictions = 0
        self.swaps = 0             # weight hot-swaps observed (cumulative)
        self.reprimes = 0          # session carries re-primed after a swap
        # durable restore: carries re-installed from the checkpoint
        # store on a cold restart / partition re-adoption, and how many
        # of them were stamped with a superseded weight version (those
        # re-prime from history on first touch instead of resuming)
        self.restored_sessions = 0
        self.restored_stale = 0
        self.requests_by_version: dict[int, int] = {}
        self.requests_by_client: dict[str, int] = {}
        # per-model attribution: every flush is tagged with its model
        # key, so /metrics can tell ensemble members apart (each member
        # of a fan-out flushes under its own key)
        self.requests_by_model: dict[str, int] = {}
        self.untracked_client_requests = 0
        # ensemble serving: fused fan-in results and their alert
        # decisions, plus the anomaly-mode gauge (1 while any hosted
        # ensemble's fused stream is extreme)
        self.ensemble_requests = 0
        self.ensemble_alerts = 0
        self.anomaly_mode = 0
        # batched decode path: streaming steps flushed as fused batches
        self.step_requests = 0
        self.step_batches = 0
        self.step_real_slots = 0    # sessions stepped
        self.step_padded_slots = 0  # decode-lane slots dispatched
        # device-resident decode slots: cumulative insert/spill traffic
        # (like the cache counters) plus occupancy gauges (last seen)
        self.slot_inserts = 0       # sessions written into a device lane
        self.slot_spills = 0        # lane carries spilled to the cache
        self.slot_active = 0        # gauge: lanes currently occupied
        self.slot_lanes = 0         # gauge: lanes configured
        self._latency = _Reservoir()
        self._staleness = _Reservoir()   # model age at serve time (s)
        self._batch_sizes = _Reservoir()
        self._step_latency = _Reservoir()

    # -- recording ---------------------------------------------------------
    def record_request(self, latency_s: float, version: int | None = None,
                       staleness_s: float | None = None) -> None:
        with self._lock:
            self.requests += 1
            self._latency.add(latency_s)
            if version is not None:
                self.requests_by_version[version] = \
                    self.requests_by_version.get(version, 0) + 1
            if staleness_s is not None:
                self._staleness.add(staleness_s)

    def record_requests(self, latencies_s, version: int | None = None,
                        staleness_s: float | None = None,
                        client_ids=None, model: str | None = None) -> None:
        """Record one flush's worth of requests under a single lock
        acquisition (the micro-batcher calls this once per flush instead
        of ``record_request`` per row — less lock churn on the hot
        path). All rows share the flush's version/staleness/``model``
        key; ``client_ids`` (optional, one per row, None entries for
        anonymous requests) feed per-client attribution."""
        with self._lock:
            for lat in latencies_s:
                self.requests += 1
                self._latency.add(lat)
                if staleness_s is not None:
                    self._staleness.add(staleness_s)
            if version is not None and latencies_s:
                self.requests_by_version[version] = \
                    self.requests_by_version.get(version, 0) \
                    + len(latencies_s)
            if model is not None and latencies_s:
                self.requests_by_model[model] = \
                    self.requests_by_model.get(model, 0) + len(latencies_s)
            if client_ids:
                for cid in client_ids:
                    if cid is None:
                        continue
                    if cid in self.requests_by_client or \
                            len(self.requests_by_client) \
                            < self.MAX_TRACKED_CLIENTS:
                        self.requests_by_client[cid] = \
                            self.requests_by_client.get(cid, 0) + 1
                    else:
                        self.untracked_client_requests += 1

    def record_swap(self, n: int = 1) -> None:
        with self._lock:
            self.swaps += n

    def record_reprime(self, n: int = 1) -> None:
        with self._lock:
            self.reprimes += n

    def record_restore(self, n: int = 1, stale: int = 0) -> None:
        """``n`` session carries re-installed from the durable store,
        ``stale`` of which carry a superseded weight version (they fall
        back to history re-prime on their next step)."""
        with self._lock:
            self.restored_sessions += n
            self.restored_stale += stale

    def record_step_batch(self, latencies_s, n_padded: int | None = None,
                          model: str | None = None) -> None:
        """One batched streaming-step flush: per-step queue+serve
        latencies under a single lock acquisition, plus decode-lane
        occupancy (``n_padded`` = lane slots dispatched, defaults to the
        real count). ``model`` feeds the same per-model attribution as
        ``record_requests``."""
        latencies_s = list(latencies_s)
        with self._lock:
            self.step_batches += 1
            self.step_requests += len(latencies_s)
            self.step_real_slots += len(latencies_s)
            self.step_padded_slots += (n_padded if n_padded is not None
                                       else len(latencies_s))
            for lat in latencies_s:
                self._step_latency.add(lat)
            if model is not None and latencies_s:
                self.requests_by_model[model] = \
                    self.requests_by_model.get(model, 0) + len(latencies_s)

    def record_ensemble(self, latency_s: float | None = None,
                        alerts: int = 0, n: int = 1,
                        anomaly: bool = False) -> None:
        """``n`` fused ensemble results (one fan-in predict, or a step
        flush's rows), ``alerts`` of which crossed the effective alert
        threshold; ``anomaly`` is the fuser's current mode (gauge)."""
        with self._lock:
            self.ensemble_requests += n
            self.ensemble_alerts += alerts
            self.anomaly_mode = int(bool(anomaly))
            if latency_s is not None:
                self._latency.add(latency_s)

    def record_anomaly(self, anomaly: bool) -> None:
        with self._lock:
            self.anomaly_mode = int(bool(anomaly))

    def record_batch(self, n_real: int, n_padded: int) -> None:
        with self._lock:
            self.batches += 1
            self.real_slots += n_real
            self.padded_slots += n_padded
            self._batch_sizes.add(float(n_real))

    def record_cache(self, hit: bool) -> None:
        with self._lock:
            if hit:
                self.cache_hits += 1
            else:
                self.cache_misses += 1

    def record_eviction(self, n: int = 1) -> None:
        with self._lock:
            self.cache_evictions += n

    def record_slots(self, inserts: int = 0, spills: int = 0,
                     active: int | None = None,
                     lanes: int | None = None) -> None:
        """Device-resident decode-slot traffic: ``inserts``/``spills``
        accumulate (steady state adds zero of each — that is the point);
        ``active``/``lanes`` are occupancy gauges overwritten with the
        latest observation."""
        with self._lock:
            self.slot_inserts += inserts
            self.slot_spills += spills
            if active is not None:
                self.slot_active = active
            if lanes is not None:
                self.slot_lanes = lanes

    # -- reading -----------------------------------------------------------
    def latency_percentile_ms(self, p: float) -> float:
        with self._lock:
            return self._latency.percentile(p) * 1e3

    def _raw_samples_locked(self) -> dict:
        return {
            "latency_s": list(self._latency._buf),
            "staleness_s": list(self._staleness._buf),
            "batch_sizes": list(self._batch_sizes._buf),
            "step_latency_s": list(self._step_latency._buf),
        }

    def raw_samples(self) -> dict:
        """Copies of the raw reservoir samples (latency / staleness /
        batch size / step latency), taken under the telemetry lock.
        This is THE way to read the reservoirs from another thread —
        the buffers themselves are mutated concurrently by flush
        workers, so reaching into ``_latency._buf`` directly races the
        ring writes (the transport ``stats`` op used to do exactly
        that)."""
        with self._lock:
            return self._raw_samples_locked()

    def snapshot(self) -> dict:
        with self._lock:
            elapsed = max(self._clock() - self._t0, 1e-9)
            lookups = self.cache_hits + self.cache_misses
            # one sort per reservoir per snapshot (not one per
            # percentile) — see _percentiles
            lat50, lat95, lat99 = self._latency.percentiles((50, 95, 99))
            stale50, stale95 = self._staleness.percentiles((50, 95))
            batch50, batch95 = self._batch_sizes.percentiles((50, 95))
            step50, step95 = self._step_latency.percentiles((50, 95))
            return {
                "requests": self.requests,
                "batches": self.batches,
                "throughput_rps": self.requests / elapsed,
                "p50_ms": lat50 * 1e3,
                "p95_ms": lat95 * 1e3,
                "p99_ms": lat99 * 1e3,
                "mean_batch": (self.real_slots / self.batches
                               if self.batches else 0.0),
                "batch_p50": batch50,
                "batch_p95": batch95,
                "batch_occupancy": (self.real_slots / self.padded_slots
                                    if self.padded_slots else 0.0),
                "cache_hit_rate": (self.cache_hits / lookups
                                   if lookups else 0.0),
                "cache_evictions": self.cache_evictions,
                "swaps": self.swaps,
                "reprimes": self.reprimes,
                "restored_sessions": self.restored_sessions,
                "restored_stale": self.restored_stale,
                "staleness_p50_s": stale50,
                "staleness_p95_s": stale95,
                "requests_by_version": dict(self.requests_by_version),
                "requests_by_client": dict(self.requests_by_client),
                "requests_by_model": dict(self.requests_by_model),
                "unique_clients": len(self.requests_by_client),
                "untracked_client_requests":
                    self.untracked_client_requests,
                "ensemble_requests": self.ensemble_requests,
                "ensemble_alerts": self.ensemble_alerts,
                "anomaly_mode": self.anomaly_mode,
                "step_requests": self.step_requests,
                "step_batches": self.step_batches,
                "steps_per_s": self.step_requests / elapsed,
                "mean_step_batch": (self.step_real_slots / self.step_batches
                                    if self.step_batches else 0.0),
                "step_occupancy": (self.step_real_slots
                                   / self.step_padded_slots
                                   if self.step_padded_slots else 0.0),
                "step_p50_ms": step50 * 1e3,
                "step_p95_ms": step95 * 1e3,
                "slot_inserts": self.slot_inserts,
                "slot_spills": self.slot_spills,
                "slot_active": self.slot_active,
                "slot_lanes": self.slot_lanes,
                "slot_occupancy": (self.slot_active / self.slot_lanes
                                   if self.slot_lanes else 0.0),
            }

    # -- sampled time series ----------------------------------------------
    def sample(self) -> dict:
        """One snapshot, timestamped and appended to the ``history``
        ring — the time-series view of this engine's own metrics."""
        snap = self.snapshot()
        snap["ts"] = time.time()
        self._history.append(snap)
        return snap

    def history(self, n: int | None = None) -> list[dict]:
        """The sampled snapshot series, oldest first (bounded ring of
        ``HISTORY_CAPACITY`` samples)."""
        out = list(self._history)
        return out if n is None else out[-n:]

    def start_sampler(self, interval_s: float = 1.0) -> None:
        """Sample ``snapshot()`` into the history ring every
        ``interval_s`` on a daemon thread (idempotent)."""
        if interval_s <= 0:
            raise ValueError("interval_s must be > 0")
        if self._sampler is not None:
            return
        self._sampler_stop.clear()

        def loop() -> None:
            while not self._sampler_stop.wait(interval_s):
                self.sample()

        self._sampler = threading.Thread(target=loop,
                                         name="telemetry-sampler",
                                         daemon=True)
        self._sampler.start()

    def stop_sampler(self) -> None:
        if self._sampler is None:
            return
        self._sampler_stop.set()
        self._sampler.join()
        self._sampler = None

    def reset_clock(self) -> None:
        """Restart the measurement window (e.g. after jit warmup):
        throughput counters AND latency/batch reservoirs, so a snapshot
        never mixes pre-reset samples with the new window. Cache and swap
        counters are cumulative state and are kept; per-version request
        counts follow the measurement window."""
        with self._lock:
            self._t0 = self._clock()
            self.requests = 0
            self.batches = 0
            self.real_slots = 0
            self.padded_slots = 0
            self.requests_by_version = {}
            self.requests_by_client = {}
            self.requests_by_model = {}
            self.untracked_client_requests = 0
            self.ensemble_requests = 0
            self.ensemble_alerts = 0
            self.step_requests = 0
            self.step_batches = 0
            self.step_real_slots = 0
            self.step_padded_slots = 0
            self._latency = _Reservoir()
            self._staleness = _Reservoir()
            self._batch_sizes = _Reservoir()
            self._step_latency = _Reservoir()

    @staticmethod
    def merge(telemetries) -> dict:
        """Cross-shard fleet snapshot: counters summed, latency /
        staleness / batch reservoirs pooled for fleet percentiles, and
        per-version request counts merged — so per-version attribution
        stays meaningful mesh-wide. Throughput is total requests over
        the longest shard window (shards serve concurrently).

        Returns the same keys as ``snapshot`` (``Telemetry.format``
        accepts the result) plus ``"shards"`` and per-shard request
        counts under ``"requests_by_shard"``."""
        telemetries = list(telemetries)
        lat: list[float] = []
        stale: list[float] = []
        bsz: list[float] = []
        step_lat: list[float] = []
        totals = {"requests": 0, "batches": 0, "real_slots": 0,
                  "padded_slots": 0, "cache_hits": 0, "cache_misses": 0,
                  "cache_evictions": 0, "swaps": 0, "reprimes": 0,
                  "restored_sessions": 0, "restored_stale": 0,
                  "untracked_client_requests": 0, "step_requests": 0,
                  "step_batches": 0, "step_real_slots": 0,
                  "step_padded_slots": 0, "slot_inserts": 0,
                  "slot_spills": 0, "slot_active": 0, "slot_lanes": 0,
                  "ensemble_requests": 0, "ensemble_alerts": 0}
        by_version: dict[int, int] = {}
        by_client: dict[str, int] = {}
        by_model: dict[str, int] = {}
        by_shard: list[int] = []
        anomaly = 0
        elapsed = 1e-9
        for tel in telemetries:
            with tel._lock:
                elapsed = max(elapsed, tel._clock() - tel._t0)
                for k in totals:
                    totals[k] += getattr(tel, k)
                anomaly = max(anomaly, tel.anomaly_mode)
                by_shard.append(tel.requests)
                for v, n in tel.requests_by_version.items():
                    by_version[v] = by_version.get(v, 0) + n
                for c, n in tel.requests_by_client.items():
                    by_client[c] = by_client.get(c, 0) + n
                for m, n in tel.requests_by_model.items():
                    by_model[m] = by_model.get(m, 0) + n
                raw = tel._raw_samples_locked()
                lat.extend(raw["latency_s"])
                stale.extend(raw["staleness_s"])
                bsz.extend(raw["batch_sizes"])
                step_lat.extend(raw["step_latency_s"])
        lookups = totals["cache_hits"] + totals["cache_misses"]
        lat50, lat95, lat99 = _percentiles(lat, (50, 95, 99))
        stale50, stale95 = _percentiles(stale, (50, 95))
        batch50, batch95 = _percentiles(bsz, (50, 95))
        step50, step95 = _percentiles(step_lat, (50, 95))
        return {
            "shards": len(telemetries),
            "requests": totals["requests"],
            "requests_by_shard": by_shard,
            "batches": totals["batches"],
            "throughput_rps": totals["requests"] / elapsed,
            "p50_ms": lat50 * 1e3,
            "p95_ms": lat95 * 1e3,
            "p99_ms": lat99 * 1e3,
            "mean_batch": (totals["real_slots"] / totals["batches"]
                           if totals["batches"] else 0.0),
            "batch_p50": batch50,
            "batch_p95": batch95,
            "batch_occupancy": (totals["real_slots"] / totals["padded_slots"]
                                if totals["padded_slots"] else 0.0),
            "cache_hit_rate": (totals["cache_hits"] / lookups
                               if lookups else 0.0),
            "cache_evictions": totals["cache_evictions"],
            "swaps": totals["swaps"],
            "reprimes": totals["reprimes"],
            "restored_sessions": totals["restored_sessions"],
            "restored_stale": totals["restored_stale"],
            "staleness_p50_s": stale50,
            "staleness_p95_s": stale95,
            "requests_by_version": by_version,
            "requests_by_client": by_client,
            "requests_by_model": by_model,
            "unique_clients": len(by_client),
            "untracked_client_requests":
                totals["untracked_client_requests"],
            "ensemble_requests": totals["ensemble_requests"],
            "ensemble_alerts": totals["ensemble_alerts"],
            "anomaly_mode": anomaly,
            "step_requests": totals["step_requests"],
            "step_batches": totals["step_batches"],
            "steps_per_s": totals["step_requests"] / elapsed,
            "mean_step_batch": (totals["step_real_slots"]
                                / totals["step_batches"]
                                if totals["step_batches"] else 0.0),
            "step_occupancy": (totals["step_real_slots"]
                               / totals["step_padded_slots"]
                               if totals["step_padded_slots"] else 0.0),
            "step_p50_ms": step50 * 1e3,
            "step_p95_ms": step95 * 1e3,
            "slot_inserts": totals["slot_inserts"],
            "slot_spills": totals["slot_spills"],
            # gauges sum across shards: total occupied / configured lanes
            "slot_active": totals["slot_active"],
            "slot_lanes": totals["slot_lanes"],
            "slot_occupancy": (totals["slot_active"] / totals["slot_lanes"]
                               if totals["slot_lanes"] else 0.0),
        }

    @staticmethod
    def format(snap: dict) -> str:
        line = (f"{snap['requests']} req in {snap['batches']} batches | "
                f"{snap['throughput_rps']:.0f} req/s | "
                f"p50 {snap['p50_ms']:.2f} ms  p95 {snap['p95_ms']:.2f} ms  "
                f"p99 {snap['p99_ms']:.2f} ms | "
                f"mean batch {snap['mean_batch']:.1f} "
                f"(occupancy {snap['batch_occupancy']:.0%}) | "
                f"cache hit {snap['cache_hit_rate']:.0%}")
        if snap.get("swaps"):
            line += (f" | {snap['swaps']} swaps, staleness p95 "
                     f"{snap['staleness_p95_s']:.2f} s, "
                     f"{len(snap['requests_by_version'])} versions served")
        if snap.get("step_requests"):
            line += (f" | {snap['step_requests']} steps in "
                     f"{snap['step_batches']} fused flushes "
                     f"({snap['steps_per_s']:.0f} steps/s, mean batch "
                     f"{snap['mean_step_batch']:.1f}, step p95 "
                     f"{snap['step_p95_ms']:.2f} ms)")
        if snap.get("slot_lanes"):
            line += (f" | slots {snap['slot_active']}/{snap['slot_lanes']} "
                     f"resident ({snap['slot_inserts']} inserts, "
                     f"{snap['slot_spills']} spills)")
        if snap.get("restored_sessions"):
            line += (f" | restored {snap['restored_sessions']} sessions "
                     f"({snap.get('restored_stale', 0)} stale)")
        if len(snap.get("requests_by_model", {})) > 1:
            per = " ".join(f"{m}:{n}" for m, n in
                           sorted(snap["requests_by_model"].items()))
            line += f" | by model {per}"
        if snap.get("ensemble_requests"):
            line += (f" | ensemble {snap['ensemble_requests']} fused, "
                     f"{snap['ensemble_alerts']} alerts"
                     + (", ANOMALY" if snap.get("anomaly_mode") else ""))
        return line

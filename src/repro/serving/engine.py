"""Dynamic micro-batching engine: a request queue drained by a worker
that groups requests by (model, length bucket), right-pads them into
fixed bucket shapes, and dispatches one jitted apply per batch.

Flush policy: a group is dispatched as soon as it holds ``max_batch``
requests, or when its oldest request has waited ``max_wait_ms`` — the
classic latency/throughput knob. Shapes are quantized (lengths to a
bucket, batch to a power of two) so the set of compiled programs is
small and fixed: after ``warmup`` the hot path never recompiles.

``EngineShard`` is one queue + worker thread; ``ServingEngine`` is the
single-shard special case that keeps the original public API. The
sharded mesh in ``repro.serving.router`` runs several ``EngineShard``
workers side by side (each over its own registry replica) and routes
requests between them.

Streaming sessions ride the same queue: ``submit_step`` enqueues one
observation for a client's resident session, and the worker flushes
every queued step for a model as ONE fused decode dispatch per
decode-lane chunk (gather carries -> fused step+alert -> scatter back)
instead of one jit dispatch per client — the batched decode path.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.obs.trace import _EPOCH as _TRACE_EPOCH
from repro.obs.trace import FlushSpans as _FlushSpans
from repro.obs.trace import finish_all as _finish_all
from repro.serving.telemetry import Telemetry


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    max_batch: int = 32
    max_wait_ms: float = 2.0
    # admissible padded lengths; () -> round up to the next power of two
    length_buckets: tuple[int, ...] = ()
    # pad the batch dim to a power of two (<= max_batch) so compiled
    # shapes are {pow2 batches} x {length buckets}, not arbitrary
    pad_batch: bool = True
    # device-resident decode lanes per model runner (rounded up to the
    # forecaster's decode width): streaming sessions stay resident on
    # device between steps and a flush is ONE fused generate dispatch.
    # 0 disables slots and restores the cache gather/scatter decode path
    decode_slots: int = 64

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.decode_slots < 0:
            raise ValueError(
                f"decode_slots must be >= 0, got {self.decode_slots}")
        if self.pad_batch and self.max_batch & (self.max_batch - 1):
            # a non-pow2 max_batch would make bucket_batch emit a non-pow2
            # clamped shape, breaking the "{pow2 batches} x {length
            # buckets}" fixed compile-set contract — round it down
            object.__setattr__(self, "max_batch",
                               1 << (self.max_batch.bit_length() - 1))

    def bucket_len(self, t: int) -> int:
        if not self.length_buckets:
            return _next_pow2(max(t, 8))
        for b in sorted(self.length_buckets):
            if t <= b:
                return b
        # longer than every configured bucket: clamp to the largest one
        # instead of emitting an uncompiled shape (the raw length used to
        # escape the fixed compile set and recompile on the serving hot
        # path — ``warmup`` never warms such shapes). ``submit`` truncates
        # the payload to its newest ``bucket`` rows; the LSTM is causal,
        # so those rows are exactly what the clamped window serves.
        return max(self.length_buckets)

    def bucket_batch(self, n: int) -> int:
        if not self.pad_batch:
            return n
        return min(_next_pow2(n), self.max_batch)


class _Request:
    __slots__ = ("payload", "length", "future", "t_enq", "client_id",
                 "trace", "t_trace")

    def __init__(self, payload: np.ndarray, t_enq: float,
                 client_id: str | None = None, trace=None,
                 t_trace=None):
        self.payload = payload
        self.length = payload.shape[0]
        self.future: Future = Future()
        self.t_enq = t_enq
        self.client_id = client_id
        self.trace = trace          # upstream TraceContext | None
        self.t_trace = t_trace      # deferred-trace submit stamp | None


class _StepRequest:
    """One streaming step: a single feature vector for a session whose
    carry lives in the shard's session cache. Grouped per model and
    flushed as ONE fused decode dispatch (``RecurrentSessionRunner.
    step_many``), not one dispatch per client."""

    __slots__ = ("payload", "history", "future", "t_enq", "client_id",
                 "trace", "t_trace")

    def __init__(self, payload: np.ndarray, t_enq: float, client_id: str,
                 history=None, trace=None, t_trace=None):
        self.payload = payload
        self.history = history
        self.future: Future = Future()
        self.t_enq = t_enq
        self.client_id = client_id
        self.trace = trace          # upstream TraceContext | None
        self.t_trace = t_trace      # deferred-trace submit stamp | None


# pseudo length-bucket under which step requests group in the pending
# map: one flush group per model, orthogonal to the window buckets
_STEP_BUCKET = -1


class _Quiesce:
    """Queue sentinel: when the worker dequeues one, everything enqueued
    before it has reached the pending map — force-flush it all and wake
    the waiter. Lets another thread (e.g. the transport worker's session
    ``extract``) serialize against in-flight steps without stopping the
    engine."""

    __slots__ = ("event",)

    def __init__(self):
        self.event = threading.Event()


class EngineShard:
    """One serving worker: a request queue drained by a thread that
    groups, pads and dispatches micro-batches over a ``ModelRegistry``
    (anything with ``get(key) -> forecaster`` works). ``shard_id``
    names the worker in thread names and mesh telemetry."""

    def __init__(self, registry, config: BatcherConfig | None = None,
                 telemetry: Telemetry | None = None, shard_id: int = 0,
                 session_cache=None, tracer=None,
                 donate_carries: bool | None = None):
        self.registry = registry
        self.config = config or BatcherConfig()
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.shard_id = shard_id
        # donate session carries to the fused step? None -> platform
        # default (on off-CPU, off on CPU). Safe only while the flush
        # worker is the sole toucher of the session state during
        # serving; the transport worker passes False because its recv
        # loop can ``extract``/``restore`` carries concurrently with
        # flushes
        self.donate_carries = donate_carries
        # per-request trace spans (repro.obs.Tracer); None -> no tracing
        self.tracer = tracer
        # trace meta is shared by reference (one dict per model, not one
        # per request) — Tracer.start keeps it without copying
        self._trace_meta: dict[str, dict] = {}
        self._queue: queue.Queue = queue.Queue()
        self._pending: dict[tuple[str, int], list] = {}
        self._running = False
        # makes submit's running-check + enqueue atomic w.r.t. stop()
        self._state_lock = threading.Lock()
        self._thread: threading.Thread | None = None
        # streaming sessions: shard-local carry cache + one batched
        # runner per hosted model, built lazily on the first step
        self._session_cache = session_cache
        self._runners: dict[str, object] = {}
        self._runners_lock = threading.Lock()
        # ensemble serving: one EnsembleForecaster runtime per hosted
        # ensemble name (holds the shard's online fusion/anomaly state,
        # shared by the predict fan-in path and the step flush), plus
        # the anomaly-driven max_wait multipliers the flush worker
        # consults per model key (1.0 when absent)
        self._ensemble_runtimes: dict[str, object] = {}
        self._wait_scales: dict[str, float] = {}

    @property
    def sessions(self):
        """The shard-local session cache (created on first use)."""
        if self._session_cache is None:
            with self._runners_lock:
                if self._session_cache is None:
                    from repro.serving.sessions import SessionCache

                    self._session_cache = SessionCache(
                        telemetry=self.telemetry)
        return self._session_cache

    def _ensemble_spec(self, model_key: str):
        """The EnsembleSpec hosted under ``model_key``, or None when the
        key is a plain model (or the registry is a duck-typed stand-in
        without ensembles) — how the serve paths tell fan-out requests
        from single-model requests."""
        fn = getattr(self.registry, "ensemble", None)
        return fn(model_key) if fn is not None else None

    def _ensemble(self, name: str):
        """This shard's EnsembleForecaster runtime for ``name`` (built
        lazily; holds the online fusion weights and anomaly state)."""
        rt = self._ensemble_runtimes.get(name)
        if rt is None:
            with self._runners_lock:
                rt = self._ensemble_runtimes.get(name)
                if rt is None:
                    from repro.serving.ensemble import EnsembleForecaster

                    rt = EnsembleForecaster(self.registry, name)
                    self._ensemble_runtimes[name] = rt
        return rt

    def _note_anomaly(self, name: str, spec, rt) -> None:
        """Fold the ensemble's anomaly state into the flush worker's
        max_wait multipliers: while the fused stream is anomalous, the
        ensemble AND its members flush sooner (alert latency beats
        batch occupancy under extremes)."""
        scale = rt.fuser().wait_scale()
        keys = (name,) + tuple(spec.members)
        if scale == 1.0:
            for k in keys:
                self._wait_scales.pop(k, None)
        else:
            for k in keys:
                self._wait_scales[k] = scale
        self.telemetry.record_anomaly(rt.fuser().anomaly)

    def _wait_scale(self, model_key: str) -> float:
        if not self._wait_scales:
            return 1.0
        return self._wait_scales.get(model_key, 1.0)

    def _step_runner(self, model_key: str):
        runner = self._runners.get(model_key)
        if runner is None:
            cache = self.sessions   # resolve BEFORE taking the lock
            with self._runners_lock:
                runner = self._runners.get(model_key)
                if runner is None:
                    from repro.serving.sessions import \
                        RecurrentSessionRunner

                    # provider-backed: the runner re-resolves the
                    # registry key each flush, so weight hot-swaps are
                    # picked up without rebuilding the runner. Carry
                    # donation (no-op on CPU) follows the shard knob —
                    # see __init__
                    # slot-capable forecasters get decode_slots device
                    # lanes; others (and decode_slots=0) keep the
                    # gather/scatter path
                    # ensemble names resolve to the shard's stable
                    # EnsembleForecaster runtime (which re-resolves its
                    # members per call): composite per-member carries
                    # live under ONE client id in the same cache, so
                    # they spill/migrate as a unit
                    if self._ensemble_spec(model_key) is not None:
                        provider = lambda: self._ensemble(model_key)  # noqa: E731
                    else:
                        provider = lambda: self.registry.get(model_key)  # noqa: E731
                    fc = provider()
                    n_slots = self.config.decode_slots \
                        if hasattr(fc, "init_slots") else 0
                    runner = RecurrentSessionRunner(
                        provider, cache=cache,
                        donate_carries=self.donate_carries,
                        num_slots=n_slots)
                    self._runners[model_key] = runner
        return runner

    def spill_sessions(self, client_ids=None) -> int:
        """Spill lane-resident session carries (all models; optionally
        just ``client_ids``) into the shard's session cache, so
        ``sessions.export`` sees every live session — the migration /
        drain path. Returns the number of sessions spilled."""
        with self._runners_lock:
            runners = list(self._runners.values())
        return sum(r.spill(client_ids) for r in runners)

    def snapshot_sessions(self, client_ids=None) -> list:
        """Non-destructive snapshot of every live session on this
        shard — the durable-checkpoint path.  Lane-resident carries
        spill to the cache first (bitwise; the slot lock serializes
        this against the flush thread, so no quiesce and no stalled
        flush), then the cache is READ, not drained.  Returns
        ``(client_id, carry, nbytes, version)`` tuples."""
        self.spill_sessions(client_ids)
        return self.sessions.snapshot(client_ids)

    def session_clients(self) -> list[str]:
        """Every client with live session state on this shard: spill
        tier (cache) plus lane-resident sessions."""
        clients = set(self.sessions.clients())
        with self._runners_lock:
            runners = list(self._runners.values())
        for r in runners:
            clients.update(r.resident_clients())
        return sorted(clients)

    def slot_stats(self) -> dict:
        """Aggregate decode-slot occupancy over this shard's runners."""
        with self._runners_lock:
            runners = list(self._runners.values())
        agg = {"lanes": 0, "active": 0, "inserts": 0, "spills": 0,
               "expiries": 0}
        for r in runners:
            for k, v in r.slot_stats().items():
                agg[k] += v
        return agg

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "EngineShard":
        with self._state_lock:
            if self._running:
                return self
            self._running = True
        self._thread = threading.Thread(
            target=self._worker, name=f"serving-shard-{self.shard_id}",
            daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        with self._state_lock:
            if not self._running:
                return
            self._running = False
        # any submit that saw _running under the lock has already enqueued,
        # and the worker drains queue + pending before exiting; submits
        # from here on raise instead of enqueueing into a dead engine
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "EngineShard":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _meta_for(self, model_key: str) -> dict:
        meta = self._trace_meta.get(model_key)
        if meta is None:
            meta = self._trace_meta[model_key] = {"model": model_key,
                                                  "shard": self.shard_id}
        return meta

    @staticmethod
    def _trace_gather(tracer, reqs):
        """One pass over a flush's requests collecting its tracing work:
        ``traced`` are upstream TraceContexts (cross-process requests)
        joining the shared FlushSpans record; ``deferred`` are
        (t_submit, t_enq) perf-counter pairs for in-process requests,
        folded into one ring block by ``Tracer.finish_block`` — their
        Trace objects only materialize when somebody reads the ring."""
        traced, deferred, fspans = [], [], None
        for r in reqs:
            if r.trace is not None:
                if fspans is None:
                    fspans = _FlushSpans()
                r.trace.attach_flush(fspans, r.t_enq)
                traced.append(r.trace)
            elif r.t_trace is not None and tracer is not None:
                deferred.append((r.t_trace, r.t_enq))
        if deferred and fspans is None:
            fspans = _FlushSpans()
        return traced, deferred, fspans

    # -- client API --------------------------------------------------------
    def submit(self, model_key: str, window,
               client_id: str | None = None, trace=None) -> Future:
        """Enqueue one window ([T, F] features or [T] token ids); returns
        a Future resolving to (forecast, p_extreme) scalars.
        ``client_id`` rides along into per-client telemetry attribution
        (the sharded mesh additionally routes on it; a single shard
        serves every client). ``trace`` is an upstream TraceContext
        (the mesh router starts one); with none given, a shard-level
        tracer opens its own."""
        spec = self._ensemble_spec(model_key)
        if spec is not None:
            return self._submit_ensemble(model_key, spec, window,
                                         client_id=client_id, trace=trace)
        # fully deferred in-process tracing: the client thread stashes ONE
        # clock stamp; the flush worker later folds the whole micro-batch
        # into a single trace block (Tracer.finish_block). No Trace object
        # is allocated on this path at all.
        tracer = self.tracer
        t_tr = (time.perf_counter()
                if trace is None and tracer is not None and tracer.enabled
                else None)
        try:
            payload = np.asarray(window)
            fc = self.registry.get(model_key)
            want_ndim = 2 if fc.feature_dim else 1
            if payload.ndim != want_ndim or payload.shape[0] < 1 or (
                    fc.feature_dim and payload.shape[1] != fc.feature_dim):
                raise ValueError(
                    f"{model_key!r} expects windows of shape "
                    f"{'[T>=1, ' + str(fc.feature_dim) + ']' if fc.feature_dim else '[T>=1]'}"
                    f", got {payload.shape}")
        except Exception:
            # a synchronous reject must not vanish from the trace ring:
            # record it as an error trace before re-raising (cold path, so
            # an eager Trace is fine here)
            if trace is not None:
                trace.finish(status="error")
            elif t_tr is not None:
                err = tracer.start("predict", t0=_TRACE_EPOCH + t_tr,
                                   meta=self._meta_for(model_key))
                if err is not None:
                    err.finish(status="error")
            raise
        bucket = self.config.bucket_len(payload.shape[0])
        if payload.shape[0] > bucket:
            # over-long window clamped to the largest length bucket: keep
            # the newest rows (causal model) so the compile set stays fixed
            payload = payload[-bucket:]
        req = _Request(payload, time.perf_counter(), client_id=client_id,
                       trace=trace, t_trace=t_tr)
        with self._state_lock:
            if not self._running:
                raise RuntimeError("engine is not running (use start() or a "
                                   "with-block)")
            self._queue.put((model_key, req))
        return req.future

    def predict(self, model_key: str, window, timeout: float | None = 30.0,
                client_id: str | None = None):
        return self.submit(model_key, window,
                           client_id=client_id).result(timeout=timeout)

    def _submit_ensemble(self, name: str, spec, window,
                         client_id: str | None = None,
                         trace=None) -> Future:
        """Fan one request across every ensemble member and join on a
        fan-in future: each member rides its OWN per-model bucket (so
        an N-member flush is exactly N fused per-model dispatches, each
        bitwise-identical to serving that member solo), and the last
        member's completion fuses the results with the shard's online
        EVT weights. The fan-in future resolves to the fused
        (forecast, p_extreme); per-member results, fusion weights and
        the alert/anomaly decision ride on it as attributes."""
        tracer = self.tracer
        if trace is None and tracer is not None and tracer.enabled:
            # eager trace (the fan-out already costs N submits): member
            # completions and the fuse land as spans on ONE trace
            trace = tracer.start("ensemble", meta=self._meta_for(name))
        rt = self._ensemble(name)
        members = spec.members
        n = len(members)
        fanin: Future = Future()
        fanin.client_id = client_id
        t0 = time.perf_counter()
        state = {"y": [0.0] * n, "p": [0.0] * n, "t": [0.0] * n,
                 "v": [None] * n, "done": 0}
        lock = threading.Lock()

        def _finish(exc=None):
            if not fanin.set_running_or_notify_cancel():
                return                      # client cancelled the fan-in
            if exc is not None:
                if trace is not None:
                    trace.finish(status="error")
                fanin.set_exception(exc)
                return
            try:
                ys = [np.asarray([state["y"][j]], np.float32)
                      for j in range(n)]
                ps = [np.asarray([state["p"][j]], np.float32)
                      for j in range(n)]
                fused = rt.fuse(ys, ps)
                self._note_anomaly(name, spec, rt)
            except Exception as e:  # noqa: BLE001 — spec swapped under us
                if trace is not None:
                    trace.finish(status="error")
                fanin.set_exception(e)
                return
            now = time.perf_counter()
            self.telemetry.record_ensemble(
                latency_s=now - t0, alerts=int(fused.alerts[0]),
                anomaly=fused.anomaly)
            fanin.model_version = tuple(state["v"])
            fanin.members = dict(zip(members, zip(state["y"], state["p"])))
            fanin.weights = np.asarray(fused.weights)
            fanin.alert = bool(fused.alerts[0])
            fanin.alert_threshold = fused.threshold
            fanin.anomaly = fused.anomaly
            if trace is not None:
                # member completion spans in completion order (a trace's
                # marks chain forward in time), then the fuse, then done
                for j in sorted(range(n), key=lambda j: state["t"][j]):
                    trace.mark("member", t=_TRACE_EPOCH + state["t"][j],
                               model=members[j])
                trace.mark("fuse")
                trace.finish()      # before set_result: a cross-process
                # done-callback exports the spans at delivery
            fanin.set_result((float(fused.forecast[0]),
                              float(fused.p_extreme[0])))

        def _member_cb(i):
            def cb(fut):
                done = failed = None
                with lock:
                    if state["done"] < 0:
                        return
                    try:
                        y, p = fut.result()
                    except Exception as e:  # noqa: BLE001
                        state["done"] = -1
                        failed = e
                    else:
                        state["y"][i] = float(y)
                        state["p"][i] = float(p)
                        state["t"][i] = time.perf_counter()
                        state["v"][i] = getattr(fut, "model_version", None)
                        state["done"] += 1
                        done = state["done"] == n
                if failed is not None:
                    _finish(failed)
                elif done:
                    _finish()
            return cb

        for i, m in enumerate(members):
            try:
                self.submit(m, window,
                            client_id=client_id).add_done_callback(
                                _member_cb(i))
            except Exception as e:  # noqa: BLE001 — sync member reject
                with lock:
                    if state["done"] >= 0:
                        state["done"] = -1
                        _finish(e)
                break
        return fanin

    def submit_step(self, model_key: str, client_id: str, x_t,
                    history=None, trace=None) -> Future:
        """Enqueue one streaming step for ``client_id``'s session:
        ``x_t`` is a single [F] feature vector (the newest observation),
        ``history`` an optional [T, F] window prefix replayed on a cache
        miss. Steps for a model group into ONE fused decode dispatch per
        flush — the batched Pallas/XLA decode path — instead of one
        dispatch per client. Returns a Future resolving to
        (forecast, p_extreme) scalars."""
        tracer = self.tracer
        t_tr = (time.perf_counter()       # deferred trace — see submit()
                if trace is None and tracer is not None and tracer.enabled
                else None)
        try:
            # ensemble names resolve to the shard's runtime (protocol-
            # compatible: validation below sees the members' shared
            # feature_dim); the step then rides the SAME queue/flush
            # machinery under the ensemble name
            fc = (self._ensemble(model_key)
                  if self._ensemble_spec(model_key) is not None
                  else self.registry.get(model_key))
            if not hasattr(fc, "step") or not fc.feature_dim:
                raise ValueError(
                    f"{model_key!r} does not support incremental session "
                    f"serving (needs step/init_carry/replay and a feature "
                    f"dim)")
            payload = np.asarray(x_t, np.float32)
            if payload.ndim == 2 and payload.shape[0] == 1:
                payload = payload[0]
            if payload.shape != (fc.feature_dim,):
                raise ValueError(
                    f"{model_key!r} expects step vectors of shape "
                    f"[{fc.feature_dim}], got {payload.shape}")
            if history is not None:
                # validate HERE, against this caller only: a malformed
                # history that first blew up inside the flush would fail
                # every other client's step sharing that fused batch
                history = np.asarray(history, np.float32)
                if history.ndim != 2 or history.shape[0] < 1 \
                        or history.shape[1] != fc.feature_dim:
                    raise ValueError(
                        f"history must be [T>=1, {fc.feature_dim}], got "
                        f"{history.shape}")
            if client_id is None:
                raise ValueError("streaming steps require a client_id "
                                 "(the session key)")
        except Exception:
            if trace is not None:
                trace.finish(status="error")    # see submit()
            elif t_tr is not None:
                err = tracer.start("step", t0=_TRACE_EPOCH + t_tr,
                                   meta=self._meta_for(model_key))
                if err is not None:
                    err.finish(status="error")
            raise
        req = _StepRequest(payload, time.perf_counter(), str(client_id),
                           history=history, trace=trace, t_trace=t_tr)
        with self._state_lock:
            if not self._running:
                raise RuntimeError("engine is not running (use start() or a "
                                   "with-block)")
            self._queue.put((model_key, req))
        return req.future

    def step(self, model_key: str, client_id: str, x_t, history=None,
             timeout: float | None = 30.0):
        """Blocking ``submit_step`` — one (forecast, p_extreme) tuple."""
        return self.submit_step(model_key, client_id, x_t,
                                history=history).result(timeout=timeout)

    def quiesce(self, timeout: float | None = 30.0) -> bool:
        """Block until every request enqueued before this call has been
        flushed (results delivered), without stopping the engine. Used
        by the transport worker to serialize session ``extract`` against
        in-flight streaming steps. Returns False on timeout; True
        immediately if the engine is not running (queue already
        drained)."""
        with self._state_lock:
            if not self._running:
                return True
            q = _Quiesce()
            self._queue.put((None, q))
        return q.event.wait(timeout)

    def warmup(self, model_key: str, lengths: tuple[int, ...] | None = None
               ) -> int:
        """Compile every (pow2 batch) x (length bucket) apply the hot path
        can hit, off the serving path. Returns #programs warmed."""
        spec = self._ensemble_spec(model_key)
        if spec is not None:
            # an ensemble's compile set IS its members' (fan-out serves
            # through their buckets); the runner build warms the
            # ensemble replay/slot programs on top (mostly cache hits)
            n = sum(self.warmup(m, lengths=lengths) for m in spec.members)
            if self._ensemble(model_key).feature_dim:
                self._step_runner(model_key)
            return n
        fc = self.registry.get(model_key)
        lens = lengths if lengths is not None else (fc.window,)
        max_b = self.config.max_batch
        # exactly the shapes bucket_batch can emit: the powers of two up
        # to max_batch (itself a power of two after __post_init__)
        if self.config.pad_batch:
            batches = sorted({min(1 << i, max_b)
                              for i in range(max_b.bit_length() + 1)})
        else:
            # unquantized batches: any size 1..max_batch can reach the
            # hot path, so all of them must be compiled here
            batches = list(range(1, max_b + 1))
        n = 0
        for t in {self.config.bucket_len(x) for x in lens}:
            for b in batches:
                fc.predict(*self._padded(fc, [np.zeros(
                    self._payload_shape(fc, t), self._payload_dtype(fc))] * b,
                    [t] * b, b, t))
                n += 1
        if hasattr(fc, "warm_decode") and fc.feature_dim:
            # the streaming decode lane: single step, batched flush and
            # miss-replay programs, plus the runner itself (its ctor
            # pre-compiles the full-window replay) — all off the hot path
            n += fc.warm_decode()
            self._step_runner(model_key)
        return n

    # -- batching internals ------------------------------------------------
    @staticmethod
    def _payload_shape(fc, t: int):
        return (t, fc.feature_dim) if fc.feature_dim else (t,)

    @staticmethod
    def _payload_dtype(fc):
        return np.float32 if fc.feature_dim else np.int32

    def _padded(self, fc, payloads, lengths, bucket_b: int, bucket_t: int):
        """Stack variable-length payloads into one right-padded batch of
        shape [bucket_b, bucket_t, ...]; padded rows get length 1."""
        shape = (bucket_b,) + self._payload_shape(fc, bucket_t)
        x = np.zeros(shape, self._payload_dtype(fc))
        out_len = np.ones((bucket_b,), np.int32)
        for i, (p, t) in enumerate(zip(payloads, lengths)):
            x[i, :t] = p
            out_len[i] = t
        return x, out_len

    def _flush_steps(self, model_key: str, reqs: list[_StepRequest]) -> None:
        """One batched decode flush: every queued step for ``model_key``
        becomes one fused dispatch per decode-lane chunk via the
        session runner's gather/scatter ``step_many``."""
        reqs = [r for r in reqs if r.future.set_running_or_notify_cancel()]
        if not reqs:
            return
        tracer = self.tracer
        traced, deferred, fspans = self._trace_gather(tracer, reqs)
        if fspans is not None:
            t0f = fspans.stamp("queue")
        try:
            runner = self._step_runner(model_key)
            fc = runner._resolve()
            outs = runner.step_many([(r.client_id, r.payload, r.history)
                                     for r in reqs])
        except Exception as e:  # noqa: BLE001 — fail the steps, not the engine
            for r in reqs:
                r.future.set_exception(e)
            _finish_all(traced, status="error")
            if deferred:
                tracer.finish_block("step", self._meta_for(model_key),
                                    fspans, deferred, status="error")
            return
        if fspans is not None:
            fspans.stamp("dispatch")
        now = time.perf_counter()
        version = getattr(fc, "version", None)
        # lane slots actually dispatched (waves for duplicate clients,
        # each padded to the decode width) — counted by the runner at
        # the dispatch decision, not re-derived here
        padded = getattr(runner, "last_step_slots", len(reqs))
        self.telemetry.record_step_batch([now - r.t_enq for r in reqs],
                                         n_padded=padded,
                                         model=model_key)
        spec = self._ensemble_spec(model_key)
        if spec is not None:
            # the fuse happened inside the ensemble runtime's step_many;
            # surface its alert/anomaly outcome into telemetry and the
            # flush worker's max_wait multipliers
            rt = self._ensemble(model_key)
            thr = rt.fuser().alert_threshold()
            self._note_anomaly(model_key, spec, rt)
            self.telemetry.record_ensemble(
                alerts=sum(1 for _, p in outs if p >= thr), n=len(outs),
                anomaly=rt.fuser().anomaly)
        if fspans is not None:
            # scatter + the umbrella flush span BEFORE set_result: the
            # transport worker's done-callback exports the trace, so
            # anything after delivery would be lost cross-process
            fspans.umbrella("flush", t0f, fspans.stamp("scatter"))
        for r, (y, p) in zip(reqs, outs):
            r.future.model_version = version
            r.future.client_id = r.client_id
            r.future.set_result((y, p))
        if fspans is not None:
            # exported traces (the transport worker's done-callback runs
            # inside set_result) materialized before this stamp and are
            # closed, so the reply span and finish only land on the
            # in-process traces — see obs.trace
            fspans.stamp("reply")
            _finish_all(traced)
            if deferred:
                tracer.finish_block("step", self._meta_for(model_key),
                                    fspans, deferred)

    def _flush(self, model_key: str, bucket_t: int,
               reqs: list[_Request]) -> None:
        if bucket_t == _STEP_BUCKET:
            self._flush_steps(model_key, reqs)
            return
        # transition futures to RUNNING; drops client-cancelled requests
        # and guarantees the set_result/set_exception below cannot raise
        # InvalidStateError into the worker thread
        reqs = [r for r in reqs if r.future.set_running_or_notify_cancel()]
        if not reqs:
            return
        tracer = self.tracer
        traced, deferred, fspans = self._trace_gather(tracer, reqs)
        if fspans is not None:
            t0f = fspans.stamp("queue")   # enqueue -> flush start
        try:
            # one atomic reference per flush: the whole micro-batch serves
            # on these weights even if the registry swaps mid-predict; the
            # next flush re-resolves and picks up the new version
            fc = self.registry.get(model_key)
            bucket_b = self.config.bucket_batch(len(reqs))
            x, lens = self._padded(fc, [r.payload for r in reqs],
                                   [r.length for r in reqs], bucket_b,
                                   bucket_t)
            if fspans is not None:
                fspans.stamp("gather", meta={"batch": len(reqs),
                                             "padded": bucket_b})
            forecast, p_extreme = fc.predict(x, lens)
        except Exception as e:  # noqa: BLE001 — fail the requests, not the engine
            for r in reqs:
                r.future.set_exception(e)
            _finish_all(traced, status="error")
            if deferred:
                tracer.finish_block("predict", self._meta_for(model_key),
                                    fspans, deferred, status="error")
            return
        if fspans is not None:
            fspans.stamp("dispatch")
        now = time.perf_counter()
        version = getattr(fc, "version", None)
        published = getattr(fc, "published_at", None)
        staleness = (now - published) if published is not None else None
        self.telemetry.record_batch(len(reqs), bucket_b)
        self.telemetry.record_requests([now - r.t_enq for r in reqs],
                                       version=version,
                                       staleness_s=staleness,
                                       client_ids=[r.client_id
                                                   for r in reqs],
                                       model=model_key)
        if fspans is not None:
            # scatter + the umbrella flush span (overlapping the chained
            # queue/gather/dispatch/scatter spans) BEFORE set_result:
            # the transport worker's done-callback exports the trace, so
            # anything recorded after delivery would be lost cross-process
            fspans.umbrella("flush", t0f, fspans.stamp("scatter"))
        for i, r in enumerate(reqs):
            # attribution before set_result: a client that wakes on the
            # result always sees which model version produced it
            r.future.model_version = version
            r.future.client_id = r.client_id
            r.future.set_result((float(forecast[i]), float(p_extreme[i])))
        if fspans is not None:
            # exported traces (the transport worker's done-callback runs
            # inside set_result) materialized before this stamp and are
            # closed, so the reply span and finish only land on the
            # in-process traces — see obs.trace
            fspans.stamp("reply")
            _finish_all(traced)
            if deferred:
                tracer.finish_block("predict", self._meta_for(model_key),
                                    fspans, deferred)

    def _flush_all(self) -> None:
        """Dispatch every pending group right now (max_batch chunks)."""
        for key in list(self._pending):
            reqs = self._pending.pop(key)
            while reqs:
                self._flush(key[0], key[1], reqs[:self.config.max_batch])
                del reqs[:self.config.max_batch]

    def _worker(self) -> None:
        cfg = self.config
        max_wait = cfg.max_wait_ms * 1e-3
        while self._running or not self._queue.empty() or self._pending:
            # drain everything already queued, then block briefly
            drained = False
            while True:
                try:
                    model_key, req = self._queue.get_nowait()
                except queue.Empty:
                    break
                drained = True
                if isinstance(req, _Quiesce):
                    # everything enqueued before the sentinel is in the
                    # pending map by now — flush it and wake the waiter
                    self._flush_all()
                    req.event.set()
                    continue
                key = (model_key,
                       _STEP_BUCKET if isinstance(req, _StepRequest)
                       else cfg.bucket_len(req.length))
                self._pending.setdefault(key, []).append(req)
            now = time.perf_counter()
            # flush full groups and expired groups; an anomalous
            # ensemble tightens its (and its members') max_wait so
            # alerts leave the queue sooner while the stream is extreme
            for key in list(self._pending):
                reqs = self._pending[key]
                while len(reqs) >= cfg.max_batch:
                    self._flush(key[0], key[1], reqs[:cfg.max_batch])
                    del reqs[:cfg.max_batch]
                wait = max_wait * self._wait_scale(key[0])
                if reqs and (now - reqs[0].t_enq >= wait
                             or not self._running):
                    self._flush(key[0], key[1], reqs)
                    reqs.clear()
                if not reqs:
                    del self._pending[key]
            if drained:
                continue
            # sleep until the next group deadline (or a short poll)
            timeout = max_wait if not self._pending else max(
                1e-4, min(r[0].t_enq + max_wait * self._wait_scale(k[0])
                          for k, r in self._pending.items())
                - time.perf_counter())
            try:
                model_key, req = self._queue.get(timeout=min(timeout, 0.05))
            except queue.Empty:
                continue
            if isinstance(req, _Quiesce):
                self._flush_all()
                req.event.set()
                continue
            key = (model_key,
                   _STEP_BUCKET if isinstance(req, _StepRequest)
                   else cfg.bucket_len(req.length))
            self._pending.setdefault(key, []).append(req)


class ServingEngine(EngineShard):
    """Single-shard serving engine — the original public API
    (``submit`` / ``predict`` / ``warmup``), now a thin special case of
    ``EngineShard``. The sharded mesh (``repro.serving.router``) runs
    the same code path once per shard."""

"""Streaming forecast serving: dynamic micro-batching, recurrent session
cache, multi-model registry, and extreme-event alerting.

Layout (DESIGN: one concern per module):

- ``engine.py``     request queue + dynamic micro-batcher (length-bucketed
                    padding, flush on max-batch or max-wait, jit-cached
                    per-bucket apply so the hot path never recompiles);
- ``sessions.py``   per-client recurrent carry cache (LRU + TTL + byte
                    accounting) making each streaming step O(1);
- ``forecaster.py`` one ``predict(window) -> (forecast, p_extreme)``
                    interface over the paper LSTM and every zoo arch,
                    with the EVT tail alert head;
- ``registry.py``   multi-model hosting keyed by name, monotone model
                    versions, atomic weight swap, checkpoint I/O;
- ``hotswap.py``    online-learning bridge: the local-SGD round loop
                    publishes worker-averaged params as new versions
                    without dropping in-flight requests;
- ``telemetry.py``  latency percentiles, throughput, batch occupancy,
                    cache hit-rate, swap count, staleness at serve time,
                    per-version request counts.
"""

from repro.serving.engine import BatcherConfig, ServingEngine
from repro.serving.forecaster import (LSTMForecaster, ZooForecaster,
                                      build_lstm_forecaster,
                                      build_zoo_forecaster)
from repro.serving.hotswap import WeightPublisher, stop_the_world_swap
from repro.serving.registry import ModelRegistry, RegistryEntry
from repro.serving.sessions import RecurrentSessionRunner, SessionCache
from repro.serving.telemetry import Telemetry

__all__ = [
    "BatcherConfig",
    "LSTMForecaster",
    "ModelRegistry",
    "RecurrentSessionRunner",
    "RegistryEntry",
    "ServingEngine",
    "SessionCache",
    "Telemetry",
    "WeightPublisher",
    "ZooForecaster",
    "build_lstm_forecaster",
    "build_zoo_forecaster",
    "stop_the_world_swap",
]

"""Streaming forecast serving: dynamic micro-batching, recurrent session
cache, multi-model registry, and extreme-event alerting.

Layout (DESIGN: one concern per module):

- ``engine.py``     request queue + dynamic micro-batcher (length-bucketed
                    padding, flush on max-batch or max-wait, jit-cached
                    per-bucket apply so the hot path never recompiles);
- ``sessions.py``   per-client recurrent carry cache (LRU + TTL + byte
                    accounting) making each streaming step O(1);
- ``forecaster.py`` one ``predict(window) -> (forecast, p_extreme)``
                    interface over the paper LSTM and every zoo arch,
                    with the EVT tail alert head;
- ``registry.py``   multi-model hosting keyed by name, checkpoint I/O;
- ``telemetry.py``  latency percentiles, throughput, batch occupancy,
                    cache hit-rate.
"""

from repro.serving.engine import BatcherConfig, ServingEngine
from repro.serving.forecaster import (LSTMForecaster, ZooForecaster,
                                      build_lstm_forecaster,
                                      build_zoo_forecaster)
from repro.serving.registry import ModelRegistry
from repro.serving.sessions import RecurrentSessionRunner, SessionCache
from repro.serving.telemetry import Telemetry

__all__ = [
    "BatcherConfig",
    "LSTMForecaster",
    "ModelRegistry",
    "RecurrentSessionRunner",
    "ServingEngine",
    "SessionCache",
    "Telemetry",
    "ZooForecaster",
    "build_lstm_forecaster",
    "build_zoo_forecaster",
]

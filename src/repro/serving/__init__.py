"""Streaming forecast serving: dynamic micro-batching, recurrent session
cache, multi-model registry, extreme-event alerting, and a sharded
serving mesh with fleet-wide weight hot-swap propagation.

Layout (DESIGN: one concern per module):

- ``engine.py``     request queue + dynamic micro-batcher (length-bucketed
                    padding, flush on max-batch or max-wait, jit-cached
                    per-bucket apply so the hot path never recompiles);
                    ``EngineShard`` is one worker, ``ServingEngine`` the
                    single-shard special case; ``submit_step`` queues
                    streaming session steps, flushed as ONE fused
                    ``slots_generate`` dispatch over the device-resident
                    decode lanes (``BatcherConfig.decode_slots``);
- ``router.py``     consistent-hash (rendezvous) routing of client ids to
                    shards + ``ShardedServingEngine``, the mesh of
                    per-shard ``EngineShard`` workers behind the same
                    ``submit``/``predict``/``warmup`` API;
- ``swarm.py``      fleet swap propagation: primary registry + per-shard
                    replicas, pull-based weight transfer under a bounded
                    staleness skew (version vector per shard);
- ``sessions.py``   the slot allocator + spill tier: sessions occupy
                    device decode lanes (LRU lane eviction spills the
                    carry to the host ``SessionCache``, bitwise-equal
                    on reload; TTL expires lanes too);
                    ``RecurrentSessionRunner.step_many`` is "ensure
                    resident -> generate -> read requested rows"
                    (``num_slots=0`` restores the gather/scatter path);
                    ``ShardedSessionCache`` shards by client id;
- ``forecaster.py`` the ``Forecaster``/``StreamingForecaster``
                    protocols — one ``predict(window) -> (forecast,
                    p_extreme)`` interface over the paper LSTM and every
                    zoo arch, with the EVT tail alert head;
                    ``DecodeSlots`` + prefill/insert/generate, the
                    device-resident decode lifecycle (carries donated in
                    and out off-CPU);
- ``ensemble.py``   composable model-set serving: ``EnsembleForecaster``
                    fans one request across N registry members and fuses
                    ``(forecast, p_extreme)`` with EVT-weighted
                    combination (weights = each member's calibrated tail
                    prior, renormalized online from rolling error), plus
                    the anomaly-aware alert path (extreme regime widens
                    alert sensitivity and tightens flush ``max_wait``);
- ``registry.py``   multi-model hosting keyed by name, monotone model
                    versions, atomic weight swap, publish subscriptions,
                    checkpoint I/O;
- ``hotswap.py``    online-learning bridge: the local-SGD round loop
                    publishes worker-averaged params as new versions
                    without dropping in-flight requests (swarm-aware:
                    publishing into a ``ShardSwarm`` fans out fleet-wide);
- ``transport.py``  multi-process mesh: each shard an ``EngineShard`` in
                    its own OS process behind a length-prefixed msgpack
                    socket protocol; weight pushes ship serialized
                    checkpoints under the same ``max_skew`` bound, live
                    join/leave migrates session carries across processes;
                    workers can live on OTHER HOSTS (``serve_shard`` +
                    ``connect_shard``) and are heartbeat-supervised —
                    a SIGKILLed worker is detected, its futures failed
                    fast, and a local replacement respawned in place;
- ``durable.py``    durable state plane: ``DurableStore`` is a
                    content-addressed, atomic-rename, fsync'd blob +
                    manifest layout (torn writes detected by checksum,
                    keep-last-K retention, monotone version merge);
                    ``CheckpointDaemon`` snapshots session carries and
                    weight versions off the hot path; ``restore_from``
                    on the mesh cold-boots the fleet back to the last
                    acknowledged publish and re-homes checkpointed
                    carries (bitwise where fresh, history re-prime
                    where stale);
- ``telemetry.py``  latency percentiles, throughput, batch occupancy,
                    cache hit-rate, swap count, staleness at serve time,
                    per-version request counts, slot insert/spill
                    counters + lane-occupancy gauges, cross-shard
                    ``merge``.
"""

from repro.serving.durable import (CheckpointDaemon, DurableStore,
                                   DurableStoreError, restore_registry)
from repro.serving.engine import BatcherConfig, EngineShard, ServingEngine
from repro.serving.ensemble import (EnsembleForecaster, EnsembleFuser,
                                    EnsembleSlots, EnsembleSpec,
                                    fusion_weights)
from repro.serving.forecaster import (DecodeSlots, Forecaster,
                                      LSTMForecaster, StreamingForecaster,
                                      ZooForecaster, build_lstm_forecaster,
                                      build_zoo_forecaster)
from repro.serving.hotswap import WeightPublisher, stop_the_world_swap
from repro.serving.registry import ModelRegistry, RegistryEntry
from repro.serving.router import ConsistentRouter, ShardedServingEngine
from repro.serving.sessions import (RecurrentSessionRunner, SessionCache,
                                    ShardedSessionCache)
from repro.serving.swarm import ShardSwarm
from repro.serving.telemetry import Telemetry
from repro.serving.transport import (MultiProcessServingEngine, RemoteShard,
                                     connect_shard, serve_shard, spawn_shard)

__all__ = [
    "BatcherConfig",
    "CheckpointDaemon",
    "ConsistentRouter",
    "DecodeSlots",
    "DurableStore",
    "DurableStoreError",
    "EngineShard",
    "EnsembleForecaster",
    "EnsembleFuser",
    "EnsembleSlots",
    "EnsembleSpec",
    "Forecaster",
    "LSTMForecaster",
    "ModelRegistry",
    "MultiProcessServingEngine",
    "RecurrentSessionRunner",
    "RegistryEntry",
    "RemoteShard",
    "ServingEngine",
    "SessionCache",
    "ShardSwarm",
    "ShardedServingEngine",
    "ShardedSessionCache",
    "StreamingForecaster",
    "Telemetry",
    "WeightPublisher",
    "ZooForecaster",
    "build_lstm_forecaster",
    "build_zoo_forecaster",
    "connect_shard",
    "fusion_weights",
    "restore_registry",
    "serve_shard",
    "spawn_shard",
    "stop_the_world_swap",
]

"""Durable state plane: a content-addressed checkpoint store plus an
async checkpoint daemon, so a cold fleet restart (or a partitioned
worker re-adopted later) resumes streams instead of re-priming them.

``DurableStore`` is a crash-safe directory store:

- **blobs/** holds content-addressed payloads (weight checkpoints,
  packed session-carry frames) named by their sha256; a blob reference
  is the string ``"sha256:<hex>"`` and readers re-hash on ``get_blob``,
  so a torn or corrupted blob is detected, never trusted.
- **manifests/** holds numbered snapshots of the fleet state (hosted
  model versions + weight refs, ensemble specs, session frames).  Each
  manifest file carries its own checksum line; writes go through
  temp-file + ``fsync`` + ``os.replace`` (and a directory fsync), so a
  crash mid-commit leaves the previous manifest intact and ``latest``
  simply skips anything torn.
- **retention** keeps the newest ``keep_last`` manifests and
  garbage-collects blobs no kept manifest references.

Commits MERGE into the newest state: a publish-time commit updates one
model entry without touching the session section, a daemon commit
replaces the session section wholesale.  Versioned entries (models,
ensemble specs) merge monotonically — an older version can never
overwrite a newer one, which is what makes the restore law ("never
resurrect a version older than the last acknowledged publish") hold
under arbitrary publish/checkpoint interleavings.

``CheckpointDaemon`` drives periodic snapshots off the hot path: it
calls ``source.checkpoint_state(store, weight_refs)`` (the process
mesh implements it — session carries come from the workers'
non-destructive ``snapshot`` frames, weights are serialized only when
their version moved) on a daemon thread and commits the result.  A
failed snapshot is counted and retried next interval; it never stops
the daemon and never blocks a serving flush.
"""

from __future__ import annotations

import hashlib
import os
import threading
from typing import Any

import msgpack

__all__ = ["DurableStore", "DurableStoreError", "CheckpointDaemon",
           "restore_registry", "pack_session_frame",
           "unpack_session_frame"]

_BLOB_PREFIX = "sha256:"
_MANIFEST_SUFFIX = ".manifest"


class DurableStoreError(RuntimeError):
    """A blob or manifest failed its integrity check (torn write,
    bit rot, or a reference into a pruned store)."""


def _fsync_dir(path: str) -> None:
    # directory fsync makes the rename itself durable (POSIX); some
    # filesystems refuse O_RDONLY dir fsync — crash-safety degrades
    # gracefully there (the rename is still atomic)
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _atomic_write(path: str, data: bytes) -> None:
    """Write ``data`` so that ``path`` either keeps its old content or
    holds all of the new — never a torn mix: temp file in the same
    directory, flush + fsync, atomic ``os.replace``, directory fsync."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path))


def _collect_refs(state: Any, out: set[str]) -> None:
    if isinstance(state, str):
        if state.startswith(_BLOB_PREFIX):
            out.add(state)
    elif isinstance(state, dict):
        for v in state.values():
            _collect_refs(v, out)
    elif isinstance(state, (list, tuple)):
        for v in state:
            _collect_refs(v, out)


def _merge_entry(old: Any, new: Any) -> Any:
    """Versioned entries merge monotonically: whichever side carries
    the higher ``version`` wins (ties go to the newer commit)."""
    if isinstance(old, dict) and isinstance(new, dict) \
            and "version" in old and "version" in new \
            and int(old["version"]) > int(new["version"]):
        return old
    return new


def _merge_state(old: dict, new: dict) -> dict:
    """Two-level merge: top-level sections whose old AND new values are
    dicts merge per-key (monotone on versioned entries); anything else
    is replaced by the new commit."""
    merged = dict(old)
    for section, value in new.items():
        have = merged.get(section)
        if isinstance(have, dict) and isinstance(value, dict):
            sec = dict(have)
            for k, v in value.items():
                sec[k] = _merge_entry(sec[k], v) if k in sec else v
            merged[section] = sec
        else:
            merged[section] = value
    return merged


class DurableStore:
    """Content-addressed, atomic-rename, fsync'd checkpoint store.

    Layout under ``root``::

        blobs/<sha256-hex>             content-addressed payloads
        manifests/<seq>.manifest       checksummed state snapshots

    Thread-safe: commits serialize under one lock; ``put_blob`` may run
    concurrently (a blob written but not yet referenced by a manifest
    is protected from garbage collection until its commit lands).
    """

    def __init__(self, root: str, keep_last: int = 3):
        if keep_last < 1:
            raise ValueError("keep_last must be >= 1")
        self.root = str(root)
        self.keep_last = keep_last
        self.blob_dir = os.path.join(self.root, "blobs")
        self.manifest_dir = os.path.join(self.root, "manifests")
        os.makedirs(self.blob_dir, exist_ok=True)
        os.makedirs(self.manifest_dir, exist_ok=True)
        self._lock = threading.Lock()
        # blobs written ahead of their manifest: GC must not reap them
        self._protected: set[str] = set()
        self.commits = 0
        self.blobs_written = 0
        self.blobs_deduped = 0

    # -- blobs -------------------------------------------------------------
    def _blob_path(self, ref: str) -> str:
        if not ref.startswith(_BLOB_PREFIX):
            raise ValueError(f"not a blob reference: {ref!r}")
        digest = ref[len(_BLOB_PREFIX):]
        if len(digest) != 64 or not all(c in "0123456789abcdef"
                                        for c in digest):
            raise ValueError(f"malformed blob reference: {ref!r}")
        return os.path.join(self.blob_dir, digest)

    def put_blob(self, data: bytes) -> str:
        """Store ``data`` content-addressed; returns its reference.
        Identical content is written once (dedup by digest)."""
        ref = _BLOB_PREFIX + hashlib.sha256(data).hexdigest()
        path = self._blob_path(ref)
        with self._lock:
            self._protected.add(ref)
        if os.path.exists(path):
            self.blobs_deduped += 1
            return ref
        _atomic_write(path, data)
        self.blobs_written += 1
        return ref

    def has_blob(self, ref: str) -> bool:
        try:
            return os.path.exists(self._blob_path(ref))
        except ValueError:
            return False

    def get_blob(self, ref: str) -> bytes:
        """Read and VERIFY a blob — the content must hash back to its
        own name, so torn writes and bit rot surface as
        ``DurableStoreError`` instead of garbage weights."""
        path = self._blob_path(ref)
        try:
            with open(path, "rb") as f:
                data = f.read()
        except OSError as e:
            raise DurableStoreError(f"blob {ref} unreadable: {e}") from e
        if _BLOB_PREFIX + hashlib.sha256(data).hexdigest() != ref:
            raise DurableStoreError(
                f"blob {ref} failed its checksum (torn write or "
                f"corruption); refusing to trust it")
        return data

    # -- manifests ---------------------------------------------------------
    def _manifest_path(self, seq: int) -> str:
        return os.path.join(self.manifest_dir,
                            f"{seq:012d}{_MANIFEST_SUFFIX}")

    def manifest_seqs(self) -> list[int]:
        """Sequence numbers of the manifests on disk, ascending."""
        out = []
        for name in os.listdir(self.manifest_dir):
            if name.endswith(_MANIFEST_SUFFIX):
                try:
                    out.append(int(name[:-len(_MANIFEST_SUFFIX)]))
                except ValueError:
                    continue
        return sorted(out)

    def _read_manifest(self, seq: int) -> dict | None:
        """One manifest, checksum-verified; None when torn/corrupt."""
        try:
            with open(self._manifest_path(seq), "rb") as f:
                raw = f.read()
        except OSError:
            return None
        nl = raw.find(b"\n")
        if nl != 64:
            return None
        checksum, payload = raw[:nl].decode("ascii", "replace"), raw[nl + 1:]
        if hashlib.sha256(payload).hexdigest() != checksum:
            return None
        try:
            doc = msgpack.unpackb(payload, raw=False, strict_map_key=False)
        except Exception:  # noqa: BLE001 — corrupt payload == torn manifest
            return None
        if not isinstance(doc, dict) or doc.get("seq") != seq:
            return None
        return doc

    def commit(self, state: dict) -> int:
        """Merge ``state`` into the newest manifest and write the
        result as a new one (see ``_merge_state`` for the monotone
        merge law), then prune to ``keep_last`` manifests and
        garbage-collect unreferenced blobs.  Returns the new sequence
        number."""
        with self._lock:
            seqs = self.manifest_seqs()
            base: dict = {}
            for seq in reversed(seqs):
                doc = self._read_manifest(seq)
                if doc is not None:
                    base = doc["state"]
                    break
            merged = _merge_state(base, state)
            new_seq = (seqs[-1] + 1) if seqs else 1
            payload = msgpack.packb({"seq": new_seq, "state": merged},
                                    use_bin_type=True)
            checksum = hashlib.sha256(payload).hexdigest().encode("ascii")
            _atomic_write(self._manifest_path(new_seq),
                          checksum + b"\n" + payload)
            self.commits += 1
            # everything the new manifest references is now
            # manifest-protected; ahead-of-commit blobs from OTHER
            # threads stay in self._protected until their commit lands
            refs: set[str] = set()
            _collect_refs(merged, refs)
            self._protected -= refs
            self._prune_locked(new_seq)
            return new_seq

    def _prune_locked(self, newest: int) -> None:
        keep = [s for s in self.manifest_seqs() if s <= newest]
        drop, keep = keep[:-self.keep_last], keep[-self.keep_last:]
        for seq in drop:
            try:
                os.remove(self._manifest_path(seq))
            except OSError:
                pass
        referenced: set[str] = set(self._protected)
        for seq in keep:
            doc = self._read_manifest(seq)
            if doc is not None:
                _collect_refs(doc["state"], referenced)
        live = {ref[len(_BLOB_PREFIX):] for ref in referenced}
        try:
            on_disk = os.listdir(self.blob_dir)
        except OSError:
            return
        for name in on_disk:
            if name.endswith(".tmp") or name not in live:
                try:
                    os.remove(os.path.join(self.blob_dir, name))
                except OSError:
                    pass

    def latest(self) -> tuple[int, dict] | None:
        """The newest GOOD snapshot: (seq, state), skipping manifests
        that fail their checksum or reference missing/corrupt blobs —
        a crash mid-commit (or mid-prune) falls back to the previous
        complete one.  None when the store holds no usable snapshot."""
        for seq in reversed(self.manifest_seqs()):
            doc = self._read_manifest(seq)
            if doc is None:
                continue
            state = doc["state"]
            refs: set[str] = set()
            _collect_refs(state, refs)
            try:
                ok = all(
                    _BLOB_PREFIX + hashlib.sha256(
                        self.get_blob(ref)).hexdigest() == ref
                    for ref in refs)
            except DurableStoreError:
                ok = False
            if ok:
                return seq, state
        return None


# -- session-frame codec -----------------------------------------------------

def pack_session_frame(client_id: str, carry, nbytes: int,
                       version: int) -> dict:
    """One session as the SAME msgpack-able frame the transport ships
    on migration (``restore`` op shape), so a checkpointed carry is
    bitwise the one a live migration would have moved."""
    from repro.serving.transport import _pack_carry

    return {"client": client_id, "carry": _pack_carry(carry),
            "nbytes": nbytes, "version": version}


def unpack_session_frame(frame: dict):
    """(client_id, carry, nbytes, version) from a packed frame."""
    from repro.serving.transport import _unpack_carry

    return (frame["client"], _unpack_carry(frame["carry"]),
            frame["nbytes"], frame["version"])


def pack_frames_blob(frames: list[dict]) -> bytes:
    """All of one snapshot's session frames as a single blob payload
    (content-addressing dedups identical snapshots wholesale)."""
    return msgpack.packb({"sessions": frames}, use_bin_type=True)


def unpack_frames_blob(data: bytes) -> list[dict]:
    return msgpack.unpackb(data, raw=False,
                           strict_map_key=False)["sessions"]


# -- restore ----------------------------------------------------------------

def restore_registry(store: DurableStore, registry,
                     device_put: bool = False) -> dict | None:
    """Re-install the store's newest good snapshot into ``registry``:
    model weights at their saved versions (monotone — a registry that
    already moved past a saved version keeps its newer one), then
    ensemble specs (members restore first, so spec validation sees
    them; stale spec versions are skipped).  Returns a summary with the
    checkpointed ``session_frames`` for the caller to re-home, or None
    when the store holds no usable snapshot."""
    found = store.latest()
    if found is None:
        return None
    seq, state = found
    models: dict[str, int] = {}
    for key, entry in sorted((state.get("models") or {}).items()):
        registry.load_bytes(store.get_blob(entry["ref"]), key=key,
                            device_put=device_put)
        models[key] = registry.version(key)
    ensembles: dict[str, int] = {}
    for name, entry in sorted((state.get("ensembles") or {}).items()):
        registry.install_ensemble(name, entry["spec"],
                                  int(entry["version"]))
        ensembles[name] = registry.ensemble_version(name)
    frames: list[dict] = []
    sessions = state.get("sessions") or {}
    if sessions.get("ref"):
        frames = unpack_frames_blob(store.get_blob(sessions["ref"]))
    return {"seq": seq, "models": models, "ensembles": ensembles,
            "session_frames": frames}


# -- the async checkpoint daemon --------------------------------------------

class CheckpointDaemon:
    """Interval snapshots of a serving engine into a ``DurableStore``,
    off the hot path.  ``source`` implements
    ``checkpoint_state(store, weight_refs) -> dict | None`` (the
    process mesh does); ``weight_refs`` is this daemon's
    ``{key: (version, blob_ref)}`` memo so unchanged weight versions
    are never re-serialized.  Snapshot failures are counted and
    retried next interval — the daemon never raises into the engine
    and never blocks a flush (the mesh's snapshot frames are
    non-destructive reads)."""

    def __init__(self, store: DurableStore, source,
                 interval_s: float = 5.0, events=None):
        if interval_s <= 0:
            raise ValueError("interval_s must be > 0")
        self.store = store
        self.source = source
        self.interval_s = interval_s
        self.events = events             # repro.obs.EventLog | None
        self.commits = 0
        self.errors = 0
        self.last_seq: int | None = None
        self._weight_refs: dict[str, tuple[int, str]] = {}
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    def checkpoint_now(self) -> int | None:
        """One synchronous snapshot + commit; returns the manifest
        sequence number (None when the source had nothing to save)."""
        state = self.source.checkpoint_state(self.store,
                                             self._weight_refs)
        if state is None:
            return None
        seq = self.store.commit(state)
        self.commits += 1
        self.last_seq = seq
        if self.events is not None:
            self.events.log("checkpoint_commit", seq=seq)
        return seq

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.checkpoint_now()
            except Exception as e:  # noqa: BLE001 — the daemon survives
                self.errors += 1
                if self.events is not None:
                    self.events.log(
                        "checkpoint_error",
                        error=f"{type(e).__name__}: {e}")

    def start(self) -> "CheckpointDaemon":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="checkpoint-daemon", daemon=True)
            self._thread.start()
        return self

    def stop(self, final_checkpoint: bool = False) -> None:
        """Stop the interval loop; ``final_checkpoint=True`` takes one
        last synchronous snapshot (clean-shutdown durability)."""
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join()
        if final_checkpoint:
            try:
                self.checkpoint_now()
            except Exception:  # noqa: BLE001 — best effort on the way out
                self.errors += 1

    def __enter__(self) -> "CheckpointDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

"""Multi-model hosting: forecasters keyed by name, with atomic weight
hot-swapping and checkpoint save/load through ``repro.checkpoint.io``
(the forecaster's config, EVT tail calibration, indicator thresholds and
model version ride along as metadata, so a loaded model serves
identically to the one that was saved).

Versioning: every key carries a monotonically increasing model version.
``register`` publishes version 1 (or bumps an existing key); ``swap``
atomically replaces the hosted forecaster and returns the new version.
Readers (`get`) take one reference under the lock, so an in-flight
micro-batch that already resolved its forecaster keeps serving the old
weights while the next flush picks up the new ones — no request is ever
dropped by a swap.
"""

from __future__ import annotations

import threading
import time
from typing import Any, NamedTuple

import jax

from repro.checkpoint.io import (assemble, dump_checkpoint_bytes,
                                 load_checkpoint, load_checkpoint_bytes,
                                 save_checkpoint)
from repro.models.rnn import RNNConfig, init_rnn
from repro.serving.ensemble import EnsembleSpec
from repro.serving.forecaster import LSTMForecaster, ZooForecaster


class RegistryEntry(NamedTuple):
    """Immutable snapshot of one hosted model."""

    forecaster: Any
    version: int
    published_at: float


def _rnn_cfg_meta(cfg: RNNConfig) -> dict:
    return {"input_dim": cfg.input_dim, "hidden": cfg.hidden,
            "num_layers": cfg.num_layers, "fc_dims": list(cfg.fc_dims),
            "window": cfg.window, "evl_head": cfg.evl_head}


def _rnn_cfg_from_meta(m: dict) -> RNNConfig:
    return RNNConfig(input_dim=m["input_dim"], hidden=m["hidden"],
                     num_layers=m["num_layers"],
                     fc_dims=tuple(m["fc_dims"]), window=m["window"],
                     evl_head=m["evl_head"])


class ModelRegistry:
    """Thread-safe name -> forecaster map used by the serving engine."""

    def __init__(self, clock=time.perf_counter, durable=None):
        self._lock = threading.Lock()
        self._clock = clock
        self._entries: dict[str, RegistryEntry] = {}
        self._subscribers: list = []
        self.swap_count = 0
        # ensemble specs live in a separate namespace from model keys:
        # specs are immutable and swapped whole (monotone versions), and
        # they notify their OWN subscriber list — a weight-propagation
        # swarm must not try to pull a checkpoint for a spec name
        self._ensembles: dict[str, EnsembleSpec] = {}
        self._ensemble_versions: dict[str, int] = {}
        self._ensemble_subscribers: list = []
        # durable backing (repro.serving.durable.DurableStore | None):
        # every publish lands on disk BEFORE subscribers fire — i.e.
        # before the mesh pushes it and records the workers' version-
        # vector acks — so a restored registry can never be older than
        # the last acknowledged publish
        self._durable = durable
        self.durable_commits = 0

    def attach_durable(self, store) -> None:
        """Back this registry with a ``DurableStore``: every future
        publish (register/swap/load) commits its weights + version to
        the store before acknowledgement. Models already hosted are
        committed immediately, so attaching to a warm registry persists
        its current state too."""
        with self._lock:
            self._durable = store
        for key in self.keys():
            self._durable_publish(key)
        for name in list(self._ensembles):
            self._durable_publish_ensemble(name)

    def _durable_publish(self, key: str) -> None:
        if self._durable is None:
            return
        entry = self.get_entry(key)
        ref = self._durable.put_blob(self.save_bytes(key))
        self._durable.commit(
            {"models": {key: {"version": entry.version, "ref": ref}}})
        self.durable_commits += 1

    def _durable_publish_ensemble(self, name: str) -> None:
        if self._durable is None:
            return
        with self._lock:
            spec = self._ensembles.get(name)
            version = self._ensemble_versions.get(name, 0)
        if spec is None:
            return
        self._durable.commit(
            {"ensembles": {name: {"version": version,
                                  "spec": spec.to_wire()}}})
        self.durable_commits += 1

    # -- publish notifications ---------------------------------------------
    def subscribe(self, callback) -> None:
        """Register ``callback(key, version)`` to run after every
        publication (register/swap/load). Callbacks fire OUTSIDE the
        registry lock — a subscriber may freely call back into the
        registry — and on the publishing thread. The swap-propagation
        swarm (``repro.serving.swarm``) uses this to track publishes
        made directly against a primary registry."""
        with self._lock:
            self._subscribers.append(callback)

    def unsubscribe(self, callback) -> bool:
        """Detach a subscriber; returns whether it was subscribed. A
        stopped serving mesh detaches its swarm so publishes stop
        fanning out into dead replicas."""
        with self._lock:
            try:
                self._subscribers.remove(callback)
                return True
            except ValueError:
                return False

    def _notify(self, key: str, version: int) -> None:
        with self._lock:
            subscribers = list(self._subscribers)
        for fn in subscribers:
            fn(key, version)

    # -- publication -------------------------------------------------------
    def _publish_locked(self, key: str, forecaster,
                        version: int | None) -> int:
        cur = self._entries.get(key)
        floor = cur.version if cur is not None else 0
        new_version = version if version is not None else floor + 1
        if new_version <= floor:
            raise ValueError(
                f"model version must increase monotonically: {key!r} is at "
                f"v{floor}, refusing v{new_version}")
        now = self._clock()
        try:
            # stamp before publication so readers never see a torn entry
            forecaster.version = new_version
            forecaster.published_at = now
        except AttributeError:
            pass                 # duck-typed stand-ins without attributes
        self._entries[key] = RegistryEntry(forecaster, new_version, now)
        return new_version

    def register(self, key: str, forecaster, version: int | None = None):
        """Host ``forecaster`` under ``key`` (bumping the version if the
        key already exists). Returns the forecaster."""
        with self._lock:
            v = self._publish_locked(key, forecaster, version)
        self._durable_publish(key)
        self._notify(key, v)
        return forecaster

    def swap(self, key: str, forecaster, version: int | None = None) -> int:
        """Atomically replace the forecaster hosted at ``key``; the key
        must already exist (use ``register`` for first publication).
        Returns the new (monotonically increased) version."""
        with self._lock:
            if key not in self._entries:
                raise KeyError(f"cannot swap unknown model {key!r}; "
                               f"hosted: {sorted(self._entries)}")
            v = self._publish_locked(key, forecaster, version)
            self.swap_count += 1
        self._durable_publish(key)
        self._notify(key, v)
        return v

    def unregister(self, key: str) -> None:
        with self._lock:
            self._entries.pop(key, None)

    # -- lookup ------------------------------------------------------------
    def get(self, key: str):
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                raise KeyError(f"unknown model {key!r}; hosted: "
                               f"{sorted(self._entries)}")
            return entry.forecaster

    def get_entry(self, key: str) -> RegistryEntry:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                raise KeyError(f"unknown model {key!r}; hosted: "
                               f"{sorted(self._entries)}")
            return entry

    def version(self, key: str) -> int:
        return self.get_entry(key).version

    def keys(self) -> list[str]:
        with self._lock:
            return sorted(self._entries)

    def items(self) -> list[tuple[str, Any]]:
        """Snapshot of (key, forecaster) pairs taken under the lock —
        safe to iterate while other threads register/unregister/swap."""
        with self._lock:
            return [(k, e.forecaster)
                    for k, e in sorted(self._entries.items())]

    def entries(self) -> list[tuple[str, RegistryEntry]]:
        """Snapshot of (key, entry) pairs, same safety contract as
        ``items``."""
        with self._lock:
            return sorted(self._entries.items())

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    # -- ensembles ---------------------------------------------------------
    def subscribe_ensembles(self, callback) -> None:
        """Register ``callback(name, spec, version)`` to run after every
        ensemble spec publication (register/swap). Same contract as
        ``subscribe``: fires outside the lock, on the publishing
        thread."""
        with self._lock:
            self._ensemble_subscribers.append(callback)

    def unsubscribe_ensembles(self, callback) -> bool:
        with self._lock:
            try:
                self._ensemble_subscribers.remove(callback)
                return True
            except ValueError:
                return False

    def _notify_ensembles(self, name: str, spec: EnsembleSpec,
                          version: int) -> None:
        with self._lock:
            subscribers = list(self._ensemble_subscribers)
        for fn in subscribers:
            fn(name, spec, version)

    def _validate_spec_locked(self, name: str, spec: EnsembleSpec) -> None:
        if name in self._entries:
            raise ValueError(f"ensemble name {name!r} collides with a "
                             f"hosted model key")
        missing = [m for m in spec.members if m not in self._entries]
        if missing:
            raise KeyError(f"ensemble {name!r} names unhosted members "
                           f"{missing}; hosted: {sorted(self._entries)}")
        fcs = [self._entries[m].forecaster for m in spec.members]
        dims = {getattr(fc, "feature_dim", None) for fc in fcs}
        wins = {getattr(fc, "window", None) for fc in fcs}
        if len(dims) > 1 or len(wins) > 1:
            raise ValueError(
                f"ensemble {name!r} members disagree on input shape: "
                f"feature_dims {sorted(map(str, dims))}, windows "
                f"{sorted(map(str, wins))} — members must serve the "
                f"same windows")

    def register_ensemble(self, name: str, members,
                          **opts) -> EnsembleSpec:
        """Host a named model group: ``members`` is an iterable of
        already-hosted model keys (or a full ``EnsembleSpec``); ``opts``
        are ``EnsembleSpec`` fusion/anomaly fields. Re-registering an
        existing name atomically replaces the whole member list
        (monotone ensemble version) — per-member hotswap/canary
        semantics are untouched because members stay ordinary model
        keys swapped through ``swap``."""
        spec = members if isinstance(members, EnsembleSpec) \
            else EnsembleSpec(members=tuple(members), **opts)
        with self._lock:
            self._validate_spec_locked(name, spec)
            v = self._ensemble_versions.get(name, 0) + 1
            self._ensembles[name] = spec
            self._ensemble_versions[name] = v
        self._durable_publish_ensemble(name)
        self._notify_ensembles(name, spec, v)
        return spec

    def swap_ensemble(self, name: str, members, **opts) -> int:
        """Atomically replace an existing ensemble's member set;
        returns the new spec version. Readers mid-flush keep the spec
        they already resolved — the next flush fuses over the new
        members (the fuser's error state rebuilds with them)."""
        with self._lock:
            if name not in self._ensembles:
                raise KeyError(f"cannot swap unknown ensemble {name!r}; "
                               f"hosted: {sorted(self._ensembles)}")
        spec = members if isinstance(members, EnsembleSpec) \
            else EnsembleSpec(members=tuple(members), **opts)
        with self._lock:
            self._validate_spec_locked(name, spec)
            v = self._ensemble_versions[name] + 1
            self._ensembles[name] = spec
            self._ensemble_versions[name] = v
        self._durable_publish_ensemble(name)
        self._notify_ensembles(name, spec, v)
        return v

    def install_ensemble(self, name: str, spec: EnsembleSpec,
                         version: int) -> bool:
        """Replica-sync path (swarm pull / transport push): install the
        spec AT the given version, skipping stale or already-applied
        versions. No notifications — replicas don't re-propagate."""
        spec = spec if isinstance(spec, EnsembleSpec) \
            else EnsembleSpec.from_wire(spec)
        with self._lock:
            if self._ensemble_versions.get(name, 0) >= int(version):
                return False
            self._validate_spec_locked(name, spec)
            self._ensembles[name] = spec
            self._ensemble_versions[name] = int(version)
            return True

    def ensemble(self, name: str) -> EnsembleSpec | None:
        """The spec hosted under ``name`` (None when the name is not an
        ensemble — how the engine tells fan-out requests from plain
        model requests)."""
        with self._lock:
            return self._ensembles.get(name)

    def ensembles(self) -> dict[str, EnsembleSpec]:
        with self._lock:
            return dict(self._ensembles)

    def ensemble_version(self, name: str) -> int:
        with self._lock:
            if name not in self._ensembles:
                raise KeyError(f"unknown ensemble {name!r}; hosted: "
                               f"{sorted(self._ensembles)}")
            return self._ensemble_versions[name]

    def unregister_ensemble(self, name: str) -> None:
        with self._lock:
            self._ensembles.pop(name, None)
            self._ensemble_versions.pop(name, None)

    # -- persistence -------------------------------------------------------
    def _save_meta(self, key: str):
        """(forecaster, checkpoint metadata) for the hosted ``key``."""
        entry = self.get_entry(key)
        fc = entry.forecaster
        meta: dict = {"kind": fc.kind, "tail": fc.tail, "gamma": fc.gamma,
                      "version": entry.version}
        if fc.kind == "lstm":
            meta["cfg"] = _rnn_cfg_meta(fc.cfg)
            meta["eps"] = list(fc.eps)
        elif fc.kind == "zoo":
            name = fc.cfg.name
            meta["reduced"] = name.endswith("-smoke")
            meta["arch"] = name[:-len("-smoke")] if meta["reduced"] else name
        else:
            raise ValueError(f"cannot persist forecaster kind {fc.kind!r}")
        return fc, meta

    def save(self, key: str, path: str) -> None:
        fc, meta = self._save_meta(key)
        save_checkpoint(path, fc.params, metadata=meta)

    def save_bytes(self, key: str) -> bytes:
        """The hosted model as in-memory checkpoint bytes (config, EVT
        calibration and version ride along) — what the mesh transport
        ships to shard worker processes on publish and join."""
        fc, meta = self._save_meta(key)
        return dump_checkpoint_bytes(fc.params, metadata=meta)

    def _rebuild(self, flat, meta, origin: str, device_put: bool = False):
        if not meta or "kind" not in meta:
            raise ValueError(f"{origin}: not a serving checkpoint (no kind "
                             "metadata)")
        kind = meta["kind"]
        if kind == "lstm":
            cfg = _rnn_cfg_from_meta(meta["cfg"])
            like = init_rnn(jax.random.PRNGKey(0), cfg)
            params = assemble(flat, like)
            if device_put:
                params = jax.device_put(params)
            fc = LSTMForecaster(cfg=cfg, params=params,
                                tail=meta.get("tail"),
                                eps=tuple(meta.get("eps", (0.01, 0.01))),
                                gamma=meta.get("gamma", 5.0))
        elif kind == "zoo":
            from repro.configs import get_config
            from repro.configs.base import reduced as reduce_cfg
            from repro.models.model_zoo import build_model

            acfg = get_config(meta["arch"])
            if meta.get("reduced"):
                acfg = reduce_cfg(acfg)
            like = build_model(acfg).init(jax.random.PRNGKey(0))
            params = assemble(flat, like)
            if device_put:
                params = jax.device_put(params)
            fc = ZooForecaster(cfg=acfg, params=params,
                               tail=meta.get("tail"),
                               gamma=meta.get("gamma", 5.0))
        else:
            raise ValueError(f"{origin}: unknown forecaster kind {kind!r}")
        fc.version = int(meta.get("version", 0))
        return fc

    def _register_loaded(self, fc, key: str | None):
        if key is not None:
            with self._lock:
                cur = self._entries.get(key)
                saved = fc.version or None
                if cur is not None and saved is not None \
                        and saved <= cur.version:
                    saved = None     # key moved on: fall back to a bump
                v = self._publish_locked(key, fc, saved)
            self._durable_publish(key)
            self._notify(key, v)
        return fc

    def load(self, path: str, key: str | None = None):
        """Rebuild a forecaster from a checkpoint and (optionally)
        register it under ``key`` at the saved version (or the next
        monotone version if the key has already moved past it). Returns
        the forecaster."""
        flat, meta = load_checkpoint(path)
        return self._register_loaded(self._rebuild(flat, meta, path), key)

    def load_bytes(self, data: bytes, key: str | None = None,
                   device_put: bool = False):
        """``load`` for in-memory checkpoint bytes (``save_bytes``
        output). ``device_put=True`` re-materializes the parameters on
        the local default device — what a shard worker process does when
        it receives a weight push over the transport."""
        flat, meta = load_checkpoint_bytes(data)
        return self._register_loaded(
            self._rebuild(flat, meta, "<bytes>", device_put=device_put),
            key)

"""Multi-model hosting: forecasters keyed by name, with checkpoint save/
load through ``repro.checkpoint.io`` (the forecaster's config, EVT tail
calibration and indicator thresholds ride along as metadata, so a loaded
model serves identically to the one that was saved).
"""

from __future__ import annotations

import threading

import jax

from repro.checkpoint.io import assemble, load_checkpoint, save_checkpoint
from repro.models.rnn import RNNConfig, init_rnn
from repro.serving.forecaster import LSTMForecaster, ZooForecaster


def _rnn_cfg_meta(cfg: RNNConfig) -> dict:
    return {"input_dim": cfg.input_dim, "hidden": cfg.hidden,
            "num_layers": cfg.num_layers, "fc_dims": list(cfg.fc_dims),
            "window": cfg.window, "evl_head": cfg.evl_head}


def _rnn_cfg_from_meta(m: dict) -> RNNConfig:
    return RNNConfig(input_dim=m["input_dim"], hidden=m["hidden"],
                     num_layers=m["num_layers"],
                     fc_dims=tuple(m["fc_dims"]), window=m["window"],
                     evl_head=m["evl_head"])


class ModelRegistry:
    """Thread-safe name -> forecaster map used by the serving engine."""

    def __init__(self):
        self._lock = threading.Lock()
        self._models: dict[str, object] = {}

    def register(self, key: str, forecaster):
        with self._lock:
            self._models[key] = forecaster
        return forecaster

    def unregister(self, key: str) -> None:
        with self._lock:
            self._models.pop(key, None)

    def get(self, key: str):
        with self._lock:
            if key not in self._models:
                raise KeyError(f"unknown model {key!r}; hosted: "
                               f"{sorted(self._models)}")
            return self._models[key]

    def keys(self) -> list[str]:
        with self._lock:
            return sorted(self._models)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._models

    # -- persistence -------------------------------------------------------
    def save(self, key: str, path: str) -> None:
        fc = self.get(key)
        meta: dict = {"kind": fc.kind, "tail": fc.tail, "gamma": fc.gamma}
        if fc.kind == "lstm":
            meta["cfg"] = _rnn_cfg_meta(fc.cfg)
            meta["eps"] = list(fc.eps)
        elif fc.kind == "zoo":
            name = fc.cfg.name
            meta["reduced"] = name.endswith("-smoke")
            meta["arch"] = name[:-len("-smoke")] if meta["reduced"] else name
        else:
            raise ValueError(f"cannot persist forecaster kind {fc.kind!r}")
        save_checkpoint(path, fc.params, metadata=meta)

    def load(self, path: str, key: str | None = None):
        """Rebuild a forecaster from a checkpoint and (optionally)
        register it under ``key``. Returns the forecaster."""
        flat, meta = load_checkpoint(path)
        if not meta or "kind" not in meta:
            raise ValueError(f"{path}: not a serving checkpoint (no kind "
                             "metadata)")
        kind = meta["kind"]
        if kind == "lstm":
            cfg = _rnn_cfg_from_meta(meta["cfg"])
            like = init_rnn(jax.random.PRNGKey(0), cfg)
            fc = LSTMForecaster(cfg=cfg, params=assemble(flat, like),
                                tail=meta.get("tail"),
                                eps=tuple(meta.get("eps", (0.01, 0.01))),
                                gamma=meta.get("gamma", 5.0))
        elif kind == "zoo":
            from repro.configs import get_config
            from repro.configs.base import reduced as reduce_cfg
            from repro.models.model_zoo import build_model

            acfg = get_config(meta["arch"])
            if meta.get("reduced"):
                acfg = reduce_cfg(acfg)
            like = build_model(acfg).init(jax.random.PRNGKey(0))
            fc = ZooForecaster(cfg=acfg, params=assemble(flat, like),
                               tail=meta.get("tail"),
                               gamma=meta.get("gamma", 5.0))
        else:
            raise ValueError(f"{path}: unknown forecaster kind {kind!r}")
        if key is not None:
            self.register(key, fc)
        return fc

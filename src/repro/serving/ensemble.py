"""Composable model-set serving: ``EnsembleSpec`` (a named model group
hosted by the registry), ``EnsembleFuser`` (online EVT-weighted fusion
of per-member ``(forecast, p_extreme)`` with an anomaly-aware alert
path), and ``EnsembleForecaster`` (the ``Forecaster`` protocol over N
registry members — fan out, fuse, and carry per-member session state
under ONE client id).

Fusion weighting (DESIGN): each member's weight is
``softmax(log(prior_m) - err_m / temperature)`` where ``prior_m`` comes
from the member's calibrated EVT tail fit (``1 / tail_scale`` — a
tighter calibrated tail is a sharper, more trusted alert head) and
``err_m`` is an exponentially-decayed rolling error. Errors are updated
online: self-supervised from each member's deviation against the
cross-member median consensus on every fused batch, or supervised via
``record_errors`` when ground truth arrives. The softmax is
max-subtracted and every input is clipped finite, so the weights are
ALWAYS convex (non-negative, sum to 1); a single-member ensemble gets
exactly weight 1.0, which — together with the ``M == 1`` fusion
shortcut that returns the member rows untouched — makes a singleton
ensemble bitwise-identical to serving that member solo on every path
(predict, step, replay, slots).

Anomaly-aware path: an EWMA of the fused ``p_extreme`` with
enter/exit hysteresis flips the fuser into *anomaly mode* when the
input stream itself turns extreme. In anomaly mode the alert threshold
is widened (scaled by ``anomaly_alert_scale`` < 1 — more sensitive)
and the engine tightens the batcher's ``max_wait`` for the ensemble
and its members (``wait_scale`` < 1 — alerts leave the queue sooner).

Every member runs through the EXISTING fused per-model machinery: an
ensemble ``predict`` is N per-model ``predict`` dispatches, an
ensemble ``step_many`` flush is N fused ``decode_many`` dispatches, a
slotted ensemble tick is N fused ``slots_generate`` dispatches — never
N×batch singles.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from typing import Any

import numpy as np

from repro.serving.forecaster import DecodeSlots

# rolling errors are clipped into [0, _BIG] (nan -> _BIG): exp(-_BIG)
# underflows to exactly 0.0, keeping the softmax finite for ANY history
_BIG = 1e6


@dataclasses.dataclass(frozen=True)
class EnsembleSpec:
    """A named model group plus its fusion/anomaly policy. Immutable —
    member swaps replace the whole spec atomically under the registry
    lock (monotone ensemble version), so readers never see a torn
    member list."""

    members: tuple[str, ...]
    # fusion weighting
    error_half_life: float = 64.0     # fused batches to halve an error
    temperature: float = 1.0          # err -> logit scale
    # alerting + anomaly-aware adaptation
    alert_threshold: float = 0.9
    anomaly_enter: float = 0.6        # fused-p EWMA >= enter: anomaly on
    anomaly_exit: float = 0.3         # fused-p EWMA < exit: anomaly off
    anomaly_alert_scale: float = 0.75  # threshold multiplier (<1: widen)
    anomaly_wait_scale: float = 0.25   # batcher max_wait multiplier
    anomaly_half_life: float = 16.0    # fused batches in the p EWMA

    def __post_init__(self):
        members = tuple(str(m) for m in self.members)
        if not members:
            raise ValueError("an ensemble needs at least one member")
        if len(set(members)) != len(members):
            raise ValueError(f"duplicate ensemble members: {members}")
        object.__setattr__(self, "members", members)
        if not 0.0 < self.anomaly_alert_scale <= 1.0:
            raise ValueError("anomaly_alert_scale must be in (0, 1]")
        if not 0.0 < self.anomaly_wait_scale <= 1.0:
            raise ValueError("anomaly_wait_scale must be in (0, 1]")
        if self.anomaly_exit > self.anomaly_enter:
            raise ValueError("anomaly_exit must be <= anomaly_enter "
                             "(hysteresis)")

    def to_wire(self) -> dict:
        """msgpack/JSON-able dict (the transport's ``ensemble`` op)."""
        d = dataclasses.asdict(self)
        d["members"] = list(self.members)
        return d

    @classmethod
    def from_wire(cls, d: dict) -> "EnsembleSpec":
        d = dict(d)
        d["members"] = tuple(d["members"])
        return cls(**d)


def fusion_weights(priors, errors, temperature: float = 1.0):
    """Convex fusion weights: ``softmax(log(prior) - err/temperature)``,
    max-subtracted. Non-finite or non-positive priors fall back to 1.0;
    errors are clipped into ``[0, 1e6]`` (nan counts as maximal error),
    so the result is non-negative and sums to 1 for ANY input history.
    A single member gets exactly ``[1.0]``."""
    priors = np.asarray(priors, np.float64).reshape(-1)
    errors = np.asarray(errors, np.float64).reshape(-1)
    if priors.shape != errors.shape:
        raise ValueError(f"priors {priors.shape} != errors {errors.shape}")
    n = priors.shape[0]
    if n == 0:
        raise ValueError("no members to weight")
    if n == 1:
        return np.ones((1,), np.float64)
    priors = np.where(np.isfinite(priors) & (priors > 0.0), priors, 1.0)
    errors = np.clip(np.nan_to_num(errors, nan=_BIG, posinf=_BIG,
                                   neginf=0.0), 0.0, _BIG)
    t = float(temperature)
    if not (math.isfinite(t) and t > 0.0):
        t = 1.0
    logits = np.log(priors) - errors / t
    logits -= logits.max()
    w = np.exp(logits)
    s = float(w.sum())
    if not (math.isfinite(s) and s > 0.0):
        return np.full((n,), 1.0 / n, np.float64)
    return w / s


@dataclasses.dataclass
class FusedResult:
    """One fused batch: per-row fused outputs plus the fusion/anomaly
    state they were produced under."""

    forecast: np.ndarray        # [B] float32
    p_extreme: np.ndarray       # [B] float32
    alerts: np.ndarray          # [B] bool (p_fused >= effective threshold)
    weights: np.ndarray         # [M] float64, convex
    threshold: float            # effective (anomaly-scaled) threshold
    anomaly: bool               # fuser was in anomaly mode for this batch


class EnsembleFuser:
    """Per-ensemble online fusion state: rolling per-member errors, the
    anomaly EWMA/hysteresis, and fused/alert counters. Lock-guarded —
    the predict fan-in callback and the step-flush worker fuse through
    the same instance."""

    def __init__(self, n_members: int, spec: EnsembleSpec):
        self.spec = spec
        self.n_members = int(n_members)
        self._lock = threading.Lock()
        self._err = np.zeros((self.n_members,), np.float64)
        self._alpha = 1.0 - 0.5 ** (1.0 / max(spec.error_half_life, 1e-9))
        self._p_alpha = 1.0 - 0.5 ** (1.0 / max(spec.anomaly_half_life,
                                                1e-9))
        self._p_ewma = 0.0
        self._anomaly = False
        self.fused = 0          # fused rows produced
        self.alerts = 0         # fused rows that alerted

    # -- state reads -------------------------------------------------------
    @property
    def anomaly(self) -> bool:
        return self._anomaly

    def errors(self) -> np.ndarray:
        with self._lock:
            return self._err.copy()

    def weights(self, priors=None) -> np.ndarray:
        if priors is None:
            priors = np.ones((self.n_members,), np.float64)
        with self._lock:
            return fusion_weights(priors, self._err, self.spec.temperature)

    def wait_scale(self) -> float:
        """Batcher ``max_wait`` multiplier: < 1 while anomalous (flush
        sooner — alert latency beats batch occupancy under extremes)."""
        return self.spec.anomaly_wait_scale if self._anomaly else 1.0

    def alert_threshold(self) -> float:
        """Effective alert threshold (anomaly mode widens the alert
        band by scaling the threshold down)."""
        scale = self.spec.anomaly_alert_scale if self._anomaly else 1.0
        return self.spec.alert_threshold * scale

    # -- state writes ------------------------------------------------------
    def record_errors(self, errs) -> None:
        """Supervised error update (ground truth arrived): EWMA the
        per-member absolute errors into the rolling state."""
        errs = np.asarray(errs, np.float64).reshape(-1)
        if errs.shape[0] != self.n_members:
            raise ValueError(f"expected {self.n_members} errors, got "
                             f"{errs.shape[0]}")
        errs = np.clip(np.nan_to_num(errs, nan=_BIG, posinf=_BIG,
                                     neginf=0.0), 0.0, _BIG)
        with self._lock:
            self._err = (1.0 - self._alpha) * self._err + self._alpha * errs

    def fuse(self, ys, ps, priors=None, update: bool = True,
             rows=None) -> FusedResult:
        """Fuse per-member forecasts ``ys`` / alert probabilities ``ps``
        (each a sequence of M arrays of shape [B]). With ``update``,
        also EWMA the self-supervised member errors (deviation from the
        cross-member median consensus) and advance the anomaly state —
        restricted to ``rows`` when given (the slots path fuses full
        lane vectors but only the stepped rows are real)."""
        ys = np.stack([np.asarray(y) for y in ys])          # [M, B]
        ps = np.stack([np.asarray(p) for p in ps])
        M = ys.shape[0]
        if M != self.n_members:
            raise ValueError(f"expected {self.n_members} members, got {M}")
        if priors is None:
            priors = np.ones((M,), np.float64)
        with self._lock:
            w = fusion_weights(priors, self._err, self.spec.temperature)
            if M == 1:
                # bitwise: a singleton ensemble IS its member
                y_f = np.asarray(ys[0], np.float32)
                p_f = np.asarray(ps[0], np.float32)
            else:
                y_f = (w @ ys.astype(np.float64)).astype(np.float32)
                p_f = (w @ ps.astype(np.float64)).astype(np.float32)
            scale = self.spec.anomaly_alert_scale if self._anomaly else 1.0
            thr = self.spec.alert_threshold * scale
            alerts = p_f >= thr
            was_anomaly = self._anomaly
            if update:
                yv = ys if rows is None else ys[:, rows]
                pv = p_f if rows is None else p_f[rows]
                av = alerts if rows is None else alerts[rows]
                if M > 1 and yv.shape[1]:
                    consensus = np.median(yv, axis=0)
                    dev = np.mean(np.abs(yv - consensus[None, :]), axis=1)
                    dev = np.clip(np.nan_to_num(dev, nan=_BIG, posinf=_BIG,
                                                neginf=0.0), 0.0, _BIG)
                    self._err = ((1.0 - self._alpha) * self._err
                                 + self._alpha * dev)
                if pv.size:
                    p_mean = float(np.mean(np.nan_to_num(pv, nan=0.0)))
                    self._p_ewma = ((1.0 - self._p_alpha) * self._p_ewma
                                    + self._p_alpha * p_mean)
                    if self._anomaly:
                        if self._p_ewma < self.spec.anomaly_exit:
                            self._anomaly = False
                    elif self._p_ewma >= self.spec.anomaly_enter:
                        self._anomaly = True
                    self.fused += int(pv.size)
                    self.alerts += int(av.sum())
        return FusedResult(forecast=y_f, p_extreme=p_f, alerts=alerts,
                           weights=w, threshold=thr, anomaly=was_anomaly)


@dataclasses.dataclass
class EnsembleSlots:
    """Per-member device decode-slot states sharing ONE lane numbering:
    lane ``i`` of every member belongs to the same client, so sessions
    spill/migrate as a unit (extract/insert walk all members at the
    same lane index)."""

    slots: dict[str, DecodeSlots]
    num_slots: int
    active: Any                 # np.ndarray bool [num_slots], host-side

    @property
    def n_active(self) -> int:
        return int(self.active.sum())


class EnsembleForecaster:
    """The ``Forecaster`` protocol over N registry members. Members are
    re-resolved from the registry on every call, so per-member hotswap
    and atomic spec (member-list) swaps are picked up mid-stream —
    ``version`` folds the spec version and every member version into
    one string, which is what makes the session runner re-prime carries
    after ANY swap. Session carries are ``{member_key: member_carry}``
    dicts under one client id; slotted serving uses ``EnsembleSlots``
    (one lane index across all members)."""

    kind = "ensemble"
    published_at: float | None = None

    def __init__(self, registry, name: str):
        self.registry = registry
        self.name = str(name)
        self._fuser: EnsembleFuser | None = None
        self._fuser_members: tuple[str, ...] = ()
        self._fuser_lock = threading.Lock()

    # -- member resolution -------------------------------------------------
    def spec(self) -> EnsembleSpec:
        spec = self.registry.ensemble(self.name)
        if spec is None:
            raise KeyError(f"no ensemble {self.name!r} in registry")
        return spec

    def _members(self):
        spec = self.spec()
        return spec, [(k, self.registry.get(k)) for k in spec.members]

    def fuser(self) -> EnsembleFuser:
        """The online fusion state for the CURRENT member set (rebuilt
        on atomic member swap — a new member list means a new error
        vector)."""
        spec = self.spec()
        with self._fuser_lock:
            if self._fuser is None or self._fuser_members != spec.members:
                self._fuser = EnsembleFuser(len(spec.members), spec)
                self._fuser_members = spec.members
            return self._fuser

    @staticmethod
    def _prior(member) -> float:
        """EVT prior from the member's calibrated tail fit: a tighter
        tail scale is a sharper alert head. Uncalibrated members get a
        neutral 1.0."""
        tail = getattr(member, "tail", None)
        if not tail:
            return 1.0
        return 1.0 / max(float(tail.get("scale", 1.0)), 1e-9)

    def fuse(self, ys, ps, update: bool = True, rows=None) -> FusedResult:
        spec, members = self._members()
        priors = [self._prior(m) for _, m in members]
        return self.fuser().fuse(ys, ps, priors=priors, update=update,
                                 rows=rows)

    # -- protocol surface --------------------------------------------------
    @property
    def version(self) -> str:
        """Spec version + every member version, folded into one
        hashable token — changes on ANY swap, which is what the session
        runner keys its re-prime on."""
        spec, members = self._members()
        mv = ",".join(f"{k}:{getattr(m, 'version', 0)}"
                      for k, m in members)
        return f"e{self.registry.ensemble_version(self.name)}|{mv}"

    @property
    def window(self) -> int:
        _, members = self._members()
        return members[0][1].window

    @property
    def feature_dim(self) -> int:
        _, members = self._members()
        return members[0][1].feature_dim

    @property
    def decode_width(self) -> int:
        _, members = self._members()
        return math.lcm(*(int(getattr(m, "decode_width", 1))
                          for _, m in members))

    def predict(self, windows, lengths=None):
        """Fan the batch across every member (one fused per-model
        ``predict`` dispatch each — N total) and fuse. Returns
        (forecast [B], p_extreme [B]) like any other forecaster."""
        _, members = self._members()
        ys, ps = [], []
        for _, m in members:
            y, p = m.predict(windows, lengths)
            ys.append(np.asarray(y))
            ps.append(np.asarray(p))
        fused = self.fuse(ys, ps)
        return fused.forecast, fused.p_extreme

    # -- incremental (session) serving ------------------------------------
    def init_carry(self, batch: int = 1):
        _, members = self._members()
        return {k: m.init_carry(batch) for k, m in members}

    def carry_nbytes(self, batch: int = 1) -> int:
        _, members = self._members()
        return sum(m.carry_nbytes(batch) for _, m in members)

    def _member_carry(self, carry, key: str, member, batch: int = 1):
        if isinstance(carry, dict) and key in carry:
            return carry[key]
        # spec swapped a member in since this carry was built: a fresh
        # carry here is only a stopgap — the runner's version-mismatch
        # re-prime rebuilds the whole dict from history on its next wave
        return member.init_carry(batch)

    def step(self, x_t, carry):
        _, members = self._members()
        ys, ps, new = [], [], {}
        for k, m in members:
            y, p, c2 = m.step(x_t, self._member_carry(carry, k, m,
                                                      len(x_t)))
            ys.append(y)
            ps.append(p)
            new[k] = c2
        fused = self.fuse(ys, ps)
        return fused.forecast, fused.p_extreme, new

    def step_many(self, xs, carries, donate: bool | None = None):
        """Batched streaming step for N sessions: every member steps
        ALL N sessions through its own fused decode lane (N member
        dispatches per flush, never N×sessions singles), then the rows
        fuse."""
        _, members = self._members()
        n = len(carries)
        ys, ps, per_member = [], [], {}
        for k, m in members:
            mc = [self._member_carry(c, k, m) for c in carries]
            y, p, out = m.step_many(xs, mc, donate=donate)
            ys.append(y)
            ps.append(p)
            per_member[k] = out
        fused = self.fuse(ys, ps)
        new = [{k: per_member[k][i] for k, _ in members}
               for i in range(n)]
        return fused.forecast, fused.p_extreme, new

    def replay(self, window, carry=None):
        """Full-window re-prime through every member's own replay (one
        fused dispatch each). Fusion runs with ``update=False`` — a
        replay re-derives a session, it is not live traffic, so it must
        not move the rolling error/anomaly state."""
        _, members = self._members()
        ys, ps, new = [], [], {}
        batch = np.asarray(window).shape[0]
        for k, m in members:
            mc = carry[k] if isinstance(carry, dict) and k in carry \
                else None
            y, p, c2 = m.replay(window, mc)
            ys.append(y)
            ps.append(p)
            new[k] = c2
        if ys and ys[0] is None:        # zero-length window: carry only
            return None, None, new
        del batch
        fused = self.fuse(ys, ps, update=False)
        return fused.forecast, fused.p_extreme, new

    # -- device-resident decode slots --------------------------------------
    def init_slots(self, num_slots: int) -> EnsembleSlots:
        """One lane numbering across every member: lane ``i`` in each
        member's slot state holds the same client. ``num_slots`` rounds
        up to the lcm of member decode widths so every member agrees on
        the lane count."""
        _, members = self._members()
        w = self.decode_width
        s = -(-int(num_slots) // w) * w
        return EnsembleSlots(
            slots={k: m.init_slots(s) for k, m in members},
            num_slots=s, active=np.zeros((s,), bool))

    def prefill(self, window, carry=None):
        return self.replay(window, carry)

    def insert(self, slots: EnsembleSlots, lane: int, carry,
               donate: bool | None = None) -> EnsembleSlots:
        _, members = self._members()
        for k, m in members:
            m.insert(slots.slots[k], lane,
                     self._member_carry(carry, k, m), donate=donate)
        slots.active[lane] = True
        return slots

    def extract(self, slots: EnsembleSlots, lane: int):
        _, members = self._members()
        return {k: m.extract(slots.slots[k], lane) for k, m in members}

    def release(self, slots: EnsembleSlots, lane: int) -> None:
        _, members = self._members()
        for k, m in members:
            m.release(slots.slots[k], lane)
        slots.active[lane] = False

    def generate(self, slots: EnsembleSlots, x, lanes=None,
                 donate: bool | None = None):
        """One fused ``slots_generate`` dispatch PER MEMBER (N total per
        tick), fused row-wise. Rows for lanes outside ``lanes`` are
        garbage (as in the single-model contract) and are excluded from
        the online error/anomaly update."""
        _, members = self._members()
        x = np.asarray(x, np.float32)
        rows = (np.flatnonzero(slots.active) if lanes is None
                else np.asarray(lanes, np.int64))
        ys, ps = [], []
        for k, m in members:
            ms = slots.slots[k]
            xm = x
            if ms.num_slots != x.shape[0]:
                xm = np.zeros((ms.num_slots, x.shape[1]), np.float32)
                xm[:x.shape[0]] = x
            y, p, _ = m.generate(ms, xm, lanes=rows, donate=donate)
            ys.append(np.asarray(y)[:slots.num_slots])
            ps.append(np.asarray(p)[:slots.num_slots])
        fused = self.fuse(ys, ps, rows=rows)
        return fused.forecast, fused.p_extreme, slots

    def warm_slots(self, num_slots: int) -> int:
        _, members = self._members()
        return sum(m.warm_slots(num_slots) for _, m in members
                   if hasattr(m, "warm_slots"))

    def warm_decode(self) -> int:
        _, members = self._members()
        return sum(m.warm_decode() for _, m in members
                   if hasattr(m, "warm_decode"))

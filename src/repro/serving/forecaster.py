"""One serving interface over every model: ``predict(windows, lengths)
-> (forecast, extreme_probability)``.

Two implementations:

- ``LSTMForecaster`` — the paper model (2xLSTM + 3xFC, window 20). The
  forecast is the next-step normalized close; the extreme probability
  fuses the trained EVL sigmoid head with the EVT tail machinery of
  ``repro.extreme`` (eq. 3 GEV depth-into-tail + eq. 4 exceedance), with
  the eq. 1 indicator as the discrete alert. Supports O(1) incremental
  ``step`` with explicit carries for the session cache.

- ``ZooForecaster`` — any ``repro.models.model_zoo`` arch serving
  next-token prediction; the "extreme event" is an anomalously
  surprising continuation (surprisal in the EVT tail), the serving-side
  analogue of the paper's extreme-event indicator.

Both are calibrated by ``fit_tail`` over a reference score distribution,
so ``p_extreme`` is comparable across models hosted in one registry.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.extreme.evt import fit_tail, gev_cdf, tail_probability
from repro.extreme.indicators import indicator_sequence, quantile_thresholds
from repro.models.rnn import (RNNConfig, init_rnn, init_rnn_carry,
                              rnn_apply_padded, rnn_step)

PyTree = Any

# One compiled function set per RNNConfig, shared by every forecaster
# instance with that config. This is what makes weight hot-swapping
# cheap: a freshly published version reuses the traced programs of the
# version it replaces (params — and the EVT tail calibration — are
# traced arguments, so only shapes key the jit cache), and the swap
# itself never compiles. The predict/step variants fuse the GEV alert
# head into the same program as the model apply: one dispatch per
# micro-batch flush, no eager tail math on the serving hot path (which
# is what lets concurrent mesh shards overlap their GIL-free compute).
_RNN_COMPILED: dict[RNNConfig, dict[str, Any]] = {}


def _fused_alert(score, head, xi, scale, active, gamma):
    """Jit-side twin of ``_alert_probability``. ``active`` is a TRACED
    flag (uncalibrated forecasters pass False with dummy xi/scale): one
    compiled program serves both states, so a calibration flip — e.g.
    the first ``WeightPublisher`` publish re-calibrating an uncalibrated
    v1 — never compiles on the serving hot path."""
    z = (score - xi) / jnp.maximum(scale, 1e-8)
    p = jnp.where(active, gev_cdf(z, gamma), jnp.zeros_like(score))
    if head is not None:
        p = 1.0 - (1.0 - head) * (1.0 - p)
    return jnp.clip(p, 0.0, 1.0)


def _compiled_rnn(cfg: RNNConfig):
    fns = _RNN_COMPILED.get(cfg)
    if fns is None:
        # benign race under threads: worst case two identical jit wrappers
        # are built and one wins the dict slot

        def predict(params, x, lens, xi, scale, active, gamma):
            y, u = rnn_apply_padded(params, x, lens, cfg=cfg)
            return y, _fused_alert(jnp.abs(y), u, xi, scale, active, gamma)

        def step(params, x_t, carry, xi, scale, active, gamma):
            y, u, carry = rnn_step(params, x_t, carry, cfg=cfg)
            return y, _fused_alert(jnp.abs(y), u, xi, scale, active,
                                   gamma), carry

        def replay(params, window, carry, xi, scale, active, gamma):
            # one lax.scan over the SAME fused per-step computation the
            # session path runs (``step`` above, alert head included), so
            # a cache-miss replay is ONE dispatch instead of O(window)
            # host round trips. The scan is fully unrolled with
            # optimization barriers at each step's boundary: inside a
            # rolled loop body XLA selects instructions differently (FMA
            # contraction, fusion shapes) than in the standalone step
            # program, which breaks the session cache's bitwise
            # step==replay promise in the low bits — unrolled
            # barrier-isolated per-step subgraphs reproduce the
            # standalone step's compilation context exactly (window
            # lengths are bounded by cfg.window, so the unrolled
            # programs stay small).
            def body(c, x_t):
                x_t, c = jax.lax.optimization_barrier((x_t, c))
                y, p, c2 = step(params, x_t, c, xi, scale, active, gamma)
                y, p, c2 = jax.lax.optimization_barrier((y, p, c2))
                return c2, (y, p, c2)

            carry, (ys, ps, _cs) = jax.lax.scan(
                body, carry, jnp.swapaxes(window, 0, 1),
                unroll=window.shape[1])
            # EVERY per-step output — y, p, and the intermediate carries
            # — is returned live (callers take [-1] / the final carry):
            # were any of them dead code, XLA would prune parts of the
            # earlier iterations and re-fuse what remains differently
            # from the standalone step program, breaking bitwise parity
            # (measured: stacking y/p alone is not enough)
            return ys, ps, _cs, carry

        # gamma is static: gev_log_cdf branches on it in Python, and it
        # is a per-deployment constant (one compile per distinct value)
        fns = {
            "apply": jax.jit(partial(rnn_apply_padded, cfg=cfg)),
            "step": jax.jit(partial(rnn_step, cfg=cfg)),
            "predict": jax.jit(predict, static_argnames=("gamma",)),
            "fused_step": jax.jit(step, static_argnames=("gamma",)),
            "replay": jax.jit(replay, static_argnames=("gamma",)),
        }
        _RNN_COMPILED[cfg] = fns
    return fns


def _alert_probability(score, tail: dict | None, gamma: float, head=None):
    """Fuse the EVT tail calibration with an optional learned head.

    ``score`` is the magnitude being judged (|forecast| or surprisal).
    GEV depth-into-tail (eq. 3) gives a monotone [0, 1] extremeness
    measure: ~0 below the calibrated threshold xi, exp(-1) at xi, -> 1
    deep in the tail. A learned sigmoid head (the paper's EVL head) is
    combined by noisy-OR so either detector can raise the alert.
    """
    score = jnp.asarray(score, jnp.float32)
    if tail is None:
        p_evt = jnp.zeros_like(score)
    else:
        z = (score - tail["xi"]) / max(tail["scale"], 1e-8)
        p_evt = gev_cdf(z, gamma)
    if head is not None:
        p_evt = 1.0 - (1.0 - jnp.asarray(head, jnp.float32)) * (1.0 - p_evt)
    return jnp.clip(p_evt, 0.0, 1.0)


@dataclasses.dataclass
class LSTMForecaster:
    """Paper LSTM behind the serving interface. ``tail`` holds the
    ``fit_tail`` parameters over |forecast| scores; ``eps`` the eq. 1
    indicator thresholds."""

    cfg: RNNConfig
    params: PyTree
    tail: dict | None = None
    eps: tuple[float, float] = (0.01, 0.01)
    gamma: float = 5.0
    # stamped by ModelRegistry.register/swap: monotone per-key version and
    # publication time (for staleness-at-serve-time telemetry)
    version: int = 0
    published_at: float | None = None
    kind: str = dataclasses.field(default="lstm", init=False)

    def __post_init__(self):
        self._fns = _compiled_rnn(self.cfg)
        self._apply, self._step = self._fns["apply"], self._fns["step"]

    # -- batched serving ---------------------------------------------------
    @property
    def window(self) -> int:
        return self.cfg.window

    @property
    def feature_dim(self) -> int:
        return self.cfg.input_dim

    def predict(self, windows, lengths=None):
        """windows [B, T, F] (right-padded), lengths [B] true lengths.
        Returns (forecast [B], p_extreme [B]) as float32 numpy arrays.
        One fused jit dispatch: model apply + GEV alert head."""
        windows = jnp.asarray(windows, jnp.float32)
        if lengths is None:
            lengths = jnp.full((windows.shape[0],), windows.shape[1],
                               jnp.int32)
        lengths = jnp.asarray(lengths, jnp.int32)
        y, p = self._fns["predict"](self.params, windows, lengths,
                                    *self._tail_args(),
                                    gamma=float(self.gamma))
        return np.asarray(y), np.asarray(p)

    def _tail_args(self):
        """(xi, scale, active) for the fused alert: dummies + inactive
        when uncalibrated — same program either way."""
        if self.tail is None:
            return 0.0, 1.0, False
        return float(self.tail["xi"]), float(self.tail["scale"]), True

    def predict_detail(self, windows, lengths=None) -> dict:
        """Rich output: forecast, p_extreme, the eq. 1 indicator, and the
        eq. 4 exceedance probability P(Y > |forecast|)."""
        y, p = self.predict(windows, lengths)
        out = {"forecast": y, "p_extreme": p,
               "indicator": np.asarray(
                   indicator_sequence(y, self.eps[0], self.eps[1]))}
        if self.tail is not None:
            t = self.tail
            out["exceedance"] = np.asarray(jnp.clip(tail_probability(
                jnp.abs(y), t["xi"], t["scale"], t["tail_at_xi"],
                self.gamma), 0.0, 1.0))
        return out

    # -- incremental (session) serving ------------------------------------
    def init_carry(self, batch: int = 1):
        return init_rnn_carry(self.params, batch)

    def carry_nbytes(self, batch: int = 1) -> int:
        return sum(int(np.prod(h.shape)) * h.dtype.itemsize + int(
            np.prod(c.shape)) * c.dtype.itemsize
            for h, c in self.init_carry(batch))

    def step(self, x_t, carry):
        """One O(1) streaming step: x_t [B, F]. Returns
        (forecast [B], p_extreme [B], new_carry) — one fused dispatch,
        like ``predict``."""
        x_t = jnp.asarray(x_t, jnp.float32)
        y, p, carry = self._fns["fused_step"](self.params, x_t, carry,
                                              *self._tail_args(),
                                              gamma=float(self.gamma))
        return np.asarray(y), np.asarray(p), carry

    def replay(self, window, carry=None):
        """Full-window recompute through the *same* per-step math the
        session path uses (this is what a cache miss executes), so cached
        incremental serving is bitwise-identical to it — as ONE jitted
        ``lax.scan`` dispatch, not a Python loop syncing the device every
        timestep (O(window) host round trips on every cache miss and
        swap re-prime)."""
        window = jnp.asarray(window, jnp.float32)
        if carry is None:
            carry = self.init_carry(window.shape[0])
        if window.shape[1] == 0:
            return None, None, carry
        ys, ps, _, carry = self._fns["replay"](self.params, window, carry,
                                               *self._tail_args(),
                                               gamma=float(self.gamma))
        return np.asarray(ys[-1]), np.asarray(ps[-1]), carry

    # -- calibration -------------------------------------------------------
    def calibrate(self, windows, quantile: float = 0.95) -> "LSTMForecaster":
        """Fit the EVT tail + indicator thresholds on this model's own
        forecast distribution over a reference window set."""
        y, _ = self.predict(windows)
        self.tail = fit_tail(np.abs(y), q=quantile)
        self.eps = quantile_thresholds(y, q=quantile)
        return self

    def with_params(self, params: PyTree) -> "LSTMForecaster":
        """Unpublished successor serving ``params`` with this model's
        calibration carried over — the hot-swap constructor. Shares the
        compiled programs, so building one never traces or compiles."""
        return dataclasses.replace(self, params=params, version=0,
                                   published_at=None)


@dataclasses.dataclass
class ZooForecaster:
    """Any model-zoo arch behind the serving interface: forecast is the
    greedy next token; extreme probability is EVT-calibrated surprisal."""

    cfg: Any                     # repro.configs.base.ArchConfig
    params: PyTree
    tail: dict | None = None
    gamma: float = 5.0
    version: int = 0
    published_at: float | None = None
    kind: str = dataclasses.field(default="zoo", init=False)

    def __post_init__(self):
        from repro.models.model_zoo import build_model
        self._model = build_model(self.cfg)

        def _fwd(params, tokens, lengths):
            frames = None
            if self.cfg.family == "audio":
                # the audio frontend is stubbed repo-wide (spec): serve
                # with deterministic synthetic frame embeddings, as the
                # pre-subsystem serve launcher did
                frames = jax.random.normal(
                    jax.random.PRNGKey(0),
                    (tokens.shape[0], self.cfg.n_frames, self.cfg.d_model))
            logits, _ = self._model.forward(params, tokens, frames)
            idx = (lengths - 1)[:, None, None]
            last = jnp.take_along_axis(logits, jnp.broadcast_to(
                idx, (logits.shape[0], 1, logits.shape[2])), axis=1)[:, 0]
            last = last[:, :self.cfg.vocab]
            logp = jax.nn.log_softmax(last, axis=-1)
            tok = jnp.argmax(last, axis=-1)
            surprisal = -jnp.take_along_axis(logp, tok[:, None], 1)[:, 0]
            return tok.astype(jnp.int32), surprisal

        self._fwd = jax.jit(_fwd)

    @property
    def window(self) -> int:
        return 32                # default serving context bucket

    @property
    def feature_dim(self) -> int:
        return 0                 # token ids, no feature axis

    def predict(self, windows, lengths=None):
        """windows int32 [B, T] token ids (right-padded). Returns
        (next_token [B] as float32, p_extreme [B])."""
        tokens = jnp.asarray(windows, jnp.int32)
        if lengths is None:
            lengths = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
        tok, surprisal = self._fwd(self.params, tokens,
                                   jnp.asarray(lengths, jnp.int32))
        p = _alert_probability(surprisal, self.tail, self.gamma)
        return np.asarray(tok, np.float32), np.asarray(p)

    def calibrate(self, windows, quantile: float = 0.95) -> "ZooForecaster":
        tokens = jnp.asarray(windows, jnp.int32)
        lengths = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
        _, surprisal = self._fwd(self.params, tokens, lengths)
        self.tail = fit_tail(np.asarray(surprisal), q=quantile)
        return self

    def with_params(self, params: PyTree) -> "ZooForecaster":
        """Unpublished successor serving ``params`` with this model's
        calibration carried over — the hot-swap constructor. A shallow
        copy (NOT dataclasses.replace: ``__post_init__`` would rebuild
        and re-jit the forward) so the compiled ``_fwd`` is shared;
        params are traced arguments, so serving the clone never
        retraces."""
        import copy

        clone = copy.copy(self)
        clone.params = params
        clone.version = 0
        clone.published_at = None
        return clone


def build_lstm_forecaster(seed: int = 0, cfg: RNNConfig | None = None,
                          params: PyTree | None = None,
                          calibrate_ticker: str | None = "AAPL",
                          n_days: int = 400) -> LSTMForecaster:
    """Paper-config LSTM forecaster; freshly initialized unless ``params``
    is given, EVT-calibrated on a synthetic reference series."""
    if cfg is None:
        from repro.configs.paper_lstm import CONFIG
        cfg = CONFIG
    if params is None:
        params = init_rnn(jax.random.PRNGKey(seed), cfg)
    fc = LSTMForecaster(cfg=cfg, params=params)
    if calibrate_ticker is not None:
        from repro.data import load_stock, make_windows
        ohlcv = load_stock(calibrate_ticker, n_days=n_days)
        ds = make_windows(ohlcv, window=cfg.window)
        fc.calibrate(ds.x)
    return fc


def build_zoo_forecaster(arch: str, seed: int = 0, reduced: bool = True,
                         calibrate_batch: int = 8) -> ZooForecaster:
    from repro.configs import get_config
    from repro.configs.base import reduced as reduce_cfg
    from repro.data.tokens import synthetic_token_batch
    from repro.models.model_zoo import build_model

    cfg = get_config(arch)
    if reduced:
        cfg = reduce_cfg(cfg)
    params = build_model(cfg).init(jax.random.PRNGKey(seed))
    fc = ZooForecaster(cfg=cfg, params=params)
    if calibrate_batch:
        toks = synthetic_token_batch(calibrate_batch, fc.window, cfg.vocab,
                                     seed=seed)
        fc.calibrate(toks)
    return fc

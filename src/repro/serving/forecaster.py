"""One serving interface over every model: ``predict(windows, lengths)
-> (forecast, extreme_probability)``.

The interface itself is the ``Forecaster`` protocol below (batch
prediction) plus ``StreamingForecaster`` (adds O(1) incremental state:
explicit carries, replay, and decode-slot residency). Anything
satisfying them composes — the registry, engine, session runner and
mesh layers are written against the protocols, which is what lets
``repro.serving.ensemble.EnsembleForecaster`` (a model *set* fused by
EVT-weighted combination) serve through the exact same paths as a
single model.

Two concrete single-model implementations:

- ``LSTMForecaster`` — the paper model (2xLSTM + 3xFC, window 20). The
  forecast is the next-step normalized close; the extreme probability
  fuses the trained EVL sigmoid head with the EVT tail machinery of
  ``repro.extreme`` (eq. 3 GEV depth-into-tail + eq. 4 exceedance), with
  the eq. 1 indicator as the discrete alert. Supports O(1) incremental
  ``step`` with explicit carries for the session cache.

- ``ZooForecaster`` — any ``repro.models.model_zoo`` arch serving
  next-token prediction; the "extreme event" is an anomalously
  surprising continuation (surprisal in the EVT tail), the serving-side
  analogue of the paper's extreme-event indicator.

Both are calibrated by ``fit_tail`` over a reference score distribution,
so ``p_extreme`` is comparable across models hosted in one registry.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.extreme.evt import fit_tail, gev_cdf, tail_probability
from repro.extreme.indicators import indicator_sequence, quantile_thresholds
from repro.kernels import dispatch
from repro.models.rnn import (RNNConfig, init_rnn, init_rnn_carry,
                              rnn_apply_padded, rnn_step, split_rnn_carry,
                              stack_rnn_carries)

PyTree = Any


@runtime_checkable
class Forecaster(Protocol):
    """What the serving plane requires of a servable model: shape
    metadata plus batched prediction. Structural — ``LSTMForecaster``,
    ``ZooForecaster`` and ``EnsembleForecaster`` all satisfy it without
    inheriting anything."""

    kind: str

    @property
    def window(self) -> int: ...

    @property
    def feature_dim(self) -> int: ...

    def predict(self, windows, lengths=None): ...


@runtime_checkable
class StreamingForecaster(Forecaster, Protocol):
    """A ``Forecaster`` that also serves O(1) streaming: explicit
    carries (opaque to callers — single models use per-layer (h, c)
    tuples, ensembles use {member: carry} dicts), history replay, and
    device-resident decode slots. This is the full contract
    ``RecurrentSessionRunner`` / ``DecodeSlots`` serving is written
    against."""

    @property
    def decode_width(self) -> int: ...

    def init_carry(self, batch: int = 1): ...

    def carry_nbytes(self, batch: int = 1) -> int: ...

    def step(self, x_t, carry): ...

    def step_many(self, xs, carries, donate=None): ...

    def replay(self, window, carry=None): ...


# One compiled function set per RNNConfig, shared by every forecaster
# instance with that config. This is what makes weight hot-swapping
# cheap: a freshly published version reuses the traced programs of the
# version it replaces (params — and the EVT tail calibration — are
# traced arguments, so only shapes key the jit cache), and the swap
# itself never compiles. The predict/step variants fuse the GEV alert
# head into the same program as the model apply: one dispatch per
# micro-batch flush, no eager tail math on the serving hot path (which
# is what lets concurrent mesh shards overlap their GIL-free compute).
_RNN_COMPILED: dict[RNNConfig, dict[str, Any]] = {}
_RNN_COMPILED_LOCK = threading.Lock()


def _donate_default() -> bool:
    """Carry donation default: on wherever it actually buys anything —
    i.e. off-CPU. XLA:CPU implements buffer donation as a warn + copy,
    so on CPU the default stays off and steady state is unchanged."""
    return jax.default_backend() != "cpu"


def _fused_alert(score, head, xi, scale, active, gamma):
    """Jit-side twin of ``_alert_probability``. ``active`` is a TRACED
    flag (uncalibrated forecasters pass False with dummy xi/scale): one
    compiled program serves both states, so a calibration flip — e.g.
    the first ``WeightPublisher`` publish re-calibrating an uncalibrated
    v1 — never compiles on the serving hot path."""
    z = (score - xi) / jnp.maximum(scale, 1e-8)
    p = jnp.where(active, gev_cdf(z, gamma), jnp.zeros_like(score))
    if head is not None:
        p = 1.0 - (1.0 - head) * (1.0 - p)
    return jnp.clip(p, 0.0, 1.0)


def _compiled_rnn(cfg: RNNConfig):
    """Compiled function set for ``cfg`` — double-checked lock, so
    concurrent first lookups (shard-join warmup races) build the
    wrappers exactly once instead of racing to the dict slot."""
    fns = _RNN_COMPILED.get(cfg)
    if fns is None:
        with _RNN_COMPILED_LOCK:
            fns = _RNN_COMPILED.get(cfg)
            if fns is None:
                fns = _build_rnn_fns(cfg)
                _RNN_COMPILED[cfg] = fns
    return fns


def _build_rnn_fns(cfg: RNNConfig):
    def predict(params, x, lens, xi, scale, active, gamma):
        y, u = rnn_apply_padded(params, x, lens, cfg=cfg)
        return y, _fused_alert(jnp.abs(y), u, xi, scale, active, gamma)

    def step(params, x_t, carry, xi, scale, active, gamma):
        y, u, carry = rnn_step(params, x_t, carry, cfg=cfg)
        return y, _fused_alert(jnp.abs(y), u, xi, scale, active,
                               gamma), carry

    def replay(params, window, carry, xi, scale, active, gamma):
        # one lax.scan over the SAME fused per-step computation the
        # session path runs (``step`` above, alert head included), so
        # a cache-miss replay is ONE dispatch instead of O(window)
        # host round trips. The scan is fully unrolled with
        # optimization barriers at each step's boundary: inside a
        # rolled loop body XLA selects instructions differently (FMA
        # contraction, fusion shapes) than in the standalone step
        # program, which breaks the session cache's bitwise
        # step==replay promise in the low bits — unrolled
        # barrier-isolated per-step subgraphs reproduce the
        # standalone step's compilation context exactly (window
        # lengths are bounded by cfg.window, so the unrolled
        # programs stay small).
        def body(c, x_t):
            x_t, c = jax.lax.optimization_barrier((x_t, c))
            y, p, c2 = step(params, x_t, c, xi, scale, active, gamma)
            y, p, c2 = jax.lax.optimization_barrier((y, p, c2))
            return c2, (y, p, c2)

        carry, (ys, ps, _cs) = jax.lax.scan(
            body, carry, jnp.swapaxes(window, 0, 1),
            unroll=window.shape[1])
        # EVERY per-step output — y, p, and the intermediate carries
        # — is returned live (callers take [-1] / the final carry):
        # were any of them dead code, XLA would prune parts of the
        # earlier iterations and re-fuse what remains differently
        # from the standalone step program, breaking bitwise parity
        # (measured: stacking y/p alone is not enough)
        return ys, ps, _cs, carry

    # -- decode lane -----------------------------------------------
    # Every streaming step — single-session or a batched flush —
    # executes the SAME barrier-isolated step subgraph at one fixed
    # batch width. That is what makes batched-step == per-session
    # step == replay hold BITWISE: XLA compiles the fused step
    # differently at different batch shapes (measured: batch-N and
    # batch-1 programs disagree in the low bits), but within one
    # program each row's output is a pure function of that row, so
    # padding rows can never perturb real sessions. The barriers
    # isolate the width-W step subgraph from the surrounding
    # pad/gather graph exactly like ``replay``'s per-step barriers
    # do — all lane programs therefore share one compilation
    # context for the step math.

    def decode_step(params, x_t, carry, xi, scale, active, gamma,
                    width):
        # x_t [b, F], carry [b, H]-stacked, b <= width (static)
        pad = width - x_t.shape[0]
        xp = jnp.pad(x_t, ((0, pad), (0, 0)))
        cp = jax.tree_util.tree_map(
            lambda a: jnp.pad(a, ((0, pad), (0, 0))), carry)
        xp, cp = jax.lax.optimization_barrier((xp, cp))
        y, p, c2 = step(params, xp, cp, xi, scale, active, gamma)
        y, p, c2 = jax.lax.optimization_barrier((y, p, c2))
        b = x_t.shape[0]
        return y[:b], p[:b], jax.tree_util.tree_map(
            lambda a: a[:b], c2)

    def decode_many(params, x_t, carries, xi, scale, active, gamma):
        # x_t [W, F]; carries: tuple of W per-session batch-1
        # carries (padding slots hold zero carries). Per-session
        # buffers go in and come out as separate jit args/results,
        # so a batched flush is ONE dispatch with no eager
        # gather/scatter ops around it.
        stacked = stack_rnn_carries(carries)
        xp, cp = jax.lax.optimization_barrier((x_t, stacked))
        y, p, c2 = step(params, xp, cp, xi, scale, active, gamma)
        y, p, c2 = jax.lax.optimization_barrier((y, p, c2))
        return y, p, tuple(split_rnn_carry(c2))

    def decode_replay(params, window, carry, xi, scale, active,
                      gamma, width):
        # window [b, T, F], b <= width: replay at lane width so the
        # unrolled per-step subgraphs match the decode steps'
        pad = width - window.shape[0]
        wp = jnp.pad(window, ((0, pad), (0, 0), (0, 0)))
        cp = jax.tree_util.tree_map(
            lambda a: jnp.pad(a, ((0, pad), (0, 0))), carry)
        ys, ps, cs, c2 = replay(params, wp, cp, xi, scale, active,
                                gamma)
        b = window.shape[0]
        # the intermediate carries stay live in the output (sliced,
        # like every other result) — pruning them re-fuses the
        # earlier unrolled steps and breaks bitwise parity (see the
        # measured note in ``replay`` above)
        return (ys[:, :b], ps[:, :b],
                jax.tree_util.tree_map(lambda a: a[:, :b], cs),
                jax.tree_util.tree_map(lambda a: a[:b], c2))

    # -- device-resident decode slots ------------------------------
    # The continuous-batching state: ``num_slots`` lanes of stacked
    # carries that LIVE on device. ``insert`` writes one session's
    # batch-1 carry into a lane (dynamic_update_slice with a TRACED
    # lane index — one compiled program serves every lane, and the
    # donating variant updates the slot state in place, no full-state
    # copy). ``extract`` is its inverse (spill / migration read).
    # ``generate`` steps ALL lanes in one dispatch: the slot state is
    # walked in static chunks of the decode-lane width, each chunk
    # running the SAME barrier-isolated step subgraph as
    # decode_step/decode_many above — one compilation context for the
    # step math, so a lane's output stays bitwise-equal to the
    # per-session step/replay path.

    def slots_insert(slot_carry, carry, lane):
        # slot_carry [S, H]-stacked, carry [1, H]-stacked, lane int32
        return jax.tree_util.tree_map(
            lambda s, row: jax.lax.dynamic_update_slice(s, row, (lane, 0)),
            slot_carry, carry)

    def slots_extract(slot_carry, lane):
        return jax.tree_util.tree_map(
            lambda s: jax.lax.dynamic_slice(s, (lane, 0), (1, s.shape[1])),
            slot_carry)

    def slots_generate(params, x, slot_carry, step_mask, xi, scale,
                       active, gamma, width):
        # x [S, F], slot_carry [S, H]-stacked, S a static multiple of
        # ``width``. step_mask [S] marks the lanes this flush actually
        # steps: resident lanes that are NOT part of the flush pass
        # their carry through unchanged (the select happens OUTSIDE
        # the barrier-isolated step subgraphs, so it cannot perturb
        # the stepped rows' bits).
        S = x.shape[0]
        ys, ps, cs = [], [], []
        for lo in range(0, S, width):
            xc = x[lo:lo + width]
            cc = jax.tree_util.tree_map(lambda a: a[lo:lo + width],
                                        slot_carry)
            xc, cc = jax.lax.optimization_barrier((xc, cc))
            y, p, c2 = step(params, xc, cc, xi, scale, active, gamma)
            y, p, c2 = jax.lax.optimization_barrier((y, p, c2))
            ys.append(y)
            ps.append(p)
            cs.append(c2)
        y = jnp.concatenate(ys)
        p = jnp.concatenate(ps)
        stepped = jax.tree_util.tree_map(
            lambda *parts: jnp.concatenate(parts, axis=0), *cs)
        m = step_mask[:, None]
        new_carry = jax.tree_util.tree_map(
            lambda old, new: jnp.where(m, new, old), slot_carry, stepped)
        return y, p, new_carry

    # gamma is static: gev_log_cdf branches on it in Python, and it
    # is a per-deployment constant (one compile per distinct value)
    return {
        "predict": jax.jit(predict, static_argnames=("gamma",)),
        # NOTE: no standalone (non-lane) step program is exposed — every
        # streaming step must go through the fixed-width decode lane
        # below, or the bitwise step==replay==batched-step contract dies
        "replay": jax.jit(replay, static_argnames=("gamma",)),
        "decode_step": jax.jit(decode_step,
                               static_argnames=("gamma", "width")),
        "decode_many": jax.jit(decode_many,
                               static_argnames=("gamma",)),
        # the donating variant: per-session carry buffers handed to
        # the lane are consumed in place (no copy into the stacked
        # batch). Only safe when the caller exclusively owns them —
        # the engine-internal runner does; see ``step_many`` — and
        # only useful off-CPU (CPU donation is a no-op that warns)
        "decode_many_donate": jax.jit(decode_many,
                                      static_argnames=("gamma",),
                                      donate_argnums=(2,)),
        "decode_replay": jax.jit(decode_replay,
                                 static_argnames=("gamma", "width")),
        "slots_insert": jax.jit(slots_insert),
        # in-place lane write: the slot state is donated back to
        # itself, so an insert never copies the other lanes
        "slots_insert_donate": jax.jit(slots_insert, donate_argnums=(0,)),
        "slots_extract": jax.jit(slots_extract),
        "slots_generate": jax.jit(slots_generate,
                                  static_argnames=("gamma", "width")),
        # the steady-state program: slot carries donated in and out —
        # one dispatch per flush, zero allocation, zero host copies
        "slots_generate_donate": jax.jit(slots_generate,
                                         static_argnames=("gamma", "width"),
                                         donate_argnums=(2,)),
    }


def _alert_probability(score, tail: dict | None, gamma: float, head=None):
    """Fuse the EVT tail calibration with an optional learned head.

    ``score`` is the magnitude being judged (|forecast| or surprisal).
    GEV depth-into-tail (eq. 3) gives a monotone [0, 1] extremeness
    measure: ~0 below the calibrated threshold xi, exp(-1) at xi, -> 1
    deep in the tail. A learned sigmoid head (the paper's EVL head) is
    combined by noisy-OR so either detector can raise the alert.
    """
    score = jnp.asarray(score, jnp.float32)
    if tail is None:
        p_evt = jnp.zeros_like(score)
    else:
        z = (score - tail["xi"]) / max(tail["scale"], 1e-8)
        p_evt = gev_cdf(z, gamma)
    if head is not None:
        p_evt = 1.0 - (1.0 - jnp.asarray(head, jnp.float32)) * (1.0 - p_evt)
    return jnp.clip(p_evt, 0.0, 1.0)


@dataclasses.dataclass
class DecodeSlots:
    """Device-resident decode slot state: ``num_slots`` lanes of stacked
    (h, c) carries held as device arrays, plus a host-side active-lane
    mask. Sessions are written into lanes with ``insert`` (prefill →
    insert), stepped in place by ``generate`` (one fused dispatch for
    ALL lanes), and read out only on spill/migration (``extract``).
    ``num_slots`` is always a multiple of the owning forecaster's
    ``decode_width`` — ``init_slots`` rounds up — so generate can chunk
    the state at the lane width with no partial chunk."""

    carry: PyTree                # [num_slots, H]-stacked per layer
    num_slots: int
    active: Any                  # np.ndarray bool [num_slots], host-side

    @property
    def n_active(self) -> int:
        return int(self.active.sum())


@dataclasses.dataclass
class LSTMForecaster:
    """Paper LSTM behind the serving interface. ``tail`` holds the
    ``fit_tail`` parameters over |forecast| scores; ``eps`` the eq. 1
    indicator thresholds."""

    cfg: RNNConfig
    params: PyTree
    tail: dict | None = None
    eps: tuple[float, float] = (0.01, 0.01)
    gamma: float = 5.0
    # stamped by ModelRegistry.register/swap: monotone per-key version and
    # publication time (for staleness-at-serve-time telemetry)
    version: int = 0
    published_at: float | None = None
    # decode-lane width: EVERY streaming step/replay runs the fused step
    # at this fixed batch width (padded; larger batches chunk), which is
    # what keeps step == replay == batched-step bitwise-equal — XLA
    # compiles different batch shapes differently, one shared width
    # side-steps that. 8 = one TPU sublane tile; also the Pallas
    # kernel's block_b.
    decode_width: int = 8
    kind: str = dataclasses.field(default="lstm", init=False)

    def __post_init__(self):
        if self.decode_width < 1:
            raise ValueError(
                f"decode_width must be >= 1, got {self.decode_width}")
        self._fns = _compiled_rnn(self.cfg)
        # one zero per-session carry, shared by every padding slot of a
        # partial batched flush (never donated — see step_many)
        self._zero_session = init_rnn_carry(self.params, 1)

    # -- batched serving ---------------------------------------------------
    @property
    def window(self) -> int:
        return self.cfg.window

    @property
    def feature_dim(self) -> int:
        return self.cfg.input_dim

    def predict(self, windows, lengths=None):
        """windows [B, T, F] (right-padded), lengths [B] true lengths.
        Returns (forecast [B], p_extreme [B]) as float32 numpy arrays.
        One fused jit dispatch: model apply + GEV alert head."""
        windows = jnp.asarray(windows, jnp.float32)
        if lengths is None:
            lengths = jnp.full((windows.shape[0],), windows.shape[1],
                               jnp.int32)
        lengths = jnp.asarray(lengths, jnp.int32)
        dispatch.record("predict", batch=int(windows.shape[0]),
                        hidden=self.cfg.hidden, kernel_op="lstm_cell")
        with jax.profiler.TraceAnnotation("repro.predict"):
            y, p = self._fns["predict"](self.params, windows, lengths,
                                        *self._tail_args(),
                                        gamma=float(self.gamma))
        return np.asarray(y), np.asarray(p)

    def _tail_args(self):
        """(xi, scale, active) for the fused alert: dummies + inactive
        when uncalibrated — same program either way."""
        if self.tail is None:
            return 0.0, 1.0, False
        return float(self.tail["xi"]), float(self.tail["scale"]), True

    def predict_detail(self, windows, lengths=None) -> dict:
        """Rich output: forecast, p_extreme, the eq. 1 indicator, and the
        eq. 4 exceedance probability P(Y > |forecast|)."""
        y, p = self.predict(windows, lengths)
        out = {"forecast": y, "p_extreme": p,
               "indicator": np.asarray(
                   indicator_sequence(y, self.eps[0], self.eps[1]))}
        if self.tail is not None:
            t = self.tail
            out["exceedance"] = np.asarray(jnp.clip(tail_probability(
                jnp.abs(y), t["xi"], t["scale"], t["tail_at_xi"],
                self.gamma), 0.0, 1.0))
        return out

    # -- incremental (session) serving ------------------------------------
    def init_carry(self, batch: int = 1):
        return init_rnn_carry(self.params, batch)

    def carry_nbytes(self, batch: int = 1) -> int:
        return sum(int(np.prod(h.shape)) * h.dtype.itemsize + int(
            np.prod(c.shape)) * c.dtype.itemsize
            for h, c in self.init_carry(batch))

    def step(self, x_t, carry):
        """One O(1) streaming step: x_t [B, F]. Returns
        (forecast [B], p_extreme [B], new_carry) — one fused dispatch
        through the decode lane (the step runs padded at
        ``decode_width``; batches beyond the width chunk)."""
        x_t = jnp.asarray(x_t, jnp.float32)
        B = x_t.shape[0]
        W = self.decode_width
        if B > W:
            ys, ps, carries = [], [], []
            for lo in range(0, B, W):
                chunk = jax.tree_util.tree_map(lambda a: a[lo:lo + W],
                                               carry)
                y, p, c2 = self.step(x_t[lo:lo + W], chunk)
                ys.append(y), ps.append(p), carries.append(c2)
            stacked = jax.tree_util.tree_map(
                lambda *leaves: jnp.concatenate(leaves, axis=0), *carries)
            return np.concatenate(ys), np.concatenate(ps), stacked
        dispatch.record("decode_step", batch=W, hidden=self.cfg.hidden,
                        kernel_op="lstm_cell")
        with jax.profiler.TraceAnnotation("repro.decode_step"):
            y, p, carry = self._fns["decode_step"](self.params, x_t, carry,
                                                   *self._tail_args(),
                                                   gamma=float(self.gamma),
                                                   width=W)
        return np.asarray(y), np.asarray(p), carry

    def step_many(self, xs, carries, donate: bool | None = None):
        """Batched streaming step for N independent sessions: xs [N, F],
        ``carries`` a list of N batch-1 carries (one per session, as the
        session cache holds them). Returns (forecast [N], p_extreme [N],
        new_carries list) in ceil(N / decode_width) fused dispatches —
        per-session buffers travel as jit arguments/results, so the
        gather/scatter around the lane costs no extra dispatches.

        ``donate=True`` additionally donates the input carry buffers to
        the lane (they are consumed — no copy into the stacked batch).
        The default (``None``) resolves to True off-CPU and False on CPU
        (XLA:CPU implements donation as a warn + copy). Donation is only
        safe when the caller exclusively owns every carry: the
        engine-internal runner does (one worker thread, cache exported
        only after drain); carries that a concurrent reader could still
        hand out (live-membership migration) must pass ``donate=False``
        explicitly — the transport workers do."""
        xs = np.asarray(xs, np.float32)
        N = len(carries)
        W = self.decode_width
        donate = _donate_default() if donate is None \
            else (donate and jax.default_backend() != "cpu")
        fn = self._fns["decode_many_donate" if donate else "decode_many"]
        ys, ps, out = [], [], []
        for lo in range(0, N, W):
            chunk = list(carries[lo:lo + W])
            n = len(chunk)
            if n < W:
                # padding slots: the shared zero carry (fresh buffers
                # when donating — a buffer may be donated only once)
                pad = [init_rnn_carry(self.params, 1) for _ in
                       range(W - n)] if donate \
                    else [self._zero_session] * (W - n)
                chunk.extend(pad)
            x = np.zeros((W, xs.shape[1]), np.float32)
            x[:n] = xs[lo:lo + n]
            dispatch.record("decode_many", batch=W, hidden=self.cfg.hidden,
                            kernel_op="lstm_cell")
            with jax.profiler.TraceAnnotation("repro.decode_many"):
                y, p, sessions = fn(self.params, x, tuple(chunk),
                                    *self._tail_args(),
                                    gamma=float(self.gamma))
            ys.append(np.asarray(y)[:n])
            ps.append(np.asarray(p)[:n])
            out.extend(sessions[:n])
        return np.concatenate(ys), np.concatenate(ps), out

    def replay(self, window, carry=None):
        """Full-window recompute through the *same* per-step math the
        session path uses (this is what a cache miss executes), so cached
        incremental serving is bitwise-identical to it — as ONE jitted
        ``lax.scan`` dispatch, not a Python loop syncing the device every
        timestep (O(window) host round trips on every cache miss and
        swap re-prime). Runs at the decode-lane width, padded, like
        every step."""
        window = jnp.asarray(window, jnp.float32)
        B = window.shape[0]
        if carry is None:
            carry = self.init_carry(B)
        if window.shape[1] == 0:
            return None, None, carry
        W = self.decode_width
        if B > W:
            ys, ps, carries = [], [], []
            for lo in range(0, B, W):
                chunk = jax.tree_util.tree_map(lambda a: a[lo:lo + W],
                                               carry)
                y, p, c2 = self.replay(window[lo:lo + W], chunk)
                ys.append(y), ps.append(p), carries.append(c2)
            stacked = jax.tree_util.tree_map(
                lambda *leaves: jnp.concatenate(leaves, axis=0), *carries)
            return np.concatenate(ys), np.concatenate(ps), stacked
        dispatch.record("decode_replay", batch=W, hidden=self.cfg.hidden,
                        kernel_op="lstm_cell")
        with jax.profiler.TraceAnnotation("repro.decode_replay"):
            ys, ps, _, carry = self._fns["decode_replay"](
                self.params, window, carry, *self._tail_args(),
                gamma=float(self.gamma), width=W)
        return np.asarray(ys[-1]), np.asarray(ps[-1]), carry

    # -- device-resident decode slots (prefill / insert / generate) --------
    def init_slots(self, num_slots: int) -> DecodeSlots:
        """Allocate the device-resident slot state: ``num_slots`` lanes
        of zero carries (rounded up to a ``decode_width`` multiple) and
        an all-free active mask."""
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        W = self.decode_width
        S = -(-int(num_slots) // W) * W
        return DecodeSlots(carry=init_rnn_carry(self.params, S),
                           num_slots=S,
                           active=np.zeros((S,), bool))

    def prefill(self, window, carry=None):
        """The prefill phase of the slot lifecycle: replay a session's
        window into a batch-1 carry ready for ``insert``. Exactly
        ``replay`` (one ``decode_replay`` dispatch at the lane width),
        named for the prefill/insert/generate API — so a prefilled lane
        is bitwise-equal to the step-by-step session it replaces."""
        return self.replay(window, carry)

    def insert(self, slots: DecodeSlots, lane: int, carry,
               donate: bool | None = None) -> DecodeSlots:
        """Write a batch-1 ``carry`` into ``lane`` — a single
        ``dynamic_update_slice`` on device, no full-state host round
        trip. With donation (default off-CPU) the slot state is updated
        in place; either way ``slots.carry`` is rebound to the result,
        so callers must treat the previous value as consumed."""
        donate = _donate_default() if donate is None \
            else (donate and jax.default_backend() != "cpu")
        dispatch.record("slots_insert", batch=1, hidden=self.cfg.hidden,
                        kernel_op="lstm_cell")
        fn = self._fns["slots_insert_donate" if donate else "slots_insert"]
        with jax.profiler.TraceAnnotation("repro.slots_insert"):
            slots.carry = fn(slots.carry, carry, jnp.int32(lane))
        slots.active[lane] = True
        return slots

    def extract(self, slots: DecodeSlots, lane: int):
        """Read ``lane``'s batch-1 carry out of the slot state (spill /
        migration path) — a single ``dynamic_slice``; the lane content
        is left intact and the extracted carry is bitwise-identical to
        what ``insert`` + ``generate`` steps produced."""
        dispatch.record("slots_extract", batch=1, hidden=self.cfg.hidden,
                        kernel_op="lstm_cell")
        with jax.profiler.TraceAnnotation("repro.slots_extract"):
            return self._fns["slots_extract"](slots.carry, jnp.int32(lane))

    def release(self, slots: DecodeSlots, lane: int) -> None:
        """Mark ``lane`` free. Its stale carry stays on device and is
        overwritten by the next ``insert``."""
        slots.active[lane] = False

    def generate(self, slots: DecodeSlots, x, lanes=None,
                 donate: bool | None = None):
        """One fused dispatch stepping the slot state: x [num_slots, F]
        (rows for lanes not being stepped are ignored). ``lanes`` lists
        the lanes this call steps (default: every active lane); all
        other lanes pass their carry through unchanged. Returns
        (forecast [num_slots], p_extreme [num_slots], slots) — read only
        the rows for ``lanes``; other rows are garbage. With donation
        (default off-CPU) the slot carries are donated in and out, so a
        steady-state generate allocates nothing and copies nothing
        host-side."""
        donate = _donate_default() if donate is None \
            else (donate and jax.default_backend() != "cpu")
        x = np.asarray(x, np.float32)
        S = slots.num_slots
        if x.shape != (S, self.feature_dim):
            raise ValueError(f"generate expects x [{S}, "
                             f"{self.feature_dim}], got {x.shape}")
        mask = np.zeros((S,), bool)
        if lanes is None:
            mask[:] = slots.active
        else:
            mask[np.asarray(lanes, np.int64)] = True
        dispatch.record("slots_generate", batch=S, hidden=self.cfg.hidden,
                        kernel_op="lstm_cell")
        fn = self._fns["slots_generate_donate" if donate
                       else "slots_generate"]
        with jax.profiler.TraceAnnotation("repro.slots_generate"):
            y, p, carry = fn(self.params, x, slots.carry, mask,
                             *self._tail_args(), gamma=float(self.gamma),
                             width=self.decode_width)
        slots.carry = carry
        return np.asarray(y), np.asarray(p), slots

    def warm_slots(self, num_slots: int) -> int:
        """Compile the slot lifecycle programs (insert/extract/generate,
        plain and donating variants) off the serving path, against a
        throwaway slot state. Returns #programs compiled."""
        slots = self.init_slots(num_slots)
        F = self.feature_dim
        x = np.zeros((slots.num_slots, F), np.float32)
        self.insert(slots, 0, self.init_carry(1), donate=False)
        self.insert(slots, 0, self.init_carry(1), donate=True)
        self.extract(slots, 0)
        self.generate(slots, x, lanes=[0], donate=False)
        self.generate(slots, x, lanes=[0], donate=True)
        return 5

    def warm_decode(self) -> int:
        """Compile the decode-lane programs (single step, batched flush
        in both its plain and donating variants, full-window replay) off
        the serving path. Returns #programs the streaming hot path can
        hit."""
        F = self.feature_dim
        W = self.decode_width
        self.step(np.zeros((1, F), np.float32), self.init_carry(1))
        self.step_many(np.zeros((W, F), np.float32),
                       [self.init_carry(1) for _ in range(W)])
        # the donating variant is what the engine's runner dispatches
        # off-CPU — it must be compiled here too, not on the first
        # flush (on CPU this resolves to the plain program: cache hit)
        self.step_many(np.zeros((W, F), np.float32),
                       [self.init_carry(1) for _ in range(W)],
                       donate=True)
        self.replay(np.zeros((1, self.window, F), np.float32))
        return 4

    # -- calibration -------------------------------------------------------
    def calibrate(self, windows, quantile: float = 0.95) -> "LSTMForecaster":
        """Fit the EVT tail + indicator thresholds on this model's own
        forecast distribution over a reference window set."""
        y, _ = self.predict(windows)
        self.tail = fit_tail(np.abs(y), q=quantile)
        self.eps = quantile_thresholds(y, q=quantile)
        return self

    def with_params(self, params: PyTree) -> "LSTMForecaster":
        """Unpublished successor serving ``params`` with this model's
        calibration carried over — the hot-swap constructor. Shares the
        compiled programs, so building one never traces or compiles."""
        return dataclasses.replace(self, params=params, version=0,
                                   published_at=None)


@dataclasses.dataclass
class ZooForecaster:
    """Any model-zoo arch behind the serving interface: forecast is the
    greedy next token; extreme probability is EVT-calibrated surprisal."""

    cfg: Any                     # repro.configs.base.ArchConfig
    params: PyTree
    tail: dict | None = None
    gamma: float = 5.0
    version: int = 0
    published_at: float | None = None
    kind: str = dataclasses.field(default="zoo", init=False)

    def __post_init__(self):
        from repro.models.model_zoo import build_model
        self._model = build_model(self.cfg)

        def _fwd(params, tokens, lengths):
            frames = None
            if self.cfg.family == "audio":
                # the audio frontend is stubbed repo-wide (spec): serve
                # with deterministic synthetic frame embeddings, as the
                # pre-subsystem serve launcher did
                frames = jax.random.normal(
                    jax.random.PRNGKey(0),
                    (tokens.shape[0], self.cfg.n_frames, self.cfg.d_model))
            logits, _ = self._model.forward(params, tokens, frames)
            idx = (lengths - 1)[:, None, None]
            last = jnp.take_along_axis(logits, jnp.broadcast_to(
                idx, (logits.shape[0], 1, logits.shape[2])), axis=1)[:, 0]
            last = last[:, :self.cfg.vocab]
            logp = jax.nn.log_softmax(last, axis=-1)
            tok = jnp.argmax(last, axis=-1)
            surprisal = -jnp.take_along_axis(logp, tok[:, None], 1)[:, 0]
            return tok.astype(jnp.int32), surprisal

        self._fwd = jax.jit(_fwd)

    @property
    def window(self) -> int:
        return 32                # default serving context bucket

    @property
    def feature_dim(self) -> int:
        return 0                 # token ids, no feature axis

    def predict(self, windows, lengths=None):
        """windows int32 [B, T] token ids (right-padded). Returns
        (next_token [B] as float32, p_extreme [B])."""
        tokens = jnp.asarray(windows, jnp.int32)
        if lengths is None:
            lengths = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
        tok, surprisal = self._fwd(self.params, tokens,
                                   jnp.asarray(lengths, jnp.int32))
        p = _alert_probability(surprisal, self.tail, self.gamma)
        return np.asarray(tok, np.float32), np.asarray(p)

    def calibrate(self, windows, quantile: float = 0.95) -> "ZooForecaster":
        tokens = jnp.asarray(windows, jnp.int32)
        lengths = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
        _, surprisal = self._fwd(self.params, tokens, lengths)
        self.tail = fit_tail(np.asarray(surprisal), q=quantile)
        return self

    def with_params(self, params: PyTree) -> "ZooForecaster":
        """Unpublished successor serving ``params`` with this model's
        calibration carried over — the hot-swap constructor. A shallow
        copy (NOT dataclasses.replace: ``__post_init__`` would rebuild
        and re-jit the forward) so the compiled ``_fwd`` is shared;
        params are traced arguments, so serving the clone never
        retraces."""
        import copy

        clone = copy.copy(self)
        clone.params = params
        clone.version = 0
        clone.published_at = None
        return clone


def build_lstm_forecaster(seed: int = 0, cfg: RNNConfig | None = None,
                          params: PyTree | None = None,
                          calibrate_ticker: str | None = "AAPL",
                          n_days: int = 400) -> LSTMForecaster:
    """Paper-config LSTM forecaster; freshly initialized unless ``params``
    is given, EVT-calibrated on a synthetic reference series."""
    if cfg is None:
        from repro.configs.paper_lstm import CONFIG
        cfg = CONFIG
    if params is None:
        params = init_rnn(jax.random.PRNGKey(seed), cfg)
    fc = LSTMForecaster(cfg=cfg, params=params)
    if calibrate_ticker is not None:
        from repro.data import load_stock, make_windows
        ohlcv = load_stock(calibrate_ticker, n_days=n_days)
        ds = make_windows(ohlcv, window=cfg.window)
        fc.calibrate(ds.x)
    return fc


def build_zoo_forecaster(arch: str, seed: int = 0, reduced: bool = True,
                         calibrate_batch: int = 8) -> ZooForecaster:
    from repro.configs import get_config
    from repro.configs.base import reduced as reduce_cfg
    from repro.data.tokens import synthetic_token_batch
    from repro.models.model_zoo import build_model

    cfg = get_config(arch)
    if reduced:
        cfg = reduce_cfg(cfg)
    params = build_model(cfg).init(jax.random.PRNGKey(seed))
    fc = ZooForecaster(cfg=cfg, params=params)
    if calibrate_batch:
        toks = synthetic_token_batch(calibrate_batch, fc.window, cfg.vocab,
                                     seed=seed)
        fc.calibrate(toks)
    return fc
